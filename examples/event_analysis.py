"""The paper's own use case (sections 4-5): a physicist submits filter
expressions over a distributed event store through the GEPS portal and
retrieves merged histograms — here as a batch-of-queries script, with
both execution backends and the Pallas fused filter kernel.

Run: PYTHONPATH=src python examples/event_analysis.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store, gather_store, shard_to_mesh
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, spmd_query_step
from repro.launch.mesh import make_mesh_of

# the web form's example filter expressions (paper Fig 4)
QUERIES = [
    "e_total > 40",
    "e_total > 40 && count(pt > 15) >= 2",
    "pt_lead > 30 || m_inv > 120",
    "count(pt > 10) >= 3 && sum(pt) < 900",
    "mean(pt) > 8 && n_tracks >= 4",
]


def ascii_hist(hist, width=40):
    top = max(1, hist.max())
    lines = []
    for i in range(0, len(hist), 8):  # coarsen 64 -> 8 rows
        v = int(hist[i:i + 8].sum())
        bar = "#" * int(width * v / max(1, int(hist.sum())))
        lines.append(f"  [{i:2d}-{i+7:2d}] {bar} {v}")
    return "\n".join(lines)


def main():
    cfgE = reduced()
    schema = ev.EventSchema.from_config(cfgE)
    store = create_store(schema, n_events=2048, n_nodes=4,
                         events_per_brick=128, replication=2, seed=11)
    catalog = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(catalog, store)
    mesh = make_mesh_of((1, 1), ("data", "model"))
    sharded = shard_to_mesh(gather_store(store), mesh)

    for expr in QUERIES:
        jid = jse.submit(expr, calib_iters=2)
        merged, stats = jse.run_job_simulated(jid)

        step = jax.jit(spmd_query_step(expr, schema, calib_iters=2))
        out = step(sharded)
        assert int(out["n_selected"]) == merged.n_selected, expr
        np.testing.assert_array_equal(
            np.asarray(out["hist"], np.int64), merged.hist)

        print(f"\nquery: {expr!r}")
        print(f"  selected {merged.n_selected}/{merged.n_processed} "
              f"(grid makespan {stats.makespan_s:.2f}s virtual, "
              f"{stats.packets} packets)")
        print("  e_total histogram of selected events:")
        print(ascii_hist(merged.hist))

    # fused Pallas event-filter path (canonical hot query)
    expr = "e_total > 40 && count(pt > 15) >= 2"
    step_pl = jax.jit(spmd_query_step(expr, schema, calib_iters=2,
                                      use_pallas=True))
    out_pl = step_pl(sharded)
    step_ref = jax.jit(spmd_query_step(expr, schema, calib_iters=2))
    out_ref = step_ref(sharded)
    assert int(out_pl["n_selected"]) == int(out_ref["n_selected"])
    print(f"\nPallas fused filter kernel agrees: "
          f"{int(out_pl['n_selected'])} selected")
    print("event analysis OK")


if __name__ == "__main__":
    main()
