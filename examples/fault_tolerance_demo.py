"""Fault tolerance end to end — the paper's future-work list, working:

1. a grid node dies mid-query: packets fail over to replicas, the result
   is exact (replication closes the paper's 'biggest disadvantage'),
2. the node rejoins: the elastic manager produces a rebalance plan,
3. a TRAINING node dies mid-run: the data pipeline re-leases its brick
   ranges; training continues uninterrupted,
4. the training process itself is killed and restarted: it resumes from
   the latest checkpoint,
5. the surviving-chip count changes: elastic_mesh_shape picks the new
   mesh and the checkpoint restores onto it (restore-by-path).

Run: PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import numpy as np

from repro.configs.geps_events import reduced
from repro.configs.registry import reduced_config
from repro.core import events as ev
from repro.core.brick import create_store, gather_store
from repro.core.catalog import MetadataCatalog
from repro.core.elastic import ElasticManager, elastic_mesh_shape
from repro.core.jse import JobSubmissionEngine
from repro.launch.mesh import make_mesh_of
from repro.train.trainer import Trainer, TrainerConfig


def main():
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=1024, n_nodes=4,
                         events_per_brick=64, replication=2, seed=21)
    catalog = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(catalog, store)

    # --- 1: node death mid-query ----------------------------------- #
    expect = int((gather_store(store)["scalars"][:, 0] > 40).sum())
    jid = jse.submit("e_total > 40")
    merged, stats = jse.run_job_simulated(jid, failure_script={0.3: 2})
    print(f"[1] node 2 died mid-job: selected {merged.n_selected}/{expect} "
          f"(exact={merged.n_selected == expect}), "
          f"{stats.reassigned} reassignments")
    assert merged.n_selected == expect

    # --- 2: elastic rejoin ------------------------------------------ #
    em = ElasticManager(catalog, store)
    plan = em.node_leave(2)
    em.apply_copies(plan)
    print(f"[2] node 2 left: {len(plan.reassign_primary)} bricks failed "
          f"over, {len(plan.copies)} re-replication copies, "
          f"{len(plan.lost_bricks)} lost")
    plan2 = em.node_join(2)
    print(f"    node 2 rejoined: {len(plan2.reassign_primary)} bricks "
          "migrated back")

    # --- 3+4: training through failures + restart ------------------- #
    cfg = reduced_config("qwen3-14b")
    mesh = make_mesh_of((1, 1), ("data", "model"))
    kills = {3: 1}
    tcfg = TrainerConfig(total_steps=6, ckpt_every=3, global_batch=4,
                         seq_len=32, log_every=2, async_ckpt=False,
                         ckpt_dir="/tmp/ft_demo_ckpt")
    import shutil
    shutil.rmtree("/tmp/ft_demo_ckpt", ignore_errors=True)
    tr = Trainer(cfg, tcfg, mesh,
                 failure_hook=lambda s: kills.pop(s, None))
    tr.train()
    print(f"[3] data node 1 died at step 3; training reached step 6")

    tcfg2 = TrainerConfig(total_steps=10, ckpt_every=5, global_batch=4,
                          seq_len=32, log_every=2, async_ckpt=False,
                          ckpt_dir="/tmp/ft_demo_ckpt")
    tr2 = Trainer(cfg, tcfg2, mesh)
    out = tr2.train()
    print(f"[4] restarted process resumed from step 6, ran "
          f"{out['steps']} more steps")
    assert out["steps"] == 4

    # --- 5: elastic re-mesh ------------------------------------------ #
    for chips in (256, 224, 128):
        print(f"[5] {chips} chips alive -> mesh {elastic_mesh_shape(chips)}")
    print("fault tolerance demo OK")


if __name__ == "__main__":
    main()
