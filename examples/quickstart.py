"""Quickstart: the GEPS grid-brick system end to end in one minute.

1. create a brick store (events distributed over 4 simulated nodes),
2. submit a filter job through the metadata catalogue,
3. let the JSE broker pick it up, dispatch per-brick packets, merge,
4. run the SAME query as one SPMD step over the mesh-sharded store,
5. train a tiny LM fed from token bricks for a few steps.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.geps_events import reduced
from repro.configs.registry import reduced_config
from repro.core import events as ev
from repro.core.brick import create_store, gather_store, shard_to_mesh
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, spmd_query_step
from repro.launch.mesh import make_mesh_of
from repro.train.trainer import Trainer, TrainerConfig


def main():
    # ---- 1-3: host-level GEPS ---------------------------------------- #
    cfgE = reduced()
    schema = ev.EventSchema.from_config(cfgE)
    store = create_store(schema, n_events=512, n_nodes=4,
                         events_per_brick=64, replication=2)
    catalog = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(catalog, store)

    expr = "e_total > 40 && count(pt > 15) >= 1"
    job = jse.submit(expr, calib_iters=2)
    print(f"submitted job {job}: {expr!r}")
    jse.broker_poll()  # the paper's polling broker
    rec = catalog.jobs[job]
    print(f"job status={rec.status} selected={rec.result['n_selected']}"
          f"/{rec.result['n_processed']} "
          f"virtual makespan={rec.result['makespan_s']:.2f}s")

    # node info, the paper's GRIS/LDAP query (Fig 5)
    print("grid-info node 0:", catalog.grid_info(0))

    # ---- 4: the SPMD realization ------------------------------------- #
    mesh = make_mesh_of((1, 1), ("data", "model"))
    sharded = shard_to_mesh(gather_store(store), mesh)
    step = jax.jit(spmd_query_step(expr, schema, calib_iters=2))
    out = step(sharded)
    assert int(out["n_selected"]) == rec.result["n_selected"]
    print(f"SPMD query step agrees: {int(out['n_selected'])} selected")

    # ---- 5: brick-fed training --------------------------------------- #
    cfg = reduced_config("qwen3-14b")
    tcfg = TrainerConfig(total_steps=10, ckpt_every=5, global_batch=4,
                         seq_len=32, log_every=5,
                         ckpt_dir="/tmp/quickstart_ckpt", async_ckpt=False)
    trainer = Trainer(cfg, tcfg, mesh)
    result = trainer.train()
    print(f"trained {result['steps']} steps, "
          f"final loss {result['final_loss']:.3f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
