"""Multi-tenant query service walkthrough.

Four tenants fire a burst of filter queries at the grid; the service
coalesces compatible queries into shared-scan batches, dedups identical
ones, answers repeats from the result cache, and records every job in the
metadata catalogue.  Run with::

    PYTHONPATH=src python examples/multi_tenant_queries.py
"""
from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.service import QueryScheduler, QueryService


def main():
    cfg = reduced()
    schema = ev.EventSchema.from_config(cfg)
    store = create_store(schema, n_events=1024, n_nodes=4,
                         events_per_brick=cfg.events_per_brick,
                         replication=2, seed=0)
    svc = QueryService(store, scheduler=QueryScheduler(max_batch=16))

    print("== burst 1: four tenants, overlapping queries ==")
    tickets = []
    for tenant in ("alice", "bob", "carol", "dan"):
        tickets.append((tenant, svc.submit(
            "e_total > 40 && count(pt > 15) >= 2", tenant=tenant)))
        tickets.append((tenant, svc.submit(
            f"e_t_miss > {25 + len(tenant)}", tenant=tenant)))
    svc.drain()
    for tenant, tid in tickets:
        tk = svc.result(tid)
        print(f"  {tenant:6s} #{tid}: {tk.status:7s} "
              f"selected={tk.result.n_selected:4d} "
              f"(job {tk.job_id}, batch {tk.batch_id})")

    print("== burst 2: repeats -> cache, no brick I/O ==")
    scanned = svc.stats.events_scanned
    tid = svc.submit("e_total>40.0 && count(pt>15)>=2", tenant="eve")
    tk = svc.result(tid)
    print(f"  eve    #{tid}: {tk.status} from_cache={tk.from_cache} "
          f"extra_events_scanned={svc.stats.events_scanned - scanned}")

    print("== dataset bump invalidates the cache ==")
    svc.catalog.bump_dataset_version()
    tid = svc.submit("e_total > 40 && count(pt > 15) >= 2", tenant="eve")
    svc.drain()
    print(f"  eve    #{tid}: from_cache={svc.result(tid).from_cache} "
          f"(rescan after epoch bump)")

    s = svc.stats
    print(f"totals: submitted={s.submitted} served={s.served} "
          f"batches={s.batches} jobs_run={s.jobs_run} "
          f"cache_hits={s.cache_hits} events_scanned={s.events_scanned}")


if __name__ == "__main__":
    main()
