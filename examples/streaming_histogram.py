"""Progressive histogram over a streamed grid scan.

The interactive-analysis UX the streaming subsystem exists for: submit a
filter query with ``stream=True``, watch the ``e_total`` histogram fill in
live as bricks report (each update is the EXACT answer over the events
scanned so far, with coverage metadata), and verify at the end that the
final snapshot is bit-identical to the batch JSE merge.

Run: PYTHONPATH=src python examples/streaming_histogram.py
"""
from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine
from repro.core.merge import results_identical
from repro.service import QueryService

EXPR = "e_total > 40 && count(pt > 15) >= 1"
N_EVENTS, N_NODES = 2048, 4


def ascii_hist(hist, width=48, bins=16):
    """Render a coarse ASCII view of the 64-bin e_total histogram."""
    coarse = hist.reshape(bins, -1).sum(axis=1)
    top = max(1, int(coarse.max()))
    return "\n".join(
        f"    [{i * 512 // bins:3d}-{(i + 1) * 512 // bins:3d}) "
        f"{'#' * int(width * c / top):<{width}} {int(c)}"
        for i, c in enumerate(coarse))


def main():
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=N_EVENTS, n_nodes=N_NODES,
                         events_per_brick=128, replication=2, seed=3)
    svc = QueryService(store, use_cache=False)

    tid = svc.submit(EXPR, tenant="analyst", stream=True)
    stream = svc.stream(tid)

    # live consumption: this callback runs inside the scan loop, so the
    # histogram genuinely renders mid-job at each quarter of coverage
    marks = [0.25, 0.5, 0.75]

    def on_update(snap):
        frac = snap.coverage.fraction or 0.0
        if marks and frac >= marks[0]:
            while marks and frac >= marks[0]:
                marks.pop(0)
            print(f"\n  t={snap.t_virtual:6.2f}s virtual — "
                  f"{snap.coverage.events_scanned}/"
                  f"{snap.coverage.events_total} events "
                  f"({100 * frac:.0f}%), "
                  f"{len(snap.coverage.bricks_seen)}/"
                  f"{snap.coverage.bricks_total} bricks, "
                  f"{snap.result.n_selected} selected")
            print(ascii_hist(snap.result.hist))

    stream.subscribe(on_update)
    print(f"streaming {EXPR!r} over {N_EVENTS} events / "
          f"{len(store.bricks)} bricks / {N_NODES} nodes")
    svc.step()

    final = stream.latest()
    assert final is not None and final.final
    print(f"\n  FINAL t={final.t_virtual:6.2f}s — "
          f"{final.result.n_selected} selected, coverage "
          f"{'complete' if final.coverage.complete else 'partial'}")
    print(ascii_hist(final.result.hist))

    # the guarantee: the final streamed snapshot is bit-identical to the
    # batch path (an independent JSE run merging only at job end)
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    batch, _ = jse.run_job_simulated(jse.submit(EXPR))
    assert results_identical(final.result, batch)
    print(f"\nfinal snapshot bit-identical to batch JSE merge "
          f"({stream.published} progressive snapshots along the way) — OK")


if __name__ == "__main__":
    main()
