"""End-to-end training driver: a ~100M-parameter qwen3-family LM trained
for a few hundred steps on brick-resident synthetic token data, with
checkpoints, restart, and loss reporting.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--resume]
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_mesh_of
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: 12L, d=768, 12H (kv 4), d_ff=2048, 32k vocab
CFG_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    qk_norm=True,
    rope_style="neox",
    mlp_style="swiglu",
    dtype="float32",       # CPU example: f32 avoids bf16 emulation cost
    param_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    ap.add_argument("--out", default="experiments/train_lm_history.json")
    args = ap.parse_args()

    from repro.models import model_zoo
    model = model_zoo.build_model(CFG_100M)
    print(f"model {CFG_100M.name}: {model.table.num_params()/1e6:.1f}M params")

    mesh = make_mesh_of((1, 1), ("data", "model"))
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(25, args.steps // 4),
        ckpt_dir=args.ckpt_dir, global_batch=args.batch, seq_len=args.seq,
        lr=3e-4, log_every=10, async_ckpt=True)
    trainer = Trainer(CFG_100M, tcfg, mesh)
    t0 = time.time()
    result = trainer.train()
    wall = time.time() - t0
    tokens = result["steps"] * args.batch * args.seq
    print(f"steps={result['steps']} wall={wall:.0f}s "
          f"tokens/s={tokens/max(wall,1e-9):.0f} "
          f"final_loss={result['final_loss']:.3f}")
    losses = trainer.history
    assert losses[-1]["loss"] < losses[0]["loss"], "loss must decrease"
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(
        {"config": dataclasses.asdict(CFG_100M), "history": losses,
         "wall_s": wall}, indent=2))
    print(f"history -> {args.out}")


if __name__ == "__main__":
    main()
