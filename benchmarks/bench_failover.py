"""Node-failure recovery (the paper's acknowledged weakness + future work):
kill a node mid-job and compare outcomes with replication factor 1 vs 2.

r=1: the job FAILS when the dead node's bricks have no replica (the
paper's "biggest disadvantage").  r=2: the packets re-queue onto replica
owners and the result is exactly the no-failure result, at a measured
makespan penalty."""
from __future__ import annotations

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store, gather_store
from repro.core.catalog import FAILED, MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel

EXPR = "e_total > 40"


def run(replication: int, kill_at=0.5, n_events=2048, n_nodes=4):
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                         events_per_brick=128, replication=replication,
                         seed=4)
    cat = MetadataCatalog(n_nodes)
    jse = JobSubmissionEngine(cat, store, TimeModel())
    jid = jse.submit(EXPR)
    merged, stats = jse.run_job_simulated(jid, failure_script={kill_at: 1})
    # post-failure the catalogue may report FAILED for r=1 jobs re-run
    import numpy as np
    batch = gather_store(store)
    expect = int((batch["scalars"][:, 0] > 40).sum())
    return {
        "replication": replication,
        "status": cat.jobs[jid].status,
        "selected": merged.n_selected,
        "expected": expect,
        "makespan_s": stats.makespan_s,
        "reassigned": stats.reassigned,
    }


def main():
    import os
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    n_ev = 512 if smoke else 2048
    baseline = run(replication=2, kill_at=1e9, n_events=n_ev)  # no failure
    r2 = run(replication=2, n_events=n_ev)
    print("scenario,status,selected,expected,makespan_s")
    print(f"no_failure_r2,{baseline['status']},{baseline['selected']},"
          f"{baseline['expected']},{baseline['makespan_s']:.3f}")
    print(f"kill_node1_r2,{r2['status']},{r2['selected']},"
          f"{r2['expected']},{r2['makespan_s']:.3f}")
    assert r2["selected"] == r2["expected"], "r=2 must lose no events"
    # r=1 with a dead node that exclusively owns bricks: job fails
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_ev, n_nodes=4,
                         events_per_brick=128, replication=1, seed=4)
    cat = MetadataCatalog(4)
    cat.mark_dead(1)
    jse = JobSubmissionEngine(cat, store, TimeModel())
    jid = jse.submit(EXPR)
    jse.run_job_simulated(jid)
    print(f"dead_node1_r1,{cat.jobs[jid].status},0,{r2['expected']},inf")
    assert cat.jobs[jid].status == FAILED
    print(f"# failover penalty: {r2['makespan_s'] / baseline['makespan_s']:.2f}x"
          f" makespan, 0 lost events (paper's weakness closed by replication)")


if __name__ == "__main__":
    main()
