"""Node-failure recovery (the paper's acknowledged weakness + future work):
kill a node mid-job and compare outcomes with replication factor 1 vs 2.

r=1: the job FAILS when the dead node's bricks have no replica (the
paper's "biggest disadvantage").  r=2: the packets re-queue onto replica
owners and the result is exactly the no-failure result, at a measured
makespan penalty.

A second pass measures the failure-policy engine (``service/policy.py``)
acting BEFORE the death: seeded failure evidence drives the sick node to
``banned``, the trace proves zero packets were routed to it from that
window on, and sustained degradation proactively re-replicates its
bricks — all while results stay identical to a policy-less service."""
from __future__ import annotations

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.backend import SimulatedBackend
from repro.core.brick import create_store, gather_store
from repro.core.catalog import FAILED, MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel
from repro.core import merge as merge_lib
from repro.obs import Observability
from repro.service import QueryService
from repro.service.policy import (POLICY_BANNED, FailurePolicy,
                                  PolicyConfig)

EXPR = "e_total > 40"


def run(replication: int, kill_at=0.5, n_events=2048, n_nodes=4):
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                         events_per_brick=128, replication=replication,
                         seed=4)
    cat = MetadataCatalog(n_nodes)
    jse = JobSubmissionEngine(cat, store, TimeModel())
    jid = jse.submit(EXPR)
    merged, stats = jse.run_job_simulated(jid, failure_script={kill_at: 1})
    # post-failure the catalogue may report FAILED for r=1 jobs re-run
    import numpy as np
    batch = gather_store(store)
    expect = int((batch["scalars"][:, 0] > 40).sum())
    return {
        "replication": replication,
        "status": cat.jobs[jid].status,
        "selected": merged.n_selected,
        "expected": expect,
        "makespan_s": stats.makespan_s,
        "reassigned": stats.reassigned,
    }


def run_policy(n_events=2048, n_windows=6):
    """Drive a policy-armed service while node 1 keeps failing for two
    windows; report the ban window, packets routed to the banned node
    after it (must be 0), and proactive re-replication volume."""
    schema = ev.EventSchema.from_config(reduced())

    def service(policy_on):
        store = create_store(schema, n_events=n_events, n_nodes=4,
                             events_per_brick=256, replication=2, seed=4)
        cat = MetadataCatalog(4)
        obs = Observability(origin="bench")
        pol = None
        if policy_on:
            pol = FailurePolicy(cat, store, obs=obs, config=PolicyConfig(
                degrade_after=1, ban_after=1, probe_after=99,
                rereplicate_after=2, rate_evidence=False))
        svc = QueryService(store, backend=SimulatedBackend(
            cat, store, adaptive_packets=False), obs=obs, policy=pol)
        return svc, obs, pol

    svc, obs, pol = service(True)
    plain, _, _ = service(False)
    results, want = [], []
    ban_window, banned_node_packets = None, 0
    for w in range(n_windows):
        if w < 2:
            for _ in range(6):
                obs.health.observe_failure(1)
        expr = f"e_total > {30 + w}"
        results.append(svc.submit(expr))
        want.append(plain.submit(expr))
        before = len(obs.tracer.records())
        svc.step()
        plain.step()
        if pol.states()[1] == POLICY_BANNED and ban_window is None:
            ban_window = w
        if ban_window is not None and w > ban_window:
            banned_node_packets += sum(
                1 for r in obs.tracer.records()[before:]
                if r.get("name") == "packet" and r["attrs"].get("node") == 1)
    identical = all(
        merge_lib.results_identical(svc.result(a).result,
                                    plain.result(b).result)
        for a, b in zip(results, want))
    return {"ban_window": ban_window,
            "banned_node_packets": banned_node_packets,
            "rereplications": pol.rereplications,
            "copies": int(obs.metrics.value("policy.rereplications") or 0),
            "identical": identical}


def main():
    import os
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    n_ev = 512 if smoke else 2048
    baseline = run(replication=2, kill_at=1e9, n_events=n_ev)  # no failure
    r2 = run(replication=2, n_events=n_ev)
    print("scenario,status,selected,expected,makespan_s")
    print(f"no_failure_r2,{baseline['status']},{baseline['selected']},"
          f"{baseline['expected']},{baseline['makespan_s']:.3f}")
    print(f"kill_node1_r2,{r2['status']},{r2['selected']},"
          f"{r2['expected']},{r2['makespan_s']:.3f}")
    assert r2["selected"] == r2["expected"], "r=2 must lose no events"
    # r=1 with a dead node that exclusively owns bricks: job fails
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_ev, n_nodes=4,
                         events_per_brick=128, replication=1, seed=4)
    cat = MetadataCatalog(4)
    cat.mark_dead(1)
    jse = JobSubmissionEngine(cat, store, TimeModel())
    jid = jse.submit(EXPR)
    jse.run_job_simulated(jid)
    print(f"dead_node1_r1,{cat.jobs[jid].status},0,{r2['expected']},inf")
    assert cat.jobs[jid].status == FAILED
    print(f"# failover penalty: {r2['makespan_s'] / baseline['makespan_s']:.2f}x"
          f" makespan, 0 lost events (paper's weakness closed by replication)")

    pol = run_policy(n_events=512 if smoke else 2048)
    print("policy: ban_window,banned_node_packets,rereplicated_copies,"
          "identical")
    print(f"policy,{pol['ban_window']},{pol['banned_node_packets']},"
          f"{pol['copies']},{pol['identical']}")
    assert pol["ban_window"] is not None, "policy must ban the sick node"
    assert pol["banned_node_packets"] == 0, \
        "no packet may route to a banned node"
    assert pol["rereplications"] >= 1 and pol["copies"] >= 1
    assert pol["identical"], "policy must not change results"
    print("# policy: sick node banned pre-death, bricks re-replicated, "
          "0 packets routed post-ban")


if __name__ == "__main__":
    main()
