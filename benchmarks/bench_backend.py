"""Execution-backend benchmark: the SPMD chunked streaming scan vs the
simulated grid, under ONE contract.

Claims under test (the unified-backend acceptance bar):

1. **Equivalence** — a dispatch window executed through
   ``SpmdBackend.run_batch`` produces final results bit-identical to
   ``SimulatedBackend.run_batch`` for the same window and packetization
   (both backends run the same fragment-factored
   ``eval_plan_slice`` primitive in the same merge order), and every
   per-chunk partial matches packet-for-packet.
2. **Streaming** — the SPMD path streams per-chunk partials: wall-clock
   time-to-first-partial must be <= 1/2 of time-to-final (it lands far
   below; the step-end-merge SPMD path it replaces had ratio 1.0 by
   construction), and the stream-aware ramp (``packet_ramp``) pushes the
   first partial earlier still without changing results.

3. **Raw speed** — the perf-pass acceptance bar: the ``(block_e,
   block_t)`` autotune sweep never loses to the fixed ``(128, 512)``
   default (the default is itself a candidate) and records a roofline
   point per tuned shape; a MIXED window (some targets out-of-family)
   still pushes events through the kernel sub-batch
   (``stats.kernel_events > 0``) bit-identically; and the mesh-sharded
   scan's lockstep critical-path makespan scales near-linearly —
   >= 1.7x at 2 mesh devices over the same measured per-chunk compute.

Run: ``PYTHONPATH=src python benchmarks/bench_backend.py``
(writes a ``BENCH_backend.json`` snapshot next to this file;
``BENCH_SMOKE=1`` shrinks the store and skips asserts + the snapshot;
``--autotune`` runs the block-shape sweep alone).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import time

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.backend import SimulatedBackend, SpmdBackend
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import eval_plan_slice
from repro.core.merge import results_identical
from repro.service import plan_window

N_EVENTS = 16384
N_NODES = 8
EVENTS_PER_BRICK = 256
CHUNK = 64  # fixed packet/chunk size on BOTH backends (identity requires
            # matching packetization; the sim runs adaptive_packets=False)
OUT = pathlib.Path(__file__).resolve().parent / "BENCH_backend.json"

BATCH = ["e_total > 40 && count(pt > 15) >= 2",
         "e_total > 30 && count(pt > 15) >= 2",
         "e_t_miss > 25 && count(pt > 15) >= 2",
         "pt_lead > 60 || n_tracks >= 8",
         "e_total > 55 && sum(pt) < 400",
         "e_t_miss > 40"]


def smoke() -> bool:
    """True under the CI benchmark smoke job (tiny store, no asserts or
    snapshot writes — bit-rot detection only)."""
    return os.environ.get("BENCH_SMOKE") == "1"


def run_window(backend, store, exprs, *, ramp=None):
    """Execute one shared-scan window on ``backend``; returns
    ``(merged, stats, partials, row)`` with wall/stream metrics."""
    plan = plan_window(exprs)
    jids = [backend.catalog.submit(e, 0, tuple(sorted(store.bricks)))
            for e in exprs]
    partials = []
    t0 = time.perf_counter()
    merged, stats = backend.run_batch(jids, plan=plan,
                                      on_partial=partials.append,
                                      packet_ramp=ramp)
    wall = time.perf_counter() - t0
    t_first = partials[0].t_virtual if partials else float("nan")
    t_final = stats.makespan_s
    return merged, stats, partials, {
        "queries": len(exprs),
        "packets": stats.packets,
        "t_first_partial_s": round(t_first, 4),
        "t_final_s": round(t_final, 4),
        "ratio": round(t_first / t_final, 4) if t_final else None,
        "wall_s": round(wall, 2),
    }


def autotune_pass(store):
    """The ``(block_e, block_t)`` sweep on a real chunk of this store's
    workload: returns the snapshot section (winner + measurements +
    roofline point) and asserts the tuned shape never loses to the fixed
    default."""
    import jax.numpy as jnp

    from repro.kernels.event_filter import ops as ef_ops
    from repro.kernels.event_filter import tune as ef_tune
    from repro.service import plan_window

    plan = plan_window([e for e in BATCH
                        if ef_ops.match_epilogue(e, store.schema)])
    params = [ef_ops.match_epilogue(t, store.schema)
              for t in plan.targets()]
    thresholds, var_idx = ef_ops.batch_kernel_params(params)
    batch = store.bricks[0]
    n = min(CHUNK, batch["scalars"].shape[0])
    ef_tune.clear_cache()
    tuned = ef_tune.autotune_block_shapes(
        jnp.asarray(batch["scalars"][:n]),
        jnp.asarray(batch["tracks"][:n]),
        jnp.asarray(batch["n_tracks"][:n]),
        thresholds, var_idx=var_idx, calib_iters=0, repeats=3)
    print(f"autotune: chunk ({n} ev), K={thresholds.shape[1]} -> "
          f"({tuned.block_e}, {tuned.block_t}) at {tuned.best_ms:.2f}ms "
          f"(default {ef_tune.DEFAULT_SHAPE} at {tuned.default_ms:.2f}ms, "
          f"{tuned.speedup_vs_default:.2f}x), "
          f"{tuned.roofline['gbytes_per_s']:.2f} GB/s")
    assert tuned.speedup_vs_default >= 1.0, \
        "tuned shape lost to the fixed (128, 512) default"
    return tuned.as_dict()


def mixed_window_pass(store, ref_merged, ref_partials):
    """A mixed window (kernel + jnp targets) through the split path:
    asserts the kernel sub-batch actually ran and everything stays
    bit-identical to the pure-jnp reference with the same chunking."""
    fused = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=CHUNK, use_pallas=True)
    merged, stats, partials, row = run_window(fused, store, BATCH)
    assert stats.kernel_events == N_EVENTS, \
        f"mixed window fell back to pure jnp (kernel_events=" \
        f"{stats.kernel_events})"
    for got, ref in zip(merged, ref_merged):
        assert results_identical(got, ref), "mixed-split final diverged"
    for pa, pb in zip(ref_partials, partials):
        assert all(results_identical(a, b)
                   for a, b in zip(pa.partials, pb.partials)), \
            "mixed-split partial diverged"
    row["kernel_events"] = stats.kernel_events
    print(f"mixed window: kernel_events={stats.kernel_events} "
          f"(of {N_EVENTS} scanned), finals + partials bit-identical, OK")
    return row


def mesh_scaling_pass(store):
    """SPMD final-time scaling with mesh width, on the lockstep
    critical-path clock: D=1 measures the serial per-chunk walls, D=2/4
    group the SAME compute onto an emulated mesh where each group costs
    its slowest member.  Near-linear scaling (>= 1.7x at D=2) is the
    acceptance bar; the model is honest — it is exactly the makespan a
    D-wide lockstep mesh pays for the measured per-shard compute (the
    shard_map fast path takes over when the host really has D devices)."""
    section = {}
    base_makespan = None
    for d in (1, 2, 4):
        be = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                         chunk_events=CHUNK, use_pallas=True,
                         mesh_devices=d, double_buffer=False)
        # warm the kernel dispatch for every chunk shape this run sees,
        # so group walls measure the scan, not jax compile
        _, _, _, row = run_window(be, store, BATCH)
        _, stats, _, row = run_window(
            SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=CHUNK, use_pallas=True,
                        mesh_devices=d, double_buffer=False),
            store, BATCH)
        if base_makespan is None:
            base_makespan = row["t_final_s"]
        speedup = base_makespan / max(row["t_final_s"], 1e-9)
        section[f"mesh{d}"] = {
            "mesh_devices": d,
            "t_final_s": row["t_final_s"],
            "speedup_vs_1": round(speedup, 3),
        }
        print(f"mesh scaling: D={d} final {row['t_final_s']:.3f}s "
              f"(speedup {speedup:.2f}x)")
    if not smoke():
        assert section["mesh2"]["speedup_vs_1"] >= 1.7, \
            f"mesh D=2 speedup {section['mesh2']['speedup_vs_1']} < 1.7"
    return section


def main():
    global N_EVENTS
    args = argparse.ArgumentParser()
    args.add_argument("--autotune", action="store_true",
                      help="run only the block-shape autotune sweep")
    args = args.parse_args()
    if smoke():
        N_EVENTS = 2048
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=N_EVENTS, n_nodes=N_NODES,
                         events_per_brick=EVENTS_PER_BRICK,
                         replication=2, seed=17)
    print(f"workload: {N_EVENTS} events / {len(store.bricks)} bricks / "
          f"{N_NODES} nodes / chunk {CHUNK}")
    if args.autotune:
        autotune_pass(store)
        return

    # warm the jnp dispatch path OUTSIDE the timed runs — one pass per
    # chunk shape the runs will see (ramp: 16, 32; steady state: 64) —
    # so the SPMD first-partial latency measures the scan, not jax
    # per-shape warmup
    for size in (16, 32, CHUNK):
        eval_plan_slice(store, plan_window(BATCH), 0, 0, size, 0)

    sim = SimulatedBackend(MetadataCatalog(store.n_nodes), store,
                           adaptive_packets=False)
    sim.engine.packet_ramp = None
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=CHUNK)
    spmd_ramp = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                            chunk_events=CHUNK)

    rows = {}
    print("name,queries,packets,t_first_partial_s,t_final_s,ratio,wall_s")
    runs = (("sim", sim, None), ("spmd", spmd, None),
            ("spmd_ramp", spmd_ramp, 16))
    merged_by, parts_by = {}, {}
    for name, backend, ramp in runs:
        merged, stats, partials, row = run_window(backend, store, BATCH,
                                                  ramp=ramp)
        merged_by[name], parts_by[name] = merged, partials
        rows[name] = row
        print(f"{name},{row['queries']},{row['packets']},"
              f"{row['t_first_partial_s']},{row['t_final_s']},"
              f"{row['ratio']},{row['wall_s']}")

    # equivalence: spmd finals bit-identical to sim (same packetization —
    # bit-identity is a per-packetization guarantee: a different chunking
    # regroups the float sum_var additions), and partial streams
    # packet-for-packet identical.  The ramp run repacketizes, so its
    # finals must agree exactly on every decomposition-invariant field
    # (counts, histogram, id sample) and to fp tolerance on sum_var.
    for got, ref in zip(merged_by["spmd"], merged_by["sim"]):
        assert results_identical(got, ref), "spmd final diverged"
    import numpy as np
    for got, ref in zip(merged_by["spmd_ramp"], merged_by["sim"]):
        assert (got.n_selected == ref.n_selected
                and got.n_processed == ref.n_processed
                and np.array_equal(got.hist, ref.hist)
                and np.array_equal(got.selected_ids, ref.selected_ids)
                and np.isclose(got.sum_var, ref.sum_var, rtol=1e-6)), \
            "spmd_ramp final diverged"
    assert len(parts_by["sim"]) == len(parts_by["spmd"])
    for pa, pb in zip(parts_by["sim"], parts_by["spmd"]):
        assert (pa.brick_id, pa.start, pa.size) == \
               (pb.brick_id, pb.start, pb.size)
        assert all(results_identical(a, b)
                   for a, b in zip(pa.partials, pb.partials))
    print("equivalence: spmd finals + per-packet partials bit-identical "
          "to sim, OK")

    # observability no-overhead guard: one more sim window with a live
    # obs bundle attached must produce bit-identical results and the
    # exact same virtual makespan as the disabled run above (obs never
    # touches the simulation clock), and the disabled path itself is
    # just `obs is None` checks.  Runs in --smoke too (size-independent).
    from repro.obs import Observability
    sim_obs = SimulatedBackend(MetadataCatalog(store.n_nodes), store,
                               adaptive_packets=False)
    sim_obs.engine.packet_ramp = None
    sim_obs.obs = Observability(origin="bench")
    merged_o, stats_o, parts_o, row_o = run_window(sim_obs, store, BATCH)
    assert all(results_identical(a, b)
               for a, b in zip(merged_o, merged_by["sim"])), \
        "obs-enabled sim results diverged"
    assert row_o["t_final_s"] == rows["sim"]["t_final_s"], \
        "obs-enabled sim changed the virtual makespan"
    print(f"obs guard: enabled run identical "
          f"(makespan {row_o['t_final_s']}s, wall {row_o['wall_s']}s vs "
          f"disabled {rows['sim']['wall_s']}s), OK")

    # perf pass: kernel autotune, mixed-window split, mesh scaling.
    # Correctness asserts (bit-identity, kernel_events, tuned >= default)
    # run in smoke too; only the timing gate (mesh 1.7x) is full-run.
    autotune = autotune_pass(store)
    rows["spmd_mixed"] = mixed_window_pass(store, merged_by["spmd"],
                                           parts_by["spmd"])
    scaling = mesh_scaling_pass(store)

    if not smoke():
        # regression pin: disabled-path final times must stay within 2%
        # of the committed snapshot.  The sim makespan is deterministic
        # (drift there means real code change), so it hard-fails; the
        # spmd final is wall-clock on the measuring host, so cross-host
        # drift is reported but only the deterministic path gates.
        if OUT.exists():
            old = json.loads(OUT.read_text())
            if old.get("config", {}).get("n_events") == N_EVENTS:
                for name in ("sim", "spmd"):
                    prev = old["rows"][name]["t_final_s"]
                    cur = rows[name]["t_final_s"]
                    drift = abs(cur - prev) / max(prev, 1e-9)
                    if name == "sim":
                        assert drift < 0.02, \
                            f"sim final time drifted {drift:.1%} vs " \
                            f"BENCH_backend.json (obs-disabled path " \
                            f"overhead?)"
                    print(f"obs guard: {name} final time drift vs "
                          f"snapshot {drift:.1%} "
                          f"({'gated <2%' if name == 'sim' else 'host-dependent, informational'})")
        for name in ("spmd", "spmd_ramp"):
            r = rows[name]
            assert r["ratio"] <= 0.5, \
                f"{name}: first partial at {r['ratio']:.2f}x of final " \
                f"(need <= 0.5)"
        assert (rows["spmd_ramp"]["t_first_partial_s"]
                <= rows["spmd"]["t_first_partial_s"] * 1.05), \
            "packet ramp regressed SPMD time-to-first-partial"
        print(f"spmd streaming: first partial at "
              f"{rows['spmd']['ratio']:.3f}x of final "
              f"(ramp {rows['spmd_ramp']['ratio']:.3f}x), OK")
        OUT.write_text(json.dumps({
            "bench": "backend",
            "config": {"n_events": N_EVENTS, "n_nodes": N_NODES,
                       "events_per_brick": EVENTS_PER_BRICK,
                       "chunk_events": CHUNK, "ramp_start": 16,
                       "replication": 2, "queries": len(BATCH)},
            "rows": rows,
            "autotune": autotune,
            "scaling": scaling,
        }, indent=2) + "\n")
        print(f"snapshot written: {OUT.name}")


if __name__ == "__main__":
    main()
