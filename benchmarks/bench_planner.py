"""Shared-aggregate planner benchmark: common-subexpression factoring on a
near-duplicate workload.

The claim under test: interactive HEP analysis traffic is dominated by
*near*-duplicate queries — the same expensive track aggregates under
different outer scalar filters.  PR 1's coalescing dedups only identical
canonical queries, so each of the 64 distinct near-duplicates below still
evaluates its own copy of the shared ``count(pt > B)`` / ``sum(pt)``
fragments on every resident packet.  The planner hash-conses every
subexpression across the window and evaluates each unique fragment once
per packet, so per-brick fragment evaluations drop >= 2x while per-query
results stay bit-identical to independent execution.

Run: ``PYTHONPATH=src python benchmarks/bench_planner.py``
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine
from repro.core.merge import results_identical
from repro.service import plan_window

N_EVENTS = 2048
N_NODES = 4
K = 64

# three hot aggregate fragments shared across the window, each under a
# distinct outer scalar filter per query -> 64 distinct canonical queries
# (PR 1 coalescing dedups none of them)
SHARED = ["count(pt > 15) >= 2", "sum(pt) < 350", "count(pt > 25) >= 1"]


def near_duplicate_workload(k: int):
    return [f"e_total > {20 + i} && {SHARED[i % len(SHARED)]}"
            for i in range(k)]


def run_batch(store, exprs, *, shared: bool, failure_script=None):
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jids = [jse.submit(e) for e in exprs]
    plan = plan_window(exprs, shared=shared, materialize=shared)
    t0 = time.perf_counter()
    merged, st = jse.run_job_batch_simulated(jids, plan=plan,
                                             failure_script=failure_script)
    return merged, st, time.perf_counter() - t0


def run_singles(store, exprs, *, failure_script=None):
    out = []
    for e in exprs:
        cat = MetadataCatalog(store.n_nodes)
        jse = JobSubmissionEngine(cat, store)
        merged, _ = jse.run_job_simulated(jse.submit(e),
                                          failure_script=failure_script)
        out.append(merged)
    return out


def main():
    # CI smoke mode: tiny workload, bit-rot detection only (the factoring
    # ratio is scale-dependent, so the >=2x assert is skipped)
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    k, n_events = (12, 512) if smoke else (K, N_EVENTS)
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_events, n_nodes=N_NODES,
                         events_per_brick=128, replication=2, seed=11)
    exprs = near_duplicate_workload(k)

    base_merged, base_st, base_wall = run_batch(store, exprs, shared=False)
    plan_merged, plan_st, plan_wall = run_batch(store, exprs, shared=True)

    n_bricks = len(store.bricks)
    base_per_brick = base_st.fragment_evals / n_bricks
    plan_per_brick = plan_st.fragment_evals / n_bricks
    ratio = base_st.fragment_evals / max(1, plan_st.fragment_evals)

    print(f"workload: K={k} near-duplicate queries, "
          f"{n_events} events / {n_bricks} bricks / {N_NODES} nodes")
    print("mode,fragment_evals,per_brick,events_scanned,wall_s")
    print(f"pr1_coalescing,{base_st.fragment_evals},"
          f"{base_per_brick:.0f},{base_st.events_scanned},{base_wall:.2f}")
    print(f"planner_factored,{plan_st.fragment_evals},"
          f"{plan_per_brick:.0f},{plan_st.events_scanned},{plan_wall:.2f}")
    print(f"reduction: {ratio:.2f}x fewer per-brick fragment evaluations "
          f"({len(plan_st.fragment_results)} shared fragments materialized "
          f"into the cache for free)")

    if not smoke:
        assert ratio >= 2.0, \
            f"planner must factor >= 2x fragment evals, got {ratio:.2f}x"

    # bit-identity: factored per-query results == independent execution,
    # clean run and under a node-failure script
    singles = run_singles(store, exprs)
    for got, want in zip(plan_merged, singles):
        assert results_identical(got, want), "factored result diverged"
    script = {0.5: 1}
    fail_merged, _, _ = run_batch(store, exprs, shared=True,
                                  failure_script=script)
    fail_singles = run_singles(store, exprs, failure_script=script)
    for got, want in zip(fail_merged, fail_singles):
        assert results_identical(got, want), \
            "factored result diverged under failure script"
    print("bit-identity: OK (clean + node-failure script)")


if __name__ == "__main__":
    main()
