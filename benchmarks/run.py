"""Benchmark harness: one entry per paper table/figure + the roofline
table.  Prints ``name,value,derived`` CSV blocks.

  crossover    - paper Fig 7 (single node vs grid-brick parallel)
  granularity  - paper section 6 packet-size effect
  straggler    - PROOF-style adaptive packets vs fixed + the failure
                 policy's speculative re-execution pass (p99 time-to-final
                 ratio; BENCH_straggler.json)
  failover     - node death with/without replication (paper future work)
                 + failure-policy pass: seeded evidence bans the sick
                 node, zero packets route to it, bricks re-replicate
  multiquery   - K-query shared scan vs one-job-at-a-time + cache hits
  planner      - common-subexpression factoring on near-duplicate queries
  streaming    - time-to-first-partial vs time-to-final (progressive
                 delivery incl. the stream-aware packet ramp; writes the
                 BENCH_streaming.json snapshot)
  fabric       - fleet shared-L2 hit rate, cross-frontend first-result
                 latency, registry pre-warming (BENCH_fabric.json)
  backend      - unified execution backends: SPMD chunked streaming scan
                 vs simulated grid (bit-identical results, wall-clock
                 time-to-first-partial; BENCH_backend.json)
  query_spmd   - SPMD grid-brick query step micro-benchmark (real compute)
  perf_probe   - lower one (arch x shape) cell and report roofline terms
                 (subprocess: the probe must set XLA_FLAGS before jax
                 imports; skipped gracefully on timeout/failure)
  roofline     - per-(arch x shape) terms from the dry-run artifacts
                 (skipped unless artifacts exist; see launch/dryrun.py)

``--smoke`` runs every bench in a tiny configuration with perf asserts
and snapshot writes disabled — the CI job that keeps benchmarks from
bit-rotting between measurement sessions.
"""
from __future__ import annotations

import argparse
import os
import time


def _section(name):
    print(f"\n## {name}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, no perf asserts, no snapshot writes "
                         "(CI bit-rot gate)")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"

    _section("crossover (paper Fig 7)")
    from benchmarks import bench_crossover
    bench_crossover.main()

    _section("granularity (paper section 6)")
    from benchmarks import bench_granularity
    bench_granularity.main()

    _section("straggler mitigation (PROOF rule)")
    from benchmarks import bench_straggler
    bench_straggler.main()

    _section("failover (paper future work)")
    from benchmarks import bench_failover
    bench_failover.main()

    _section("multi-query shared scan + result cache (service)")
    from benchmarks import bench_multiquery
    bench_multiquery.main()

    _section("shared-aggregate planner (fragment factoring)")
    from benchmarks import bench_planner
    bench_planner.main()

    _section("streaming partial-merge delivery (progressive histograms)")
    from benchmarks import bench_streaming
    bench_streaming.main()

    _section("coherence fabric (fleet cache tier + registry)")
    from benchmarks import bench_fabric
    bench_fabric.main()

    _section("execution backends (SPMD chunked streaming vs simulated)")
    from benchmarks import bench_backend
    bench_backend.main()

    _section("spmd query step (grid-brick job, wall time on this host)")
    import jax
    import jax.numpy as jnp
    from repro.configs.geps_events import reduced
    from repro.core import events as ev
    from repro.core.brick import create_store, gather_store
    from repro.core.jse import spmd_query_step

    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=1024 if args.smoke else 4096,
                         n_nodes=4, events_per_brick=256, replication=2,
                         seed=5)
    batch = {k: jnp.asarray(v) for k, v in gather_store(store).items()}
    for use_pallas in (False, True):
        step = jax.jit(spmd_query_step(
            "e_total > 40 && count(pt > 15) >= 2", schema, calib_iters=4,
            use_pallas=use_pallas))
        out = step(batch)  # compile + run
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = step(batch)
            jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        label = "pallas_interpret" if use_pallas else "xla"
        print(f"query_spmd_{label},{us:.0f}us_per_call,"
              f"selected={int(out['n_selected'])}")

    _section("perf probe (lower one cell, roofline terms)")
    # subprocess on purpose: the probe must set XLA_FLAGS (host device
    # count) BEFORE jax is imported, and this harness imported jax above
    import pathlib
    import subprocess
    import sys
    probe_arch = "xlstm-350m" if args.smoke else "starcoder2-3b"
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.perf_probe",
             "--arch", probe_arch, "--shape", "train_4k"],
            capture_output=True, text=True,
            timeout=240 if args.smoke else 600,
            cwd=pathlib.Path(__file__).resolve().parent.parent,
            env={**os.environ,
                 "PYTHONPATH": "src" + os.pathsep
                 + os.environ.get("PYTHONPATH", "")})
        if proc.returncode == 0:
            print(proc.stdout.strip())
        else:
            print(f"perf_probe,skipped,rc={proc.returncode}: "
                  f"{proc.stderr.strip().splitlines()[-1][:120] if proc.stderr.strip() else ''}")
    except subprocess.TimeoutExpired:
        print("perf_probe,skipped,timeout")

    _section("roofline (from dry-run artifacts)")
    try:
        from benchmarks import bench_roofline
        bench_roofline.main(["--mesh", "16x16"])
    except Exception as e:  # noqa: BLE001
        print(f"roofline,skipped,{e!r:.120}")

    print("\nall benchmarks done")


if __name__ == "__main__":
    main()
