"""Paper Fig 7: processing time, single node ("hobbit") vs grid-brick
parallel (GEPS), as a function of raw-event-file size.

The paper observed a watershed at ~2000 events on its fast-Ethernet
two-node grid: below it, the tightly-coupled single node wins (executable
staging + dispatch + result transfer dominate); above it, parallel brick
processing wins.  We reproduce with the virtual-time grid simulation
(REAL numpy compute per packet, modeled network/staging costs calibrated
to the paper's setup) and report the measured crossover.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel

EXPR = "e_total > 40 && count(pt > 15) >= 1"


def run(n_nodes: int = 2, sizes=(250, 500, 1000, 2000, 4000, 8000)):
    cfgE = reduced()
    schema = ev.EventSchema.from_config(cfgE)
    rows = []
    crossover = None
    prev = None
    for n_events in sizes:
        store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                             events_per_brick=max(64, n_events // 16),
                             replication=2, seed=1)
        cat = MetadataCatalog(n_nodes)
        jse = JobSubmissionEngine(cat, store, TimeModel())
        jid = jse.submit(EXPR)
        t0 = time.perf_counter()
        merged, stats = jse.run_job_simulated(jid)
        wall = time.perf_counter() - t0
        single = jse.single_node_time(n_events)
        rows.append({
            "n_events": n_events,
            "geps_parallel_s": stats.makespan_s,
            "single_node_s": single,
            "speedup": single / stats.makespan_s,
            "selected": merged.n_selected,
            "host_wall_s": wall,
        })
        if prev is not None and crossover is None:
            if rows[-1]["speedup"] >= 1.0 > prev["speedup"]:
                # linear interpolation between the two sizes
                x0, x1 = prev["n_events"], n_events
                y0, y1 = prev["speedup"], rows[-1]["speedup"]
                crossover = x0 + (1.0 - y0) * (x1 - x0) / (y1 - y0)
        prev = rows[-1]
    return rows, crossover


def main():
    import os
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    # smoke: fewer sizes (still spanning the watershed), no bound assert
    rows, crossover = run(sizes=(250, 1000, 2000, 4000)) if smoke else run()
    print("n_events,geps_parallel_s,single_node_s,speedup,selected")
    for r in rows:
        print(f"{r['n_events']},{r['geps_parallel_s']:.3f},"
              f"{r['single_node_s']:.3f},{r['speedup']:.3f},{r['selected']}")
    if crossover is not None:
        print(f"# crossover (watershed) ~ {crossover:.0f} events "
              f"(paper section 6: ~2000)")
    if not smoke:
        assert crossover is not None and 500 < crossover < 4000, crossover
    return rows


if __name__ == "__main__":
    main()
