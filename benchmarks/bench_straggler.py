"""Straggler mitigation (PROOF rule, paper related-work + future-work):
one node runs at 0.2x speed; compare makespan with fixed uniform packets
vs throughput-adaptive packets (slower slaves get smaller packets; the
fast nodes steal the remaining work)."""
from __future__ import annotations

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel

EXPR = "e_total > 40"


def run(adaptive: bool, straggler_speed=0.2, n_events=4096, n_nodes=4):
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                         events_per_brick=256, replication=2, seed=3)
    speeds = {n: 1.0 for n in range(n_nodes)}
    speeds[1] = straggler_speed
    cat = MetadataCatalog(n_nodes)
    for n, s in speeds.items():
        cat.node(n).throughput_ema = s
    jse = JobSubmissionEngine(cat, store, TimeModel(), node_speed=speeds,
                              adaptive_packets=adaptive)
    jid = jse.submit(EXPR)
    merged, stats = jse.run_job_simulated(jid)
    return stats.makespan_s, merged.n_selected


def main():
    import os
    n_ev = 1024 if os.environ.get("BENCH_SMOKE") == "1" else 4096
    fixed, sel_f = run(adaptive=False, n_events=n_ev)
    adap, sel_a = run(adaptive=True, n_events=n_ev)
    assert sel_f == sel_a, "mitigation must not change results"
    print("mode,makespan_s")
    print(f"fixed,{fixed:.3f}")
    print(f"adaptive,{adap:.3f}")
    print(f"# straggler mitigation speedup: {fixed / adap:.2f}x")
    return fixed, adap


if __name__ == "__main__":
    main()
