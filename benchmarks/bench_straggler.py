"""Straggler mitigation (PROOF rule, paper related-work + future-work):
one node runs at 0.2x speed; compare makespan with fixed uniform packets
vs throughput-adaptive packets (slower slaves get smaller packets; the
fast nodes steal the remaining work).

Each run executes with the observability plane attached, so the report
includes the per-packet virtual-latency histogram straight from the
metrics registry — the adaptive run's distribution visibly loses the
straggler's fat tail.

A second pass measures the failure policy's *speculative re-execution*
(``service/policy.py``): with fixed packets and an extreme straggler,
time-to-final (the virtual stamp of the LAST partial — honest in both
modes, unlike the default-path makespan which does not charge undelivered
tails) is compared with speculation on vs off over a straggler-speed
grid.  Outside smoke mode the p99 of the per-config ratio must be <=
0.7 and everything is committed as ``BENCH_straggler.json``."""
from __future__ import annotations

import json
import os
import pathlib

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel
from repro.obs import Observability

EXPR = "e_total > 40"
OUT = pathlib.Path(__file__).resolve().parent / "BENCH_straggler.json"


def run(adaptive: bool, straggler_speed=0.2, n_events=4096, n_nodes=4):
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                         events_per_brick=256, replication=2, seed=3)
    speeds = {n: 1.0 for n in range(n_nodes)}
    speeds[1] = straggler_speed
    cat = MetadataCatalog(n_nodes)
    for n, s in speeds.items():
        cat.node(n).throughput_ema = s
    jse = JobSubmissionEngine(cat, store, TimeModel(), node_speed=speeds,
                              adaptive_packets=adaptive)
    obs = Observability(origin="bench")
    jse.obs = obs
    jid = jse.submit(EXPR)
    merged, stats = jse.run_job_simulated(jid)
    return stats.makespan_s, merged.n_selected, obs


def packet_latency(obs):
    """Per-packet *virtual* latency histogram for the run, derived from
    the packet spans (the wall-clock ``packet.latency_s`` histogram also
    exists but measures this host, not the simulated grid).  Returns the
    registry histogram and the max latency."""
    durs = [r["t1_virtual"] - r["t0_virtual"]
            for r in obs.tracer.records() if r["name"] == "packet"]
    hist = obs.metrics.histogram("packet.latency_virtual_s")
    for d in durs:
        hist.observe(d)
    return hist, (max(durs) if durs else 0.0)


def run_speculative(speculate: bool, straggler_speed: float,
                    n_events=2048, n_nodes=4, seed=3):
    """Time-to-final for one fixed-packet run with an extreme straggler:
    the virtual stamp of the last delivered partial (comparable across
    speculation modes), plus the engine's speculation counters."""
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                         events_per_brick=256, replication=2, seed=seed)
    speeds = {n: 1.0 for n in range(n_nodes)}
    speeds[1] = straggler_speed
    cat = MetadataCatalog(n_nodes)
    jse = JobSubmissionEngine(cat, store, TimeModel(), node_speed=speeds,
                              adaptive_packets=False)
    jid = jse.submit(EXPR)
    stamps = []
    merged, stats = jse.run_job_batch_simulated(
        [jid], on_partial=lambda p: stamps.append(p.t_virtual),
        speculate=speculate)
    return max(stamps), merged[0].n_selected, stats


def speculation_grid(n_events):
    """Per-straggler-speed spec/no-spec time-to-final ratios (results
    asserted identical pairwise)."""
    # extreme stragglers (2-3.5% speed): speculation can only launch once
    # a fast node drains the queue, so its time-to-final floors at
    # drain + one duplicate — the win is the straggler tail ABOVE that
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    speeds = [0.03] if smoke else [0.02, 0.025, 0.03, 0.035]
    seeds = [3] if smoke else [3, 5, 11]
    rows = []
    for speed in speeds:
        for seed in seeds:
            plain, sel_p, _ = run_speculative(False, speed,
                                              n_events=n_events, seed=seed)
            spec, sel_s, stats = run_speculative(True, speed,
                                                 n_events=n_events,
                                                 seed=seed)
            assert sel_p == sel_s, "speculation must not change results"
            rows.append({"straggler_speed": speed, "seed": seed,
                         "time_to_final_s": round(plain, 4),
                         "time_to_final_spec_s": round(spec, 4),
                         "speculated": stats.speculated,
                         "spec_wins": stats.spec_wins,
                         "ratio": round(spec / plain, 4)})
    return rows


def main():
    n_ev = 1024 if os.environ.get("BENCH_SMOKE") == "1" else 4096
    fixed, sel_f, obs_f = run(adaptive=False, n_events=n_ev)
    adap, sel_a, obs_a = run(adaptive=True, n_events=n_ev)
    assert sel_f == sel_a, "mitigation must not change results"
    hist_f, max_f = packet_latency(obs_f)
    hist_a, max_a = packet_latency(obs_a)
    print("mode,makespan_s,packets,max_packet_latency_s")
    print(f"fixed,{fixed:.3f},{hist_f.count},{max_f:.3f}")
    print(f"adaptive,{adap:.3f},{hist_a.count},{max_a:.3f}")
    print(f"# straggler mitigation speedup: {fixed / adap:.2f}x")

    spec_rows = speculation_grid(min(n_ev, 2048))
    ratios = sorted(r["ratio"] for r in spec_rows)
    p99 = ratios[min(len(ratios) - 1, int(0.99 * len(ratios)))]
    print("speculation: straggler_speed,seed,time_to_final_s,"
          "with_speculation_s,ratio,wins")
    for r in spec_rows:
        print(f"spec,{r['straggler_speed']},{r['seed']},"
              f"{r['time_to_final_s']},{r['time_to_final_spec_s']},"
              f"{r['ratio']},{r['spec_wins']}")
    print(f"# speculative re-execution p99 time-to-final ratio: {p99:.3f}")
    if os.environ.get("BENCH_SMOKE") != "1":
        assert p99 <= 0.7, (
            f"speculation must cut p99 time-to-final to <=0.7x (got {p99})")
        OUT.write_text(json.dumps({
            "bench": "straggler",
            "config": {"n_events": n_ev, "n_nodes": 4,
                       "straggler_speed": 0.2, "expr": EXPR},
            "rows": {
                name: {"makespan_s": round(mk, 4),
                       "packet_latency_virtual_s": h.to_dict()}
                for name, mk, h in (("fixed", fixed, hist_f),
                                    ("adaptive", adap, hist_a))},
            "speedup": round(fixed / adap, 3),
            "speculation": {"rows": spec_rows,
                            "p99_ratio": round(p99, 4)},
        }, indent=2) + "\n")
        print(f"snapshot written: {OUT.name}")
    return fixed, adap


if __name__ == "__main__":
    main()
