"""Straggler mitigation (PROOF rule, paper related-work + future-work):
one node runs at 0.2x speed; compare makespan with fixed uniform packets
vs throughput-adaptive packets (slower slaves get smaller packets; the
fast nodes steal the remaining work).

Each run executes with the observability plane attached, so the report
includes the per-packet virtual-latency histogram straight from the
metrics registry — the adaptive run's distribution visibly loses the
straggler's fat tail.  Outside smoke mode the histograms and makespans
are committed as ``BENCH_straggler.json``."""
from __future__ import annotations

import json
import os
import pathlib

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel
from repro.obs import Observability

EXPR = "e_total > 40"
OUT = pathlib.Path(__file__).resolve().parent / "BENCH_straggler.json"


def run(adaptive: bool, straggler_speed=0.2, n_events=4096, n_nodes=4):
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                         events_per_brick=256, replication=2, seed=3)
    speeds = {n: 1.0 for n in range(n_nodes)}
    speeds[1] = straggler_speed
    cat = MetadataCatalog(n_nodes)
    for n, s in speeds.items():
        cat.node(n).throughput_ema = s
    jse = JobSubmissionEngine(cat, store, TimeModel(), node_speed=speeds,
                              adaptive_packets=adaptive)
    obs = Observability(origin="bench")
    jse.obs = obs
    jid = jse.submit(EXPR)
    merged, stats = jse.run_job_simulated(jid)
    return stats.makespan_s, merged.n_selected, obs


def packet_latency(obs):
    """Per-packet *virtual* latency histogram for the run, derived from
    the packet spans (the wall-clock ``packet.latency_s`` histogram also
    exists but measures this host, not the simulated grid).  Returns the
    registry histogram and the max latency."""
    durs = [r["t1_virtual"] - r["t0_virtual"]
            for r in obs.tracer.records() if r["name"] == "packet"]
    hist = obs.metrics.histogram("packet.latency_virtual_s")
    for d in durs:
        hist.observe(d)
    return hist, (max(durs) if durs else 0.0)


def main():
    n_ev = 1024 if os.environ.get("BENCH_SMOKE") == "1" else 4096
    fixed, sel_f, obs_f = run(adaptive=False, n_events=n_ev)
    adap, sel_a, obs_a = run(adaptive=True, n_events=n_ev)
    assert sel_f == sel_a, "mitigation must not change results"
    hist_f, max_f = packet_latency(obs_f)
    hist_a, max_a = packet_latency(obs_a)
    print("mode,makespan_s,packets,max_packet_latency_s")
    print(f"fixed,{fixed:.3f},{hist_f.count},{max_f:.3f}")
    print(f"adaptive,{adap:.3f},{hist_a.count},{max_a:.3f}")
    print(f"# straggler mitigation speedup: {fixed / adap:.2f}x")
    if os.environ.get("BENCH_SMOKE") != "1":
        OUT.write_text(json.dumps({
            "bench": "straggler",
            "config": {"n_events": n_ev, "n_nodes": 4,
                       "straggler_speed": 0.2, "expr": EXPR},
            "rows": {
                name: {"makespan_s": round(mk, 4),
                       "packet_latency_virtual_s": h.to_dict()}
                for name, mk, h in (("fixed", fixed, hist_f),
                                    ("adaptive", adap, hist_a))},
            "speedup": round(fixed / adap, 3),
        }, indent=2) + "\n")
        print(f"snapshot written: {OUT.name}")
    return fixed, adap


if __name__ == "__main__":
    main()
