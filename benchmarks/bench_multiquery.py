"""Multi-tenant shared-scan benchmark: queries/sec and events-scanned-
per-query for K concurrent queries, shared-scan coalescing vs. the
one-job-at-a-time baseline, plus the cache-hit path.

The claim under test (the DIAL/LHC interactive-analysis regime): at high
query concurrency the dominant cost is re-reading brick-resident events,
so coalescing K compatible queries into one sweep drops the per-query scan
volume ~K x, and a repeated query should return from the result cache with
ZERO brick I/O.
"""
from __future__ import annotations

import time

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine
from repro.service import QueryScheduler, QueryService

N_EVENTS = 2048
N_NODES = 4


def _store(schema, seed=11):
    return create_store(schema, n_events=N_EVENTS, n_nodes=N_NODES,
                        events_per_brick=128, replication=2, seed=seed)


def _exprs(k):
    # distinct thresholds -> distinct canonical queries (no dedup shortcut)
    return [f"e_total > {20 + i} && count(pt > {5 + i % 11}) >= 1"
            for i in range(k)]


def run_k(store, k):
    exprs = _exprs(k)

    # baseline: one job at a time, each its own full sweep
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    t0 = time.perf_counter()
    seq_scanned = seq_makespan = 0
    for e in exprs:
        _, st = jse.run_job_simulated(jse.submit(e))
        seq_scanned += st.events_scanned
        seq_makespan += st.makespan_s
    seq_wall = time.perf_counter() - t0

    # shared scan: all K coalesced into one sweep
    cat2 = MetadataCatalog(store.n_nodes)
    jse2 = JobSubmissionEngine(cat2, store)
    jids = [jse2.submit(e) for e in exprs]
    t0 = time.perf_counter()
    _, st2 = jse2.run_job_batch_simulated(jids)
    shared_wall = time.perf_counter() - t0

    return {
        "k": k,
        "seq_scanned_per_q": seq_scanned / k,
        "shared_scanned_per_q": st2.events_scanned / k,
        "seq_qps_wall": k / seq_wall,
        "shared_qps_wall": k / shared_wall,
        "seq_makespan_s": seq_makespan,
        "shared_makespan_s": st2.makespan_s,
    }


def run_cache(store):
    svc = QueryService(store, scheduler=QueryScheduler(max_batch=8))
    expr = "e_total > 40 && count(pt > 15) >= 2"
    svc.submit(expr, tenant="a")
    svc.drain()
    scanned_cold = svc.stats.events_scanned
    t0 = time.perf_counter()
    tid = svc.submit(expr, tenant="b")   # repeat -> served at submit time
    hit_wall = time.perf_counter() - t0
    ticket = svc.result(tid)
    assert ticket.from_cache, "repeat query must hit the cache"
    assert svc.stats.events_scanned == scanned_cold, \
        "cache hit must not scan any brick"
    return {"cold_scanned": scanned_cold, "hit_scanned": 0,
            "hit_wall_us": hit_wall * 1e6}


def main():
    import os
    schema = ev.EventSchema.from_config(reduced())
    store = _store(schema)
    # CI smoke mode trims the widest batch (the amortization invariant is
    # scale-free, so the asserts stay on)
    ks = (1, 8) if os.environ.get("BENCH_SMOKE") == "1" else (1, 8, 64)
    print("k,seq_scanned_per_q,shared_scanned_per_q,"
          "seq_qps_wall,shared_qps_wall,seq_makespan_s,shared_makespan_s")
    for k in ks:
        r = run_k(store, k)
        print(f"{r['k']},{r['seq_scanned_per_q']:.0f},"
              f"{r['shared_scanned_per_q']:.1f},{r['seq_qps_wall']:.1f},"
              f"{r['shared_qps_wall']:.1f},{r['seq_makespan_s']:.2f},"
              f"{r['shared_makespan_s']:.2f}")
        assert r["shared_scanned_per_q"] <= r["seq_scanned_per_q"] / k + 1, \
            "shared scan must amortize the sweep ~K x"

    c = run_cache(store)
    print(f"cache_hit,cold_scanned={c['cold_scanned']},"
          f"hit_scanned={c['hit_scanned']},"
          f"hit_wall={c['hit_wall_us']:.0f}us")


if __name__ == "__main__":
    main()
