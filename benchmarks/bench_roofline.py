"""Roofline table: derive compute/memory/collective terms for every
dry-run artifact (EXPERIMENTS.md section Roofline reads from this).

Usage: PYTHONPATH=src python -m benchmarks.bench_roofline [--mesh 16x16]
Writes experiments/roofline.csv + experiments/roofline.md and prints CSV.
"""
from __future__ import annotations

import argparse
import csv
import json
from pathlib import Path

from repro.analysis.roofline import ARTIFACT_DIR, analyze_cell

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments"

FIX_HINTS = {
    "compute": ("raise arithmetic intensity: bigger per-chip tiles "
                "(fewer microbatches) or drop remat recompute"),
    "memory": ("cut HBM traffic: bf16 attention intermediates / fused "
               "flash kernel keeps (Sq x C) tiles in VMEM"),
    "collective": ("overlap or shrink collectives: hierarchical in-pod "
                   "reduce-scatter first, int8 cross-pod merge"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--arch", default=None)
    args = ap.parse_args(argv)

    rows = []
    for path in sorted(ARTIFACT_DIR.glob(f"*__{args.mesh}.json")):
        arch, shape, mesh = path.stem.split("__")
        if args.arch and arch != args.arch:
            continue
        rec = json.loads(path.read_text())
        if "skipped" in rec:
            continue
        r = analyze_cell(arch, shape, mesh)
        rows.append(r)

    rows.sort(key=lambda r: (r.arch, r.shape))
    print("arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,"
          "dominant,useful_ratio,flops_per_chip,bytes_per_chip,"
          "collective_per_chip,model_flops")
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / f"roofline_{args.mesh}.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["arch", "shape", "mesh", "t_compute_ms", "t_memory_ms",
                    "t_collective_ms", "dominant", "useful_ratio",
                    "flops_per_chip", "bytes_per_chip",
                    "collective_per_chip", "model_flops", "fix_hint"])
        for r in rows:
            line = [r.arch, r.shape, r.mesh,
                    round(r.t_compute * 1e3, 2), round(r.t_memory * 1e3, 2),
                    round(r.t_collective * 1e3, 2), r.dominant,
                    round(r.useful_ratio, 3), f"{r.flops_per_chip:.4e}",
                    f"{r.bytes_per_chip:.4e}",
                    f"{r.collective_per_chip:.4e}",
                    f"{r.model_flops_total:.4e}", FIX_HINTS[r.dominant]]
            w.writerow(line)
            print(",".join(str(x) for x in line[:12]))

    with open(OUT_DIR / f"roofline_{args.mesh}.md", "w") as f:
        f.write("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) "
                "| dominant | MODEL/HLO flops |\n|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write(f"| {r.arch} | {r.shape} | {r.t_compute*1e3:.1f} | "
                    f"{r.t_memory*1e3:.1f} | {r.t_collective*1e3:.1f} | "
                    f"{r.dominant} | {r.useful_ratio:.2f} |\n")
    return rows


if __name__ == "__main__":
    main()
