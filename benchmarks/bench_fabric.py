"""Coherence-fabric benchmark: what the shared tier buys a fleet.

Three claims under test, on a fleet of 4 front-ends over one brick store:

1. **Fleet hit rate** — on a skewed multi-tenant workload (a hot pool of
   repeated queries spread round-robin over the fleet, plus a distinct
   long tail), the shared-L2 fleet's cache hit rate is STRICTLY above
   the same fleet with independent per-front-end caches: with
   independent caches every front-end pays its own cold miss for every
   hot query; with the shared tier only the first front-end does.

2. **Cross-front-end first-result latency** — a tenant asking front-end
   B for a query front-end A already answered gets its (streamed) final
   result immediately from the shared tier (zero scan latency on the
   virtual grid clock), where the independent-cache fleet re-runs the
   scan and the tenant waits for the first partial of a fresh sweep.

3. **Registry pre-warming** — with the persistent fragment registry
   seeding each window's planner, a conjunct that is hot ACROSS windows
   (but referenced only once per window) is materialized into the cache,
   so later whole-query submissions of it never scan; total per-brick
   fragment evaluations drop below per-window factoring alone.

4. **Single-flight execution** — on a near-duplicate workload (every
   window one canonical submitted at EVERY front-end — the duplicate
   the shared L2 cannot close, because same-window duplicates miss
   independently and each runs its own scan), scan-intent leases
   (``fabric/leases.py``) collapse the fleet to ONE scan per canonical:
   fleet-wide scanned events drop >= 3x against the no-lease fleet,
   every per-ticket final stays bit-identical, and the remote
   first-result latency is unchanged.

Plus the observability acceptance pass: the same workload replayed with
``Fleet(obs=True)`` must produce a schema-valid fleet trace (written as
Perfetto-loadable ``BENCH_fabric_trace.json`` outside smoke) whose
fleet-merged metric counters reconcile EXACTLY with the service-stats
aggregation (L1 + L2 hit counters vs ``fleet_stats``).

And the flight-recorder acceptance pass: arming ``Fleet(flight=True)``
on the obs workload must leave the virtual timeline EXACTLY unchanged
(bit-identical finals AND identical comparable trace records — every
virtual makespan included — vs the recorder-less run), and the recorded
log must replay bit-identically through ``repro.obs.replay``.

Run: ``PYTHONPATH=src python benchmarks/bench_fabric.py``
(writes a ``BENCH_fabric.json`` snapshot next to this file;
``BENCH_SMOKE=1`` shrinks sizes and skips the snapshot + perf asserts).
"""
from __future__ import annotations

import json
import os
import pathlib

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store
from repro.fabric import Fleet, FragmentRegistry
from repro.obs import trace as trace_lib
from repro.service import QueryService

OUT = pathlib.Path(__file__).resolve().parent / "BENCH_fabric.json"
TRACE_OUT = pathlib.Path(__file__).resolve().parent / \
    "BENCH_fabric_trace.json"

N_EVENTS = 4096
N_NODES = 8
EVENTS_PER_BRICK = 256
N_FRONTENDS = 4
N_TENANTS = 8
N_QUERIES = 96
WINDOW = 8

HOT_POOL = [
    "e_total > 40 && count(pt > 15) >= 2",
    "e_t_miss > 30",
    "pt_lead > 60 || n_tracks >= 8",
    "e_total > 55 && sum(pt) < 400",
    "count(pt > 25) >= 1",
    "e_total + 2 * e_t_miss > 120",
]


def smoke() -> bool:
    """True when the CI benchmark smoke job is running (tiny sizes, no
    snapshot writes, no perf asserts — bit-rot detection only)."""
    return os.environ.get("BENCH_SMOKE") == "1"


def skewed_workload(n: int):
    """(tenant, expr) pairs: ~2/3 draws from the hot pool (the
    interactive-analysis regime), the rest a distinct long tail."""
    out = []
    for i in range(n):
        tenant = f"tenant{i % N_TENANTS}"
        if i % 3 != 2:
            expr = HOT_POOL[(i * 7) % len(HOT_POOL)]
        else:
            expr = (f"e_total > {20 + (i % 13) * 5} && "
                    f"count(pt > 15) >= {1 + i % 3}")
        out.append((tenant, expr))
    return out


def run_fleet(store, *, shared_cache: bool) -> dict:
    """Replay the skewed workload over a fleet; returns aggregate stats."""
    fleet = Fleet(store, N_FRONTENDS, shared_cache=shared_cache)
    for i, (tenant, expr) in enumerate(skewed_workload(N_QUERIES)):
        fleet.submit(expr, tenant=tenant)  # round-robin over front-ends
        if (i + 1) % WINDOW == 0:
            fleet.step()
    fleet.drain()
    stats = fleet.fleet_stats()
    fleet.close()
    return stats


def run_obs_fleet(store) -> dict:
    """The skewed workload again with the observability plane ON: the
    fleet-merged metrics must reconcile exactly with ``fleet_stats``,
    the trace must schema-validate, and (outside smoke) the Chrome
    trace lands next to the snapshot, Perfetto-loadable."""
    fleet = Fleet(store, N_FRONTENDS, obs=True)
    for i, (tenant, expr) in enumerate(skewed_workload(N_QUERIES)):
        fleet.submit(expr, tenant=tenant)
        if (i + 1) % WINDOW == 0:
            fleet.step()
    fleet.drain()
    stats = fleet.fleet_stats()
    snap = fleet.metrics_snapshot()
    recs = fleet.trace_records()
    problems = trace_lib.validate_records(recs)
    assert not problems, f"fleet trace invalid: {problems[:5]}"
    l1, l2 = snap.value("cache.hits_l1"), snap.value("cache.hits_l2")
    assert l1 + l2 == stats["cache_hits"], \
        f"obs cache counters {l1}+{l2} != fleet_stats " \
        f"{stats['cache_hits']}"
    assert l2 == stats["l2_hits"], \
        f"obs L2 counter {l2} != fleet_stats {stats['l2_hits']}"
    assert snap.value("tickets.served") == stats["served"], \
        "obs tickets.served != fleet_stats served"
    out = {"trace_records": len(recs), "cache_hits_l1": l1,
           "cache_hits_l2": l2,
           "tickets_served": snap.value("tickets.served")}
    if not smoke():
        fleet.save_chrome_trace(TRACE_OUT)
        out["trace_file"] = TRACE_OUT.name
    fleet.close()
    return out


def run_flight_fleet(store) -> dict:
    """Recording must be free on the virtual clock: the obs workload
    with the flight recorder armed yields bit-identical finals and an
    IDENTICAL comparable trace (every virtual makespan included) vs the
    recorder-less run, and the log replays bit-identically."""
    from repro.obs import replay as replay_lib

    def one(flight: bool):
        fleet = Fleet(store, N_FRONTENDS, obs=True, flight=flight)
        gtids = []
        for i, (tenant, expr) in enumerate(skewed_workload(N_QUERIES)):
            gtids.append(fleet.submit(expr, tenant=tenant))
            if (i + 1) % WINDOW == 0:
                fleet.step()
        fleet.drain()
        results = [fleet.result(g).result for g in gtids]
        recs = trace_lib.comparable_records(fleet.trace_records())
        log = list(fleet.flight.records) if flight else None
        fleet.close()
        return results, recs, log

    res_on, trace_on, log = one(True)
    res_off, trace_off, _ = one(False)
    assert all(merge_lib.results_identical(a, b)
               for a, b in zip(res_on, res_off)), \
        "flight recording changed a final result"
    assert trace_on == trace_off, \
        "flight recording perturbed the virtual timeline"
    # this workload never mutates the store (no deaths/re-replication),
    # so replaying over the same store object is sound
    rep = replay_lib.replay_run(log, store=store)
    assert rep.identical, \
        f"replay diverged: {rep.mismatches[:3]} {rep.bus_divergences[:3]}"
    return {"flight_records": len(log), "finals": rep.n_finals,
            "replay_identical": rep.identical}


def near_duplicate_workload(windows: int):
    """One canonical per window, near-duplicates of each other (same
    structure, shifted cut) so no window hits a previous window's cache
    entry — every window is the same-window duplicate-scan race."""
    return [f"e_total > {30 + w} && count(pt > 15) >= 2"
            for w in range(windows)]


def run_single_flight(store, *, single_flight: bool):
    """The duplicate-work race at benchmark scale: every window's
    canonical is submitted at EVERY front-end simultaneously.  Returns
    (aggregate stats, per-ticket final results in submission order)."""
    windows = 4 if smoke() else 8
    fleet = Fleet(store, N_FRONTENDS, single_flight=single_flight)
    gtids = []
    for expr in near_duplicate_workload(windows):
        gtids.extend(fleet.submit(expr, tenant=f"tenant{i}", frontend=i)
                     for i in range(N_FRONTENDS))
        fleet.step()
    fleet.drain()
    results = [fleet.result(g).result for g in gtids]
    assert all(r is not None for r in results), "unserved duplicate ticket"
    stats = fleet.fleet_stats()
    fleet.close()
    return stats, results


def remote_first_result_latency(store, *, shared_cache: bool,
                                single_flight: bool = False) -> float:
    """Virtual-clock latency until a tenant at front-end 1 holds a final
    result for a query front-end 0 already answered."""
    fleet = Fleet(store, 2, shared_cache=shared_cache,
                  single_flight=single_flight)
    fleet.submit(HOT_POOL[0], tenant="a", frontend=0)
    fleet.drain()
    g = fleet.submit(HOT_POOL[0], tenant="b", frontend=1, stream=True)
    rs = fleet.stream(g)
    fleet.drain()
    snap = rs.latest()
    assert snap is not None and snap.final, "remote query never finished"
    fleet.close()
    return snap.t_virtual


def run_registry(store, *, use_registry: bool) -> dict:
    """Cross-window workload: a conjunct hot across windows (once per
    window), later submitted as a whole query.  Returns fragment-eval
    accounting."""
    registry = FragmentRegistry(hot_min_windows=2) if use_registry else None
    svc = QueryService(store, registry=registry)
    frag = "count(pt > 15) >= 2"
    windows = 4 if smoke() else 8
    for w in range(windows):
        svc.submit(f"e_total > {30 + w} && {frag}", tenant="a")
        svc.submit(f"e_t_miss > {10 + w}", tenant="b")
        svc.step()
        if w >= 2:  # after warmup, tenants start asking for the conjunct
            t = svc.submit(frag, tenant=f"c{w}")
            svc.drain()
            assert svc.result(t).status == "SERVED"
    out = {
        "fragment_evals": svc.stats.fragment_evals,
        "per_brick": svc.stats.fragment_evals / len(store.bricks),
        "events_scanned": svc.stats.events_scanned,
        "cache_hits": svc.stats.cache_hits,
    }
    svc.close()
    return out


def main():
    global N_EVENTS, N_QUERIES
    if smoke():
        N_EVENTS, N_QUERIES = 1024, 24
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=N_EVENTS, n_nodes=N_NODES,
                         events_per_brick=EVENTS_PER_BRICK,
                         replication=2, seed=17)
    print(f"workload: fleet of {N_FRONTENDS}, {N_QUERIES} queries / "
          f"{N_TENANTS} tenants (skewed), store {N_EVENTS} events / "
          f"{len(store.bricks)} bricks / {N_NODES} nodes")

    shared = run_fleet(store, shared_cache=True)
    indep = run_fleet(store, shared_cache=False)
    print("mode,hit_rate,cache_hits,l2_hits,events_scanned")
    print(f"shared_l2,{shared['hit_rate']:.3f},{shared['cache_hits']},"
          f"{shared['l2_hits']},{shared['events_scanned']}")
    print(f"independent,{indep['hit_rate']:.3f},{indep['cache_hits']},"
          f"{indep['l2_hits']},{indep['events_scanned']}")

    obs = run_obs_fleet(store)
    print(f"obs_fleet,trace_records={obs['trace_records']},"
          f"hits_l1={obs['cache_hits_l1']:.0f},"
          f"hits_l2={obs['cache_hits_l2']:.0f},"
          f"served={obs['tickets_served']:.0f},reconciled=exact")

    fl = run_flight_fleet(store)
    print(f"flight_fleet,records={fl['flight_records']},"
          f"finals={fl['finals']},virtual_makespan=unchanged,"
          f"replay_identical={fl['replay_identical']}")

    lat_shared = remote_first_result_latency(store, shared_cache=True)
    lat_indep = remote_first_result_latency(store, shared_cache=False)
    lat_single = remote_first_result_latency(store, shared_cache=True,
                                             single_flight=True)
    print(f"remote_first_result_s,shared={lat_shared:.3f},"
          f"independent={lat_indep:.3f},single_flight={lat_single:.3f}")

    sf, sf_results = run_single_flight(store, single_flight=True)
    nl, nl_results = run_single_flight(store, single_flight=False)
    reduction = nl["events_scanned"] / max(1, sf["events_scanned"])
    identical = all(merge_lib.results_identical(a, b)
                    for a, b in zip(sf_results, nl_results))
    print("single_flight,mode,events_scanned,adopted,fallbacks")
    print(f"single_flight,lease,{sf['events_scanned']},{sf['adopted']},"
          f"{sf['lease_fallbacks']}")
    print(f"single_flight,no_lease,{nl['events_scanned']},0,0")
    print(f"single_flight,scan_reduction={reduction:.2f}x,"
          f"finals_identical={identical}")
    assert identical, "adopted finals must be bit-identical to no-lease"

    reg = run_registry(store, use_registry=True)
    plain = run_registry(store, use_registry=False)
    print("registry,fragment_evals,per_brick,events_scanned,cache_hits")
    print(f"prewarmed,{reg['fragment_evals']},{reg['per_brick']:.0f},"
          f"{reg['events_scanned']},{reg['cache_hits']}")
    print(f"window_only,{plain['fragment_evals']},{plain['per_brick']:.0f},"
          f"{plain['events_scanned']},{plain['cache_hits']}")

    if not smoke():
        assert shared["hit_rate"] > indep["hit_rate"], \
            f"shared L2 hit rate {shared['hit_rate']:.3f} must beat " \
            f"independent {indep['hit_rate']:.3f}"
        assert lat_shared < lat_indep, \
            "shared tier must answer the remote tenant faster"
        assert reg["fragment_evals"] < plain["fragment_evals"], \
            "registry pre-warming must reduce per-brick fragment evals"
        assert reduction >= 3.0, \
            f"single-flight must cut fleet-wide scanned events >= 3x " \
            f"on the near-duplicate workload (got {reduction:.2f}x)"
        assert sf["adopted"] > 0, "no adoptions happened"
        assert lat_single == lat_shared, \
            f"single-flight must not change remote first-result " \
            f"latency ({lat_single:.3f}s vs {lat_shared:.3f}s)"
        OUT.write_text(json.dumps({
            "bench": "fabric",
            "config": {"n_events": N_EVENTS, "n_nodes": N_NODES,
                       "events_per_brick": EVENTS_PER_BRICK,
                       "n_frontends": N_FRONTENDS, "n_tenants": N_TENANTS,
                       "n_queries": N_QUERIES, "window": WINDOW,
                       "replication": 2},
            "fleet_hit_rate": {"shared_l2": shared,
                               "independent": indep},
            "remote_first_result_s": {"shared_l2": lat_shared,
                                      "independent": lat_indep,
                                      "single_flight": lat_single},
            "registry_prewarming": {"prewarmed": reg,
                                    "window_only": plain},
            "single_flight": {"lease": sf, "no_lease": nl,
                              "scan_reduction_x": reduction,
                              "finals_identical": identical},
        }, indent=2) + "\n")
        print(f"snapshot written: {OUT.name}")
        print(f"shared-L2 fleet hit rate {shared['hit_rate']:.3f} > "
              f"independent {indep['hit_rate']:.3f}; registry "
              f"{plain['fragment_evals'] / max(1, reg['fragment_evals']):.2f}x"
              f" fewer fragment evals: OK")


if __name__ == "__main__":
    main()
