"""Perf-iteration probe: lower one cell with config overrides and report
roofline terms + memory — the measure step of the hypothesis->change->
measure loop in EXPERIMENTS.md section Perf.

Usage:
  PYTHONPATH=src python -m benchmarks.perf_probe --arch grok-1-314b \
      --shape train_4k --set microbatches=4 remat_policy=dots
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

from repro.analysis import hlo_parse  # noqa: E402
from repro.analysis.flops import model_flops  # noqa: E402
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402
from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.dryrun import _mem_dict, lower_cell  # noqa: E402


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def probe(arch: str, shape_name: str, multi_pod: bool = False, **overrides):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    record, lowered, compiled = lower_cell(
        arch, shape_name, multi_pod=multi_pod, cfg_override=cfg)
    totals = hlo_parse.analyze(compiled.as_text())
    mem = record["memory"]
    shape = SHAPES[shape_name]
    mf = model_flops(cfg, shape)
    chips = 512 if multi_pod else 256
    out = {
        "arch": arch, "shape": shape_name,
        "overrides": overrides,
        "t_compute_ms": totals.flops / PEAK_FLOPS * 1e3,
        "t_memory_ms": totals.bytes / HBM_BW * 1e3,
        "t_collective_ms": totals.collective_bytes / ICI_BW * 1e3,
        "flops_per_chip": totals.flops,
        "bytes_per_chip": totals.bytes,
        "collective_per_chip": totals.collective_bytes,
        "collective_by_op": totals.collective_by_op,
        "useful_ratio": mf / max(1.0, totals.flops * chips),
        "args_gb": mem.get("argument_size_in_bytes", 0) / 1e9,
        "temp_gb": mem.get("temp_size_in_bytes", 0) / 1e9,
        "compile_s": record["compile_s"],
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()
    overrides = dict(parse_override(kv) for kv in args.set)
    out = probe(args.arch, args.shape, args.multi_pod, **overrides)
    coll = out.pop("collective_by_op")
    print(json.dumps(out, indent=2))
    print("collectives:", {k: f"{v:.3e}" for k, v in coll.items()})


if __name__ == "__main__":
    main()
