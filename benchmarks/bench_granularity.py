"""Paper section 6: "different granularities of event data will
dramatically affect the overall performance" — sweep the packet size and
report makespan on a heterogeneous 4-node grid.

Small packets: per-packet dispatch latency dominates.  Huge packets: load
imbalance dominates (one straggling packet holds the job).  The adaptive
scheduler should land near the hand-tuned optimum without tuning.
"""
from __future__ import annotations

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel

EXPR = "e_total > 40"
SPEEDS = {0: 1.0, 1: 1.0, 2: 0.5, 3: 2.0}  # heterogeneous nodes


def run_one(packet: int, adaptive: bool, n_events=4096, n_nodes=4):
    cfgE = reduced()
    schema = ev.EventSchema.from_config(cfgE)
    store = create_store(schema, n_events=n_events, n_nodes=n_nodes,
                         events_per_brick=256, replication=2, seed=2)
    cat = MetadataCatalog(n_nodes)
    for n, s in SPEEDS.items():
        cat.node(n).throughput_ema = s
    jse = JobSubmissionEngine(cat, store, TimeModel(), node_speed=SPEEDS,
                              adaptive_packets=adaptive)
    jid = jse.submit(EXPR)
    merged, stats = jse.run_job_simulated(jid)
    # patch scheduler base packet by re-running with the size
    return stats.makespan_s, merged.n_selected


def main():
    import os
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    n_ev = 1024 if smoke else 4096
    sizes = (8, 128, 512) if smoke else (8, 32, 128, 512, 2048)
    print("packet_size,adaptive,makespan_s")
    results = {}
    for packet in sizes:
        cfgE = reduced()
        schema = ev.EventSchema.from_config(cfgE)
        store = create_store(schema, n_events=n_ev, n_nodes=4,
                             events_per_brick=256, replication=2, seed=2)
        cat = MetadataCatalog(4)
        for n, s in SPEEDS.items():
            cat.node(n).throughput_ema = s
        jse = JobSubmissionEngine(cat, store, TimeModel(), node_speed=SPEEDS,
                                  adaptive_packets=False)
        jse_sched_packet = packet

        # monkey-level configuration: fixed packet size
        from repro.core.packets import AdaptivePacketScheduler
        orig_init = AdaptivePacketScheduler.__init__

        def patched(self, catalog, **kw):
            kw.update(base_packet=jse_sched_packet,
                      min_packet=jse_sched_packet,
                      max_packet=jse_sched_packet)
            orig_init(self, catalog, **kw)

        AdaptivePacketScheduler.__init__ = patched
        try:
            jid = jse.submit(EXPR)
            _, stats = jse.run_job_simulated(jid)
        finally:
            AdaptivePacketScheduler.__init__ = orig_init
        results[packet] = stats.makespan_s
        print(f"{packet},fixed,{stats.makespan_s:.3f}")

    # adaptive run
    store = create_store(
        ev.EventSchema.from_config(reduced()), n_events=n_ev, n_nodes=4,
        events_per_brick=256, replication=2, seed=2)
    cat = MetadataCatalog(4)
    for n, s in SPEEDS.items():
        cat.node(n).throughput_ema = s
    jse = JobSubmissionEngine(cat, store, TimeModel(), node_speed=SPEEDS,
                              adaptive_packets=True)
    jid = jse.submit(EXPR)
    _, stats = jse.run_job_simulated(jid)
    print(f"adaptive,adaptive,{stats.makespan_s:.3f}")
    best_fixed = min(results.values())
    print(f"# adaptive vs best fixed: {stats.makespan_s:.3f} vs "
          f"{best_fixed:.3f}")
    return results, stats.makespan_s


if __name__ == "__main__":
    main()
