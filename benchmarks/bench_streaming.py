"""Streaming result delivery benchmark: time-to-first-partial vs
time-to-final on a multi-brick workload.

The claim under test: with per-packet partial-merge streaming, a tenant
reads an exact progressive histogram long before the job completes —
time-to-first-partial must be <= 1/4 of time-to-final (both on the
simulated grid clock, the same clock as ``JobStats.makespan_s``) — and the
final streamed snapshot stays bit-identical to the batch JSE merge.

Two streaming-friendly sizings are measured: the PR 3 workaround (fixed
small packets — PROOF-adaptive sizing optimizes makespan by handing each
node ~1/(4·nodes) of the store up front, which is exactly wrong for
time-to-first-partial) and the stream-aware RAMP (PROOF-adaptive sizing
kept ON, with early packets capped small and growing geometrically —
``QueryService(stream_ramp=...)``).  The ramp must not regress
time-to-first-partial vs. the fixed workaround while retaining adaptive
sizing for the bulk of the scan.

Run: ``PYTHONPATH=src python benchmarks/bench_streaming.py``
(writes a ``BENCH_streaming.json`` snapshot next to this file;
``BENCH_SMOKE=1`` shrinks the store and skips asserts + the snapshot).
"""
from __future__ import annotations

import json
import os
import pathlib
import time

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine
from repro.core.merge import results_identical
from repro.service import QueryService

N_EVENTS = 32768
N_NODES = 8
EVENTS_PER_BRICK = 256
OUT = pathlib.Path(__file__).resolve().parent / "BENCH_streaming.json"

BATCH = ["e_total > 40 && count(pt > 15) >= 2",
         "e_total > 30 && count(pt > 15) >= 1",
         "e_t_miss > 25 && count(pt > 15) >= 2",
         "pt_lead > 60 || n_tracks >= 8",
         "e_total > 55 && sum(pt) < 400",
         "e_total > 35 && sum(pt) < 400",
         "e_t_miss > 40",
         "e_total + 2 * e_t_miss > 120"]


def smoke() -> bool:
    """True under the CI benchmark smoke job (tiny store, no asserts or
    snapshot writes — bit-rot detection only)."""
    return os.environ.get("BENCH_SMOKE") == "1"


def run_streamed(store, exprs, *, ramp=None):
    """One streamed shared-scan window; returns per-run metrics.

    ``ramp=None`` reproduces the PR 3 workaround (adaptive packets
    disabled, small fixed packets); an integer enables stream-aware
    sizing: adaptive packets stay ON and the service caps the streamed
    window's early packets at ``ramp`` events."""
    svc = QueryService(store, use_cache=False, stream_ramp=ramp)
    if ramp is None:
        svc.jse.adaptive_packets = False  # fixed packets: the workaround
    recorder = {"first": None, "snaps": 0}

    def record(snap):
        if recorder["first"] is None:
            recorder["first"] = snap.t_virtual
        recorder["snaps"] += 1

    tids = [svc.submit(e, tenant=f"t{i}", stream=True)
            for i, e in enumerate(exprs)]
    svc.stream(tids[0]).subscribe(record)
    t0 = time.perf_counter()
    svc.step()
    wall = time.perf_counter() - t0

    finals = [svc.stream(t).latest() for t in tids]
    assert all(f is not None and f.final for f in finals)
    t_final = finals[0].t_virtual
    return {
        "queries": len(exprs),
        "t_first_partial_s": round(recorder["first"], 4),
        "t_final_s": round(t_final, 4),
        "ratio": round(recorder["first"] / t_final, 4),
        "snapshots": recorder["snaps"],
        "coverage_complete": all(f.coverage.complete for f in finals),
        "wall_s": round(wall, 2),
    }, [f.result for f in finals]


def run_spmd_streamed(store, exprs, *, double_buffer):
    """The same streamed window on the SPMD kernel-split scan path, with
    host-side prefix merging either overlapped with device compute
    (``double_buffer=True``, the default) or strictly serialized.  Used
    to re-verify that double buffering never delays the first partial —
    its whole point is overlapping the merge with the NEXT chunk."""
    svc = QueryService(store, use_cache=False, backend="spmd",
                       backend_kwargs=dict(use_pallas=True,
                                           double_buffer=double_buffer,
                                           chunk_events=64))
    recorder = {"first": None, "snaps": 0}

    def record(snap):
        if recorder["first"] is None:
            recorder["first"] = snap.t_virtual
        recorder["snaps"] += 1

    tids = [svc.submit(e, tenant=f"t{i}", stream=True)
            for i, e in enumerate(exprs)]
    svc.stream(tids[0]).subscribe(record)
    t0 = time.perf_counter()
    svc.step()
    wall = time.perf_counter() - t0
    finals = [svc.stream(t).latest() for t in tids]
    assert all(f is not None and f.final for f in finals)
    t_final = finals[0].t_virtual
    return {
        "queries": len(exprs),
        "t_first_partial_s": round(recorder["first"], 4),
        "t_final_s": round(t_final, 4),
        "ratio": round(recorder["first"] / t_final, 4),
        "snapshots": recorder["snaps"],
        "wall_s": round(wall, 2),
    }, [f.result for f in finals]


def main():
    global N_EVENTS
    if smoke():
        N_EVENTS = 4096
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=N_EVENTS, n_nodes=N_NODES,
                         events_per_brick=EVENTS_PER_BRICK,
                         replication=2, seed=13)
    print(f"workload: {N_EVENTS} events / {len(store.bricks)} bricks / "
          f"{N_NODES} nodes")
    print("name,queries,t_first_partial_s,t_final_s,ratio,snapshots,wall_s")

    rows = {}
    finals = {}
    for name, exprs, ramp in (("single_query", BATCH[:1], None),
                              ("batch8", BATCH, None),
                              ("batch8_ramp", BATCH, 16)):
        row, merged = run_streamed(store, exprs, ramp=ramp)
        rows[name] = row
        finals[name] = merged
        print(f"{name},{row['queries']},{row['t_first_partial_s']},"
              f"{row['t_final_s']},{row['ratio']},{row['snapshots']},"
              f"{row['wall_s']}")

    if not smoke():
        for name, row in rows.items():
            assert row["ratio"] <= 0.25, \
                f"{name}: first partial at {row['ratio']:.2f}x of final " \
                f"(need <= 0.25)"
        print(f"time-to-first-partial <= 1/4 time-to-final: OK "
              f"(single {rows['single_query']['ratio']:.3f}, "
              f"batch {rows['batch8']['ratio']:.3f}, "
              f"ramp {rows['batch8_ramp']['ratio']:.3f})")
        # stream-aware ramp must not regress first-partial latency vs the
        # fixed-packet workaround (it keeps adaptive sizing for the bulk)
        assert (rows["batch8_ramp"]["t_first_partial_s"]
                <= rows["batch8"]["t_first_partial_s"] * 1.05), \
            "packet ramp regressed time-to-first-partial"
        print("stream-aware ramp: first partial "
              f"{rows['batch8_ramp']['t_first_partial_s']}s <= fixed "
              f"{rows['batch8']['t_first_partial_s']}s, OK")

    # SPMD double-buffer leg: overlapping the host-side prefix merge with
    # the next chunk's device compute must not delay the first partial
    # (warm the kernel dispatch once, then measure both modes)
    run_spmd_streamed(store, BATCH, double_buffer=True)
    for name, buf in (("spmd_unbuffered", False), ("spmd_buffered", True)):
        row, merged = run_spmd_streamed(store, BATCH, double_buffer=buf)
        rows[name] = row
        finals[name] = merged
        print(f"{name},{row['queries']},{row['t_first_partial_s']},"
              f"{row['t_final_s']},{row['ratio']},{row['snapshots']},"
              f"{row['wall_s']}")
    for got, ref in zip(finals["spmd_buffered"], finals["spmd_unbuffered"]):
        assert results_identical(got, ref), \
            "double buffering changed streamed finals"
    if not smoke():
        assert (rows["spmd_buffered"]["t_first_partial_s"]
                <= rows["spmd_unbuffered"]["t_first_partial_s"] * 1.25
                + 0.005), \
            "double buffering regressed SPMD time-to-first-partial"
        print("spmd double-buffer: first partial "
              f"{rows['spmd_buffered']['t_first_partial_s']}s vs "
              f"unbuffered {rows['spmd_unbuffered']['t_first_partial_s']}s "
              "(no regress), OK")

    # bit-identity spot check: streamed finals == an independent batch run
    # merging only at job end (same store, fixed packets)
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store, adaptive_packets=False)
    want, _ = jse.run_job_batch_simulated([jse.submit(e) for e in BATCH])
    for got, ref in zip(finals["batch8"], want):
        assert results_identical(got, ref), "streamed final diverged"
    print("bit-identity: streamed finals == batch JSE merge, OK")

    if not smoke():
        OUT.write_text(json.dumps({
            "bench": "streaming",
            "config": {"n_events": N_EVENTS, "n_nodes": N_NODES,
                       "events_per_brick": EVENTS_PER_BRICK,
                       "packet_events": 64, "ramp_start": 16,
                       "replication": 2},
            "rows": rows,
        }, indent=2) + "\n")
        print(f"snapshot written: {OUT.name}")


if __name__ == "__main__":
    main()
