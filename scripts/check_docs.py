#!/usr/bin/env python3
"""Docs sanity checker (run by the CI docs job).

- Fenced ``python`` blocks in README.md / docs/*.md / src/**/README.md
  must compile (syntax-valid snippets).
- Fenced ``bash`` blocks must shlex-parse line by line (no mangled
  commands in quickstarts).
- Relative markdown links must resolve to files in the repo.
- No ``*.pyc`` / ``__pycache__`` files may be tracked by git — checked
  against both the file list and the HEAD tree, so a committed
  ``__pycache__`` *directory* fails even if its files were filtered.
- ``benchmarks/__pycache__/`` must be gitignored (the bench runners
  drop bytecode next to the committed BENCH_*.json snapshots).
- Public-API doc coverage: every public module / class / function /
  method in ``src/repro/core``, ``src/repro/service``,
  ``src/repro/fabric`` and ``src/repro/obs`` must carry a docstring
  (the packages tenants program against stay documented).
- Contract coverage: every public top-level symbol of
  ``src/repro/core/backend.py`` and of the event_filter kernel surface
  (``src/repro/kernels/event_filter/{ops,tune}.py``) must be mentioned
  by name in ``docs/backends.md``, every public top-level symbol of the
  ``src/repro/obs`` modules in ``docs/observability.md``, and every
  public top-level symbol of ``src/repro/service/policy.py`` in
  ``docs/policy.md`` — adding an API without documenting the contract
  fails CI.

Exits non-zero with a per-finding report on any violation.
"""
from __future__ import annotations

import ast
import pathlib
import re
import shlex
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
FENCE_RE = re.compile(r"^```(\w*)\s*$")
LINK_RE = re.compile(r"\[[^\]]+\]\(([^)#]+)(?:#[^)]*)?\)")
API_PACKAGES = ("src/repro/core", "src/repro/service", "src/repro/fabric",
                "src/repro/obs")


def doc_files():
    out = [ROOT / "README.md"]
    out += sorted((ROOT / "docs").glob("*.md"))
    out += sorted((ROOT / "src").rglob("README.md"))
    return [p for p in out if p.exists()]


def fenced_blocks(text):
    """Yield (language, start_line, block_text) for each fenced block."""
    lang, start, buf = None, 0, []
    for i, line in enumerate(text.splitlines(), 1):
        m = FENCE_RE.match(line.strip())
        if m and lang is None:
            lang, start, buf = m.group(1) or "text", i, []
        elif line.strip() == "```" and lang is not None:
            yield lang, start, "\n".join(buf)
            lang = None
        elif lang is not None:
            buf.append(line)


def check_file(path):
    errors = []
    text = path.read_text()
    rel = path.relative_to(ROOT)
    for lang, line, block in fenced_blocks(text):
        if lang == "python":
            try:
                compile(block, f"{rel}:{line}", "exec")
            except SyntaxError as e:
                errors.append(f"{rel}:{line}: python block fails to "
                              f"compile: {e}")
        elif lang in ("bash", "sh", "shell"):
            for off, cmd in enumerate(block.splitlines()):
                cmd = cmd.strip()
                if not cmd or cmd.startswith("#"):
                    continue
                try:
                    shlex.split(cmd.rstrip("\\"))
                except ValueError as e:
                    errors.append(f"{rel}:{line + off}: bash line does "
                                  f"not parse: {e}")
    for m in LINK_RE.finditer(text):
        target = m.group(1).strip()
        if "://" in target or target.startswith("mailto:"):
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{rel}: broken relative link: {target}")
    return errors


def check_api_docs():
    """Undocumented public symbols in the API packages (see module doc).

    Public = not underscore-prefixed; covered: the module itself,
    top-level classes and functions, and methods of public classes."""
    errors = []
    for pkg in API_PACKAGES:
        for path in sorted((ROOT / pkg).glob("*.py")):
            rel = path.relative_to(ROOT)
            tree = ast.parse(path.read_text())
            if not ast.get_docstring(tree):
                errors.append(f"{rel}:1: public module lacks a docstring")
            for node in tree.body:
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef,
                                         ast.ClassDef)):
                    continue
                if node.name.startswith("_"):
                    continue
                if not ast.get_docstring(node):
                    errors.append(f"{rel}:{node.lineno}: public "
                                  f"{node.name!r} lacks a docstring")
                if isinstance(node, ast.ClassDef):
                    for sub in node.body:
                        if (isinstance(sub, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))
                                and not sub.name.startswith("_")
                                and not ast.get_docstring(sub)):
                            errors.append(
                                f"{rel}:{sub.lineno}: public method "
                                f"{node.name}.{sub.name} lacks a docstring")
    return errors


def _contract_doc_errors(sources, doc_rel):
    """Every public top-level name (classes, functions, UPPERCASE
    constants) in ``sources`` must appear in the contract doc
    ``doc_rel``."""
    doc = ROOT / doc_rel
    if not doc.exists():
        return [f"contract doc {doc_rel} is missing"]
    text = doc.read_text()
    errors = []
    for src in sources:
        for node in ast.parse(src.read_text()).body:
            names = []
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names = [node.name]
            elif isinstance(node, ast.Assign):
                names = [t.id for t in node.targets
                         if isinstance(t, ast.Name) and t.id.isupper()]
            for name in names:
                if name.startswith("_"):
                    continue
                if not re.search(rf"\b{re.escape(name)}\b", text):
                    errors.append(
                        f"{doc_rel}: public symbol {name!r} "
                        f"({src.relative_to(ROOT)}) is undocumented in "
                        f"the contract doc")
    return errors


def check_backend_contract_doc():
    """Every public top-level name in core/backend.py — plus the
    event_filter kernel surface the SPMD backend programs against
    (ops.py recognizers/kernel entry points, tune.py autotuner) — must
    appear in docs/backends.md (see module docstring)."""
    return _contract_doc_errors(
        [ROOT / "src/repro/core/backend.py",
         ROOT / "src/repro/kernels/event_filter/ops.py",
         ROOT / "src/repro/kernels/event_filter/tune.py"],
        "docs/backends.md")


def check_policy_contract_doc():
    """Every public top-level name in service/policy.py must appear in
    docs/policy.md (state machine, thresholds and decision surface stay
    in sync with the code)."""
    return _contract_doc_errors([ROOT / "src/repro/service/policy.py"],
                                "docs/policy.md")


def check_lease_contract_doc():
    """Every public top-level name in fabric/leases.py must appear in
    docs/fabric.md (single-flight lease lifecycle, TTL/failover
    semantics and the adoption surface stay in sync with the code)."""
    return _contract_doc_errors([ROOT / "src/repro/fabric/leases.py"],
                                "docs/fabric.md")


def check_obs_contract_doc():
    """Every public top-level name of the observability package must
    appear in docs/observability.md (span taxonomy / metric catalog /
    health semantics stay in sync with the code)."""
    return _contract_doc_errors(
        sorted((ROOT / "src/repro/obs").glob("*.py")),
        "docs/observability.md")


def check_benchmark_hygiene():
    """``benchmarks/__pycache__/`` must be covered by .gitignore (the
    bench runners import ``benchmarks`` as a package, so running them
    drops bytecode next to the committed BENCH_*.json snapshots — an
    unignored cache dir shows up in every ``git status`` and invites a
    committed-bytecode regression)."""
    probe = subprocess.run(
        ["git", "check-ignore", "-q", "benchmarks/__pycache__/x.pyc"],
        cwd=ROOT, check=False)
    if probe.returncode != 0:
        return ["benchmarks/__pycache__/ is not gitignored "
                "(add it to .gitignore)"]
    return []


def check_no_tracked_pyc():
    """No bytecode in git: neither tracked ``*.pyc``/``__pycache__``
    files, nor a committed ``__pycache__`` directory in the HEAD tree
    (``ls-tree -rd`` sees tree entries that ``ls-files`` can miss)."""
    out = subprocess.run(["git", "ls-files"], cwd=ROOT, check=True,
                         capture_output=True, text=True).stdout
    bad = [f for f in out.splitlines()
           if f.endswith(".pyc") or "__pycache__" in f]
    errors = [f"tracked bytecode must not be committed: {f}" for f in bad]
    tree = subprocess.run(["git", "ls-tree", "-rd", "--name-only", "HEAD"],
                          cwd=ROOT, check=False,
                          capture_output=True, text=True).stdout
    errors += [f"committed __pycache__ directory: {d}"
               for d in tree.splitlines() if d.endswith("__pycache__")]
    return errors


def main() -> int:
    errors = []
    for path in doc_files():
        errors += check_file(path)
    errors += check_no_tracked_pyc()
    errors += check_benchmark_hygiene()
    errors += check_api_docs()
    errors += check_backend_contract_doc()
    errors += check_policy_contract_doc()
    errors += check_lease_contract_doc()
    errors += check_obs_contract_doc()
    if errors:
        print(f"check_docs: {len(errors)} problem(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_docs: OK ({len(doc_files())} docs checked, "
          f"no tracked bytecode, public API of "
          f"{'+'.join(p.split('/')[-1] for p in API_PACKAGES)} documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
