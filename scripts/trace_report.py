#!/usr/bin/env python3
"""Trace analyzer: per-ticket latency breakdown from a JSONL span trace.

Reads a trace written by ``serve.py --trace-out out.jsonl`` (or any
:mod:`repro.obs.trace` JSONL export), schema-validates it, and prints

- the per-ticket latency breakdown — for every ticket, time (virtual
  seconds) from submit to final, split by phase (queue wait, plan share,
  scan/dispatch, stream delivery) plus the outcome, cache tier and the
  adopting owner when the ticket was served by lease adoption;
- a fleet-events section counting the failure-policy and single-flight
  vocabulary per front-end: ``policy_transition`` (by edge),
  ``rereplicate`` (copies), ``lease_adopt`` and ``lease_fallback``;
- the top-N slowest packets with their grid node, brick and size (the
  straggler view the paper's operators would start from).

Lease-export streams stamp their string lease key as the ``ticket`` of
``stream_partial`` events; those rows sort after integer tickets and
are otherwise reported verbatim.

Usage::

    python scripts/trace_report.py trace.jsonl [--top 10] [--tickets 20]

Exits non-zero when the trace fails schema validation (leaked open
spans, dangling parents, bad fields) so CI can gate on it.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from collections import defaultdict

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import trace as trace_lib  # noqa: E402


def span_dur(rec) -> float:
    t1 = rec.get("t1_virtual")
    return 0.0 if t1 is None else max(0.0, float(t1) - rec["t0_virtual"])


def ticket_breakdown(records):
    """Per-ticket phase timings: submit span, the window that served it,
    and its final event, keyed off the span taxonomy."""
    by_ticket = defaultdict(dict)
    windows = {}  # (process, span_id) -> window record
    children = defaultdict(list)  # (process, parent_id) -> records
    for rec in records:
        if rec["parent_id"] is not None:
            children[(rec["process"], rec["parent_id"])].append(rec)
        if rec["name"] == "window":
            windows[(rec["process"], rec["span_id"])] = rec
    for rec in records:
        t = rec["ticket"]
        if t is None:
            continue
        # ticket ids are per-front-end, so key on (process, ticket)
        info = by_ticket[(rec["process"], t)]
        if rec["name"] == "submit":
            info["submit"] = rec
        elif rec["name"] == "final":
            info["final"] = rec
        elif rec["name"] == "stream":
            info["stream"] = rec
        elif rec["name"] == "lease_adopt":
            info["adopt"] = rec
    rows = []
    # ticket keys may mix ints and lease-key strings: ints sort first
    order = lambda kv: (kv[0][0], isinstance(kv[0][1], str), str(kv[0][1]))
    for (_, t), info in sorted(by_ticket.items(), key=order):
        sub, fin = info.get("submit"), info.get("final")
        if sub is None:
            continue
        adopt = info.get("adopt")
        row = {
            "ticket": t,
            "process": sub["process"],
            "status": sub["status"],
            "cache_tier": sub["attrs"].get("cache_tier", "-"),
            "adopted_from": ("-" if adopt is None
                             else str(adopt["attrs"].get("owner", "?"))),
            "outcome": (fin or {}).get("attrs", {}).get("outcome", "-"),
            "submit_t": sub["t0_virtual"],
            "final_t": None if fin is None else fin["t0_virtual"],
            "total": None,
            "queue_wait": None,
            "plan": 0.0,
            "scan": 0.0,
        }
        if fin is not None:
            row["total"] = max(0.0, fin["t0_virtual"] - sub["t0_virtual"])
            batch = fin["attrs"].get("batch")
            # find the window that served this ticket and split its time
            for (proc, _), w in windows.items():
                if proc != sub["process"] or \
                        w["attrs"].get("batch") != batch or batch is None:
                    continue
                row["queue_wait"] = max(
                    0.0, w["t0_virtual"] - sub["t0_virtual"])
                for kid in children[(proc, w["span_id"])]:
                    if kid["name"] == "plan":
                        row["plan"] += span_dur(kid)
                    elif kid["name"] == "dispatch":
                        row["scan"] += span_dur(kid)
                break
        rows.append(row)
    return rows


def slowest_packets(records, top):
    pkts = [r for r in records if r["name"] == "packet"]
    pkts.sort(key=span_dur, reverse=True)
    return pkts[:top]


def fleet_events(records):
    """Per-process counts of the failure-policy / single-flight events:
    ``policy_transition`` edges, ``rereplicate`` copy totals, and lease
    adoption/fallback occurrences."""
    counts = defaultdict(lambda: defaultdict(int))
    for rec in records:
        name, a = rec["name"], rec.get("attrs", {})
        if name == "policy_transition":
            counts[rec["process"]][
                f"policy {a.get('old')}->{a.get('new')}"] += 1
        elif name == "rereplicate":
            counts[rec["process"]]["rereplicate copies"] += int(
                a.get("copies", 0))
        elif name == "lease_adopt":
            counts[rec["process"]]["lease adopts"] += 1
        elif name == "lease_fallback":
            counts[rec["process"]]["lease fallbacks"] += 1
    return counts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="JSONL trace file (serve.py --trace-out)")
    ap.add_argument("--top", type=int, default=10,
                    help="slowest packets to show")
    ap.add_argument("--tickets", type=int, default=20,
                    help="max tickets to list")
    args = ap.parse_args(argv)

    records = trace_lib.load_jsonl(args.trace)
    problems = trace_lib.validate_records(records)
    if problems:
        print(f"TRACE INVALID: {len(problems)} problem(s)")
        for p in problems[:20]:
            print("  -", p)
        return 1
    print(f"{args.trace}: {len(records)} records, schema ok")

    rows = ticket_breakdown(records)
    print(f"\nper-ticket latency (virtual seconds), "
          f"{min(len(rows), args.tickets)}/{len(rows)} tickets:")
    hdr = (f"{'ticket':>6} {'fe':>5} {'outcome':>8} {'tier':>4} "
           f"{'adopt':>6} {'total':>9} {'queued':>9} {'plan':>9} "
           f"{'scan':>9}")
    print(hdr)
    for row in rows[:args.tickets]:
        fmt = lambda v: "-" if v is None else f"{v:9.4f}"
        print(f"{str(row['ticket']):>6} {row['process']:>5} "
              f"{row['outcome']:>8} {row['cache_tier']:>4} "
              f"{row['adopted_from']:>6} "
              f"{fmt(row['total']):>9} {fmt(row['queue_wait']):>9} "
              f"{row['plan']:9.4f} {row['scan']:9.4f}")

    events = fleet_events(records)
    if events:
        print("\nfleet events (policy / leases):")
        for proc in sorted(events):
            for what in sorted(events[proc]):
                print(f"  {proc:>5} {what}: {events[proc][what]}")

    pkts = slowest_packets(records, args.top)
    if pkts:
        print(f"\ntop {len(pkts)} slowest packets:")
        print(f"{'dur_s':>9} {'fe':>5} {'node':>5} {'brick':>6} "
              f"{'events':>7}")
        for p in pkts:
            a = p["attrs"]
            print(f"{span_dur(p):9.4f} {p['process']:>5} "
                  f"{a.get('node', '-'):>5} {a.get('brick', '-'):>6} "
                  f"{a.get('size', '-'):>7}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
