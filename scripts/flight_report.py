#!/usr/bin/env python3
"""Divergence triage: diff two recorded runs and name the first causal
divergence.

Compares two JSONL logs record-by-record and, for the first index where
they differ, prints both records plus each side's ancestry chain (the
``cause`` links back to the driver op that started it) — the operator's
answer to "where did these two runs stop being the same run".

Two input formats, auto-detected per file:

- **flight logs** (``serve.py --flight-out`` / ``FlightRecorder``):
  records carry ``eid``/``kind``/``cause``; compared verbatim (the logs
  are deterministic, so any byte difference is a real divergence);
- **span traces** (``serve.py --trace-out *.jsonl``): compared through
  :func:`repro.obs.trace.comparable_records`, which strips wall-clock
  stamps first.

Usage::

    python scripts/flight_report.py run_a.jsonl run_b.jsonl [--context 3]

Exit status: 0 when the logs are equivalent, 1 when they diverge.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import flight as flight_lib  # noqa: E402
from repro.obs import trace as trace_lib  # noqa: E402


def is_flight(records) -> bool:
    """Flight logs carry ``eid``; span traces carry ``span_id``."""
    return bool(records) and "eid" in records[0]


def canon(rec) -> str:
    return json.dumps(rec, sort_keys=True)


def ancestry(records, eid, limit=10):
    """The cause chain of record ``eid``: itself, its cause, its cause's
    cause ... up to the root driver op."""
    chain = []
    while eid is not None and len(chain) < limit:
        rec = records[eid]
        chain.append(rec)
        eid = rec.get("cause")
    return chain


def brief(rec) -> str:
    """One-line rendering of a flight record."""
    skip = ("schema", "eid", "kind", "origin", "cause")
    fields = ", ".join(f"{k}={rec[k]!r}" for k in rec if k not in skip)
    return (f"eid {rec['eid']:>5} {rec['kind']:<16} "
            f"[{rec.get('origin', '')}] {fields}")


# fleet/store configuration, not run behaviour: differences here are
# reported as notes, and the divergence search targets the events after
CONFIG_KINDS = ("run_header", "store_config")


def first_divergence(a, b, skip_config=False):
    """Index of the first differing record pair, or None when one log is
    a prefix of the other (the index past the prefix) or they match.
    ``skip_config`` ignores pairs where both sides are config records
    (reported separately by the caller)."""
    n = min(len(a), len(b))
    for i in range(n):
        if skip_config and a[i].get("kind") in CONFIG_KINDS \
                and b[i].get("kind") in CONFIG_KINDS:
            continue
        if canon(a[i]) != canon(b[i]):
            return i
    return None if len(a) == len(b) else n


def config_diffs(a, b):
    """Field-level differences between the two logs' config records."""
    ca = {r["kind"]: r for r in a if r.get("kind") in CONFIG_KINDS}
    cb = {r["kind"]: r for r in b if r.get("kind") in CONFIG_KINDS}
    diffs = []
    for kind in sorted(set(ca) | set(cb)):
        ra, rb = ca.get(kind, {}), cb.get(kind, {})
        for key in sorted(set(ra) | set(rb)):
            if key in ("eid", "cause") or ra.get(key) == rb.get(key):
                continue
            diffs.append(f"{kind}.{key}: "
                         f"{ra.get(key)!r} vs {rb.get(key)!r}")
    return diffs


def report_flight(a, b, name_a, name_b, context):
    for name, recs in ((name_a, a), (name_b, b)):
        problems = flight_lib.validate_flight(recs)
        if problems:
            print(f"{name}: INVALID flight log ({problems[0]})")
            return 1
    cfg = config_diffs(a, b)
    for d in cfg:
        print(f"config differs: {d}")
    i = first_divergence(a, b, skip_config=True)
    if i is None:
        if cfg:
            print(f"events identical despite config differences: "
                  f"{len(a)} records")
            return 1
        print(f"logs identical: {len(a)} records")
        return 0
    print(f"first divergent event at record {i} "
          f"({len(a)} vs {len(b)} records):")
    for name, recs in ((name_a, a), (name_b, b)):
        print(f"\n  {name}:")
        if i >= len(recs):
            print("    <log ends here>")
            continue
        for rec in recs[max(0, i - context):i]:
            print(f"    {brief(rec)}")
        print(f"  > {brief(recs[i])}")
        chain = ancestry(recs, recs[i]["eid"])
        if len(chain) > 1:
            arrow = " <- ".join(
                f"{r['kind']}({r['eid']})" for r in chain)
            print(f"    ancestry: {arrow}")
    return 1


def report_trace(a, b, name_a, name_b, context):
    ca = trace_lib.comparable_records(a)
    cb = trace_lib.comparable_records(b)
    i = first_divergence(ca, cb)
    if i is None:
        print(f"traces equivalent: {len(ca)} comparable records")
        return 0
    print(f"first divergence at comparable record {i} "
          f"({len(ca)} vs {len(cb)} records):")
    for name, recs in ((name_a, ca), (name_b, cb)):
        print(f"\n  {name}:")
        if i >= len(recs):
            print("    <trace ends here>")
            continue
        for rec in recs[max(0, i - context):i + 1]:
            mark = ">" if rec is recs[i] else " "
            print(f"  {mark} span {rec['span_id']:>5} "
                  f"{rec['name']:<16} [{rec['process']}] "
                  f"ticket={rec['ticket']!r} status={rec['status']} "
                  f"attrs={rec['attrs']!r}")
    return 1


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Diff two flight logs (or span traces) and name the "
                    "first causal divergence.")
    ap.add_argument("log_a", help="first JSONL log")
    ap.add_argument("log_b", help="second JSONL log")
    ap.add_argument("--context", type=int, default=3,
                    help="matching records to show before the divergence")
    args = ap.parse_args(argv)

    a = flight_lib.load_flight(args.log_a)
    b = flight_lib.load_flight(args.log_b)
    fa, fb = is_flight(a), is_flight(b)
    if fa != fb:
        print("cannot compare a flight log against a span trace")
        return 2
    if fa:
        return report_flight(a, b, args.log_a, args.log_b, args.context)
    return report_trace(a, b, args.log_a, args.log_b, args.context)


if __name__ == "__main__":
    sys.exit(main())
