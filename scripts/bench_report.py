#!/usr/bin/env python3
"""Bench-trajectory observatory: the committed benchmark snapshots
(``benchmarks/BENCH_*.json``) across git history, as one table with
per-metric regression gates.

Each benchmark writes a JSON snapshot that gets committed alongside the
code change that produced it, so the repository's own history IS the
performance trajectory.  This tool replays that history (``git log`` /
``git show`` per snapshot file, plus the working-tree copy when it
differs), extracts the gated metrics, and

- prints the trajectory table: one row per gated metric, one column per
  version (short commit hash, ``work`` for the dirty working tree);
- with ``--check``, compares the newest version of every metric against
  the previous one and exits non-zero when any metric regressed past
  its tolerance — the CI bench-trajectory step.

Gates live in :data:`GATES`: dotted JSON path, direction, and relative
tolerance.  A metric missing from an old snapshot (added later) is
shown as ``-`` and never fails the check.  Stdlib + git only — runs in
the docs/CI environment with no scientific stack.

Usage::

    python scripts/bench_report.py [--check] [--repo PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LOWER, HIGHER = "lower", "higher"


@dataclasses.dataclass(frozen=True)
class Gate:
    """One gated benchmark metric: where it lives (snapshot file +
    dotted JSON path), which direction is better, and how much relative
    movement the wrong way ``--check`` tolerates."""
    file: str        # snapshot name under benchmarks/
    path: str        # dotted path into the JSON (e.g. "rows.sim.ratio")
    better: str      # LOWER or HIGHER is better
    rel_tol: float   # allowed relative regression before --check fails


#: The regression surface: the headline metric of every benchmark.
GATES = (
    Gate("BENCH_backend.json", "rows.sim.ratio", LOWER, 0.25),
    Gate("BENCH_backend.json", "rows.spmd.ratio", LOWER, 0.25),
    Gate("BENCH_backend.json", "rows.spmd_ramp.ratio", LOWER, 0.25),
    # perf pass: mesh-sharded scan scaling (lockstep critical-path
    # speedup over D=1), the block-shape autotune's margin over the
    # fixed default, and the winner's achieved memory bandwidth
    Gate("BENCH_backend.json", "scaling.mesh2.speedup_vs_1", HIGHER, 0.10),
    Gate("BENCH_backend.json", "scaling.mesh4.speedup_vs_1", HIGHER, 0.10),
    Gate("BENCH_backend.json", "autotune.speedup_vs_default", HIGHER, 0.05),
    Gate("BENCH_backend.json", "autotune.roofline.gbytes_per_s",
         HIGHER, 0.50),
    Gate("BENCH_streaming.json", "rows.single_query.ratio", LOWER, 0.25),
    Gate("BENCH_streaming.json", "rows.batch8.ratio", LOWER, 0.25),
    Gate("BENCH_streaming.json", "rows.batch8_ramp.ratio", LOWER, 0.25),
    Gate("BENCH_fabric.json", "fleet_hit_rate.shared_l2.hit_rate",
         HIGHER, 0.05),
    Gate("BENCH_fabric.json", "single_flight.scan_reduction_x",
         HIGHER, 0.05),
    Gate("BENCH_straggler.json", "speedup", HIGHER, 0.10),
    Gate("BENCH_straggler.json", "rows.adaptive.makespan_s", LOWER, 0.10),
    Gate("BENCH_straggler.json", "speculation.p99_ratio", LOWER, 0.25),
)


def dig(doc, dotted):
    """Navigate a dotted path into nested dicts; None when absent."""
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def _git(repo, *args):
    return subprocess.run(["git", "-C", str(repo), *args],
                          capture_output=True, text=True)


def snapshot_versions(repo, relpath):
    """Every historical version of one snapshot file, oldest first:
    ``[(label, parsed_json), ...]`` — one entry per commit touching it,
    plus a ``work`` entry when the working tree differs from HEAD's."""
    out = []
    log = _git(repo, "log", "--reverse", "--format=%h", "--", relpath)
    hashes = [h for h in log.stdout.split() if h]
    last_blob = None
    for h in hashes:
        show = _git(repo, "show", f"{h}:{relpath}")
        if show.returncode != 0:
            continue  # deleted in this commit
        try:
            out.append((h, json.loads(show.stdout)))
            last_blob = show.stdout
        except ValueError:
            continue
    worktree = pathlib.Path(repo) / relpath
    if worktree.exists():
        text = worktree.read_text()
        if last_blob is None or text != last_blob:
            try:
                out.append(("work", json.loads(text)))
            except ValueError:
                pass
    return out


def trajectory(repo):
    """``{snapshot file: [(label, doc), ...]}`` for every gated file."""
    files = sorted({g.file for g in GATES})
    return {f: snapshot_versions(repo, f"benchmarks/{f}") for f in files}


def check_gate(gate, values):
    """The gate verdict over its value trajectory: ``(ok, message)``.
    Compares the last two present values; absent history passes."""
    present = [(label, v) for label, v in values if v is not None]
    if len(present) < 2:
        return True, "no history"
    (l0, v0), (l1, v1) = present[-2], present[-1]
    if v0 == 0:
        return True, "zero baseline"
    rel = (v1 - v0) / abs(v0)
    worse = rel > gate.rel_tol if gate.better == LOWER \
        else -rel > gate.rel_tol
    msg = (f"{l0}={v0:g} -> {l1}={v1:g} ({rel:+.1%}, "
           f"{gate.better} is better, tol {gate.rel_tol:.0%})")
    return not worse, msg


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Benchmark trajectory across git history, with "
                    "per-metric regression gates.")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the newest version of any "
                         "gated metric regressed past its tolerance")
    ap.add_argument("--repo", default=str(ROOT),
                    help="repository root (default: this script's repo)")
    args = ap.parse_args(argv)

    if _git(args.repo, "rev-parse", "--git-dir").returncode != 0:
        print("bench_report: not a git repository (shallow CI checkout "
              "needs fetch-depth: 0)")
        return 2

    traj = trajectory(args.repo)
    labels = {f: [label for label, _ in vs] for f, vs in traj.items()}
    width = max(len(g.path) for g in GATES) + 2

    failures = []
    cur_file = None
    for gate in GATES:
        versions = traj[gate.file]
        if gate.file != cur_file:
            cur_file = gate.file
            cols = "  ".join(f"{l:>10}" for l in labels[gate.file])
            print(f"\n{gate.file}  [{len(versions)} versions]")
            print(f"  {'metric':<{width}}{cols}")
        values = [(label, dig(doc, gate.path)) for label, doc in versions]
        cells = "  ".join("         -" if v is None else f"{v:>10g}"
                          for _, v in values)
        ok, msg = check_gate(gate, values)
        flag = "" if ok else "  << REGRESSED"
        print(f"  {gate.path:<{width}}{cells}{flag}")
        if not ok:
            failures.append(f"{gate.file}:{gate.path}: {msg}")

    if args.check:
        if failures:
            print(f"\n{len(failures)} gate(s) regressed:")
            for f in failures:
                print(f"  - {f}")
            return 1
        print("\nall gates pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
