"""Per-node health telemetry: rolling latency/failure EWMAs and the
ok/degraded/suspect report the scheduler consumes.

The paper names node failure as the grid's biggest weakness; before any
resource-status policy (ROADMAP item 4) can *act* on sick nodes, the
service has to *see* them.  A :class:`HealthMonitor` folds the
per-packet telemetry the engine already produces
(:class:`~repro.core.jse.PacketTelemetry`, now node-attributed) into a
per-node scan-rate EWMA (seconds per event — size-normalized so packet
ramping doesn't masquerade as slowness) and a failure EWMA (decays on
every healthy packet, jumps on a node death).

Fleet aggregation rides the existing gossip path: a monitor's
:meth:`digest` piggybacks on the epoch gossip digest, and
:meth:`merge_digest` folds remote observations in.  Entries are keyed
``(node, origin)`` and carry a per-origin monotonic ``stamp``, so merge
is idempotent and order-free (newest evidence per origin wins — the
version-vector discipline the fabric already uses for epochs); a
front-end never overwrites its own observations with hearsay.

The :class:`HealthReport` classifies each node *relative to the fleet
median* scan rate: > ``degraded_factor`` x median is ``degraded``,
> ``suspect_factor`` x median — or a failure EWMA over threshold — is
``suspect``.  Relative thresholds make the report portable across
machines and workloads (absolute rates are not).  Consumption is
advisory and flag-gated in :class:`~repro.service.scheduler.QueryScheduler`
(``health_gate``): a degraded fleet gets narrower dispatch windows so
sick nodes see less concurrent work.  This is deliberately the *hook*,
not the policy — RSS-style routing plugs in here later.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

HEALTH_OK, HEALTH_DEGRADED, HEALTH_SUSPECT = "ok", "degraded", "suspect"
HEALTH_STATES = (HEALTH_OK, HEALTH_DEGRADED, HEALTH_SUSPECT)


@dataclasses.dataclass
class NodeHealth:
    """One origin's rolling view of one node: packet count, scan-rate
    EWMA (s/event), failure EWMA in [0, 1], and a per-origin monotonic
    ``stamp`` used as merge precedence."""
    node: int
    origin: str
    packets: int = 0
    rate_ewma: float = 0.0
    failure_ewma: float = 0.0
    stamp: int = 0

    def to_dict(self) -> Dict:
        """Wire form for the gossip digest."""
        return {"node": self.node, "origin": self.origin,
                "packets": self.packets, "rate_ewma": self.rate_ewma,
                "failure_ewma": self.failure_ewma, "stamp": self.stamp}

    @staticmethod
    def from_dict(d: Dict) -> "NodeHealth":
        """Rebuild an entry from its wire form."""
        return NodeHealth(node=int(d["node"]), origin=d["origin"],
                          packets=int(d["packets"]),
                          rate_ewma=float(d["rate_ewma"]),
                          failure_ewma=float(d["failure_ewma"]),
                          stamp=int(d["stamp"]))


@dataclasses.dataclass
class HealthReport:
    """Point-in-time fleet health: per-node state plus the combined
    rate/failure evidence behind it."""
    states: Dict[int, str]
    rates: Dict[int, float]
    failures: Dict[int, float]
    median_rate: float = 0.0

    @property
    def suspects(self) -> List[int]:
        """Nodes classified suspect, sorted."""
        return sorted(n for n, s in self.states.items()
                      if s == HEALTH_SUSPECT)

    @property
    def degraded(self) -> List[int]:
        """Nodes classified degraded, sorted."""
        return sorted(n for n, s in self.states.items()
                      if s == HEALTH_DEGRADED)

    @property
    def healthy_fraction(self) -> float:
        """Fraction of observed nodes in state ``ok`` (1.0 when nothing
        has been observed — no evidence is not a verdict)."""
        if not self.states:
            return 1.0
        ok = sum(1 for s in self.states.values() if s == HEALTH_OK)
        return ok / len(self.states)

    def to_dict(self) -> Dict:
        """JSON-friendly dump (string node keys)."""
        return {"states": {str(n): s for n, s in self.states.items()},
                "rates": {str(n): r for n, r in self.rates.items()},
                "failures": {str(n): f for n, f in self.failures.items()},
                "median_rate": self.median_rate}


class HealthMonitor:
    """Rolling per-node health, locally observed and gossip-merged.

    Parameters tune the EWMAs and classification: ``alpha`` is the EWMA
    weight of a new observation; ``min_packets`` is the evidence floor
    below which a node is reported ``ok`` (insufficient data is not
    sickness); the factors set the degraded/suspect rate thresholds
    relative to the fleet-median rate; ``failure_threshold`` is the
    failure-EWMA level that makes a node suspect outright."""

    def __init__(self, origin: str = "fe0", *, alpha: float = 0.25,
                 min_packets: int = 3, degraded_factor: float = 2.0,
                 suspect_factor: float = 4.0,
                 failure_threshold: float = 0.3):
        self.origin = origin
        self.alpha = alpha
        self.min_packets = min_packets
        self.degraded_factor = degraded_factor
        self.suspect_factor = suspect_factor
        self.failure_threshold = failure_threshold
        # (node -> origin -> entry); own origin's entries are authoritative
        self._entries: Dict[int, Dict[str, NodeHealth]] = {}

    # --------------------------- observation -------------------------- #
    def _own(self, node: int) -> NodeHealth:
        ent = self._entries.setdefault(node, {}).get(self.origin)
        if ent is None:
            ent = NodeHealth(node=node, origin=self.origin)
            self._entries[node][self.origin] = ent
        return ent

    def observe_packet(self, node: int, size: int, wall_s: float):
        """Fold one scanned packet into the node's EWMAs (healthy
        evidence: the failure EWMA decays)."""
        if node < 0 or size <= 0:
            return
        rate = wall_s / size
        ent = self._own(node)
        if ent.packets == 0:
            ent.rate_ewma = rate
        else:
            ent.rate_ewma += self.alpha * (rate - ent.rate_ewma)
        ent.failure_ewma *= (1.0 - self.alpha)
        ent.packets += 1
        ent.stamp += 1

    def observe_failure(self, node: int):
        """Fold one node death / packet failure into the failure EWMA."""
        ent = self._own(node)
        ent.failure_ewma += self.alpha * (1.0 - ent.failure_ewma)
        ent.stamp += 1

    def observe_stats(self, stats):
        """Convenience: fold a whole :class:`~repro.core.jse.JobStats`
        worth of node-attributed packet telemetry."""
        for t in getattr(stats, "packet_telemetry", ()):
            self.observe_packet(getattr(t, "node", -1), t.size, t.wall_s)

    # ------------------------- fleet aggregation ---------------------- #
    def digest(self) -> Dict:
        """JSON-able dump of every known entry (own + learned), suitable
        for piggybacking on a gossip digest."""
        return {"origin": self.origin,
                "entries": [ent.to_dict()
                            for node in sorted(self._entries)
                            for _, ent in sorted(
                                self._entries[node].items())]}

    def merge_digest(self, payload: Optional[Dict]):
        """Fold a remote digest in: per ``(node, origin)``, the higher
        ``stamp`` wins (idempotent, order-free); own-origin entries are
        never overwritten by hearsay."""
        if not payload:
            return
        for d in payload.get("entries", ()):
            ent = NodeHealth.from_dict(d)
            if ent.origin == self.origin:
                continue
            cur = self._entries.setdefault(ent.node, {}).get(ent.origin)
            if cur is None or ent.stamp > cur.stamp:
                self._entries[ent.node][ent.origin] = ent

    # ----------------------------- report ----------------------------- #
    def _combined(self, node: int) -> NodeHealth:
        """Packet-weighted combination of every origin's view of a node
        (failure takes the max: one origin seeing deaths is enough)."""
        ents = list(self._entries.get(node, {}).values())
        total = sum(e.packets for e in ents)
        out = NodeHealth(node=node, origin="*", packets=total)
        if total > 0:
            out.rate_ewma = sum(e.rate_ewma * e.packets
                                for e in ents) / total
        if ents:
            out.failure_ewma = max(e.failure_ewma for e in ents)
        return out

    def report(self) -> HealthReport:
        """Classify every observed node against the fleet-median rate."""
        combined = {n: self._combined(n) for n in sorted(self._entries)}
        rates = sorted(c.rate_ewma for c in combined.values()
                       if c.packets >= self.min_packets)
        median = rates[len(rates) // 2] if rates else 0.0
        states: Dict[int, str] = {}
        for n, c in combined.items():
            if c.failure_ewma >= self.failure_threshold:
                states[n] = HEALTH_SUSPECT
            elif c.packets < self.min_packets or median <= 0.0:
                states[n] = HEALTH_OK
            elif c.rate_ewma > self.suspect_factor * median:
                states[n] = HEALTH_SUSPECT
            elif c.rate_ewma > self.degraded_factor * median:
                states[n] = HEALTH_DEGRADED
            else:
                states[n] = HEALTH_OK
        return HealthReport(
            states=states,
            rates={n: c.rate_ewma for n, c in combined.items()},
            failures={n: c.failure_ewma for n, c in combined.items()},
            median_rate=median)
