"""Deterministic replay of a recorded fleet flight log.

The flight log (:mod:`repro.obs.flight`) contains everything that made a
run what it was: the fleet's construction parameters (``run_header``),
every driver call in order (``op`` records), and — crucially — the
outcome of every bus send (``bus_send`` records).  The bus is the ONLY
stochastic component of a fleet run (its seeded loss draw), and
partitions/per-link loss funnel through the same decision point, so
substituting the recorded outcomes while re-issuing the recorded driver
calls re-drives the run exactly:

- :class:`ReplayBus` — a :class:`~repro.fabric.bus.MessageBus` whose
  ``_send_outcome`` consults the recorded script (keyed by send ordinal)
  instead of the RNG/partition state, flagging divergences when the
  replayed traffic stops matching the recorded shape;
- :func:`replay_run` — builds a fresh fleet from the header, applies the
  ops, and compares the replay's own flight log against the original on
  the bit-identity surface: ``final`` digests (status / adopted /
  cached / result digest per global ticket) and the full
  ``stream_snapshot`` prefix of every streamed ticket;
- :func:`main` — the CLI (``python -m repro.obs.replay flight.jsonl``)
  the CI replay-smoke job runs: exit 0 iff the replay is bit-identical.

Replay needs a brick store equal to the original run's.  Logs recorded
through ``serve.py --flight-out`` carry a ``store_config`` record and
are self-contained; programmatic logs take ``store=`` (build a FRESH
store with the same parameters — the original object may have been
mutated by re-replication or elastic migration during the run).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Any, Dict, List, Optional

from repro.fabric.bus import MessageBus
from repro.obs import flight as flight_lib


class ReplayError(RuntimeError):
    """The log cannot be replayed (wrong schema, missing header, or a
    construction parameter replay cannot reproduce, e.g. a custom
    scheduler factory)."""


class ReplayBus(MessageBus):
    """A message bus that substitutes recorded send outcomes.

    ``script`` maps the send ordinal (``BusStats.sent`` AFTER the
    increment — the n-th ``send()`` call overall) to its recorded
    ``bus_send`` record.  Sends beyond the script fall back to the live
    decision (counted in :attr:`overruns`); a scripted send whose
    (src, dst, topic) no longer matches the recording lands in
    :attr:`divergences` — both mean the replay has drifted and
    bit-identity is already lost."""

    def __init__(self, script: Dict[int, dict], *, delay: int = 0,
                 drop_rate: float = 0.0, seed: int = 0):
        super().__init__(delay=delay, drop_rate=drop_rate, seed=seed)
        self._script = dict(script)
        self.divergences: List[str] = []
        self.overruns = 0

    def _send_outcome(self, src: str, dst: str, topic: str) -> str:
        rec = self._script.get(self.stats.sent)
        if rec is None:
            self.overruns += 1
            return super()._send_outcome(src, dst, topic)
        if (rec["src"], rec["dst"], rec["topic"]) != (src, dst, topic):
            self.divergences.append(
                f"send #{self.stats.sent}: recorded "
                f"{rec['src']}->{rec['dst']}/{rec['topic']}, replayed "
                f"{src}->{dst}/{topic}")
            return super()._send_outcome(src, dst, topic)
        return rec["outcome"]


@dataclasses.dataclass
class ReplayReport:
    """Outcome of one :func:`replay_run`: :attr:`identical` is the
    bit-identity verdict; on failure :attr:`mismatches` lists the
    differing finals/snapshots and :attr:`bus_divergences` /
    :attr:`overruns` say where the traffic shape drifted.
    :attr:`records` is the REPLAY's own flight log (for triage with
    ``scripts/flight_report.py``) and :attr:`trace` its trace records
    when the run had ``obs=True`` (for ``comparable_records`` checks)."""
    identical: bool
    mismatches: List[str]
    bus_divergences: List[str]
    overruns: int
    n_finals: int
    n_snapshots: int
    fleet_stats: Dict[str, Any]
    records: List[dict] = dataclasses.field(default_factory=list)
    trace: List[dict] = dataclasses.field(default_factory=list)


def _projected(records):
    """The bit-identity surface of a flight log: final tuples (in gtid
    order) and the per-ticket stream-snapshot prefixes (in publish
    order)."""
    finals = [(r["gtid"], r["status"], r.get("digest"),
               bool(r.get("adopted")), bool(r.get("cached")))
              for r in records if r["kind"] == "final"]
    snaps = [(r["gtid"], r["seq"], bool(r["final"]), r["digest"])
             for r in records if r["kind"] == "stream_snapshot"]
    return sorted(finals), snaps


def _build_store(records):
    sc = next((r for r in records if r["kind"] == "store_config"), None)
    if sc is None:
        raise ReplayError(
            "log has no store_config record (programmatic recording): "
            "pass store= with a freshly built store equal to the "
            "original run's")
    if sc.get("schema_name") != "geps_reduced":
        raise ReplayError(
            f"unknown store schema {sc.get('schema_name')!r}")
    from repro.configs.geps_events import reduced as geps_reduced
    from repro.core import events as ev
    from repro.core.brick import create_store
    schema = ev.EventSchema.from_config(geps_reduced())
    return create_store(schema, n_events=sc["n_events"],
                        n_nodes=sc["n_nodes"],
                        events_per_brick=sc["events_per_brick"],
                        replication=sc["replication"], seed=sc["seed"])


def replay_run(records: List[dict], *, store=None) -> ReplayReport:
    """Re-drive a fleet from a recorded flight log and compare.

    Builds a fresh fleet from the log's ``run_header`` (over ``store``,
    or a store built from the log's ``store_config``), wires a
    :class:`ReplayBus` scripted with the recorded send outcomes, applies
    every recorded driver op in order, and returns a
    :class:`ReplayReport` whose ``identical`` asserts bit-equality of
    finals and stream prefixes with the original run."""
    problems = flight_lib.validate_flight(records)
    if problems:
        raise ReplayError(f"invalid flight log: {problems[:3]}")
    header = next((r for r in records if r["kind"] == "run_header"), None)
    if header is None:
        raise ReplayError("log has no run_header record")
    for flag in ("scheduler_factory", "policy_config", "l2_path"):
        if header.get(flag):
            raise ReplayError(
                f"run used {flag}, which the log cannot serialize — "
                f"replay programmatically instead")
    if store is None:
        store = _build_store(records)

    # lazy import: repro.obs is imported by the fabric package, so a
    # top-level fleet import here would be circular
    from repro.fabric.fleet import Fleet
    from repro.fabric.registry import FragmentRegistry

    script = {r["n"]: r for r in records if r["kind"] == "bus_send"}
    bus = ReplayBus(script, delay=header["bus_delay"],
                    drop_rate=header["bus_drop_rate"])
    fleet = Fleet(
        store, header["n_frontends"], bus=bus,
        shared_cache=header["shared_cache"],
        l1_capacity=header["l1_capacity"],
        l2_capacity=header["l2_capacity"],
        registry=FragmentRegistry() if header["registry"] else None,
        backend=header["backend"],
        gossip_fanout=header["gossip_fanout"],
        service_kwargs=header["service_kwargs"] or None,
        obs=header["obs"], gossip_repair=header["gossip_repair"],
        policy=header["policy"], single_flight=header["single_flight"],
        lease_ttl=header["lease_ttl"], flight=True)

    mismatches: List[str] = []
    closed = False
    for op in (r for r in records if r["kind"] == "op"):
        name = op["op"]
        if name == "submit":
            if op.get("scripted"):
                raise ReplayError("scripted submit cannot be replayed")
            gtid = fleet.submit(op["expr"], tenant=op["tenant"],
                                calib_iters=op["calib_iters"],
                                stream=op["stream"],
                                frontend=op["frontend"])
            if gtid != op["gtid"]:
                mismatches.append(
                    f"submit issued gtid {gtid}, recorded {op['gtid']}")
        elif name == "step":
            if op.get("scripted"):
                raise ReplayError(
                    "run used a failure_script, which the log cannot "
                    "serialize — replay programmatically instead")
            fleet.step(op["frontend"], pump_rounds=op["pump_rounds"])
        elif name == "pump":
            fleet.pump(op["rounds"])
        elif name == "drain":
            fleet.drain(max_windows=op["max_windows"])
        elif name == "bump":
            fleet.bump_dataset_version(op["frontend"])
        elif name == "stream":
            fleet.stream(op["gtid"], frontend=op["frontend"])
        elif name == "node_leave":
            fleet.node_leave(op["grid_node"],
                             observed_by=op["observed_by"])
        elif name == "node_join":
            fleet.node_join(op["grid_node"], observed_by=op["observed_by"])
        elif name == "frontend_leave":
            fleet.frontend_leave(op["index"])
        elif name == "ban_frontend":
            fleet.ban_frontend(op["index"], by=op["by"])
        elif name == "close":
            fleet.close()
            closed = True
        else:
            raise ReplayError(f"unknown driver op {name!r}")

    # snapshot before the implicit close() below appends its own op
    replay_records = list(fleet.flight.records)
    stats = fleet.fleet_stats()
    trace = fleet.trace_records() if header["obs"] else []
    if not closed:
        fleet.close()

    want_finals, want_snaps = _projected(records)
    got_finals, got_snaps = _projected(replay_records)
    for label, want, got in (("final", want_finals, got_finals),
                             ("stream_snapshot", want_snaps, got_snaps)):
        if want == got:
            continue
        n = min(len(want), len(got))
        i = next((k for k in range(n) if want[k] != got[k]), n)
        mismatches.append(
            f"{label}[{i}]: recorded "
            f"{want[i] if i < len(want) else '<missing>'} vs replayed "
            f"{got[i] if i < len(got) else '<missing>'} "
            f"({len(want)} recorded, {len(got)} replayed)")
    identical = (not mismatches and not bus.divergences
                 and bus.overruns == 0)
    return ReplayReport(identical=identical, mismatches=mismatches,
                        bus_divergences=list(bus.divergences),
                        overruns=bus.overruns,
                        n_finals=len(got_finals),
                        n_snapshots=len(got_snaps),
                        fleet_stats=stats, records=replay_records,
                        trace=trace)


def main(argv: Optional[List[str]] = None) -> int:
    """CLI: replay a ``--flight-out`` log and assert bit-identity.
    Exit 0 when finals and stream prefixes match the recording exactly,
    1 otherwise (with a mismatch report on stdout)."""
    ap = argparse.ArgumentParser(
        description="Replay a recorded fleet flight log and assert "
                    "bit-identical finals and stream prefixes.")
    ap.add_argument("log", help="flight JSONL written by --flight-out")
    args = ap.parse_args(argv)
    records = flight_lib.load_flight(args.log)
    report = replay_run(records)
    print(f"replay: {report.n_finals} finals, {report.n_snapshots} "
          f"stream snapshots, {report.overruns} script overruns, "
          f"{len(report.bus_divergences)} bus divergences")
    if report.identical:
        print("replay: bit-identical to recording")
        return 0
    for m in report.mismatches[:10]:
        print(f"  mismatch: {m}")
    for d in report.bus_divergences[:10]:
        print(f"  bus: {d}")
    print("replay: DIVERGED from recording")
    return 1


if __name__ == "__main__":
    sys.exit(main())
