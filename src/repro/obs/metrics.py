"""Process-local metrics registry with fleet-mergeable snapshots.

Counters, gauges and fixed-bucket histograms for the quantities the
benchmarks used to print ad hoc: cache hits by tier, dispatch-window
sizes, per-packet scan latencies, gossip traffic, stream backpressure
conflations, bus drops.  Two design rules:

1. **Get-or-create by name.**  Instrumented layers call
   ``registry.counter("cache.hits_l1").inc()`` — no central metric
   enumeration to keep in sync; the catalog lives in
   ``docs/observability.md``.
2. **Snapshots are mergeable.**  :func:`merge2` combines two
   :class:`MetricsSnapshot` values (counters add, gauges take max,
   histograms add bucket-wise) and is associative + commutative, so a
   fleet-wide view is just the existing
   :func:`repro.core.merge.tree_merge` machinery applied to per-frontend
   snapshots — the same reduction shape the grid uses for query results.

Histogram bucket edges are part of a metric's identity: merging two
histograms with different edges is an error, not a resample.
"""
from __future__ import annotations

import bisect
import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import merge as merge_lib

# default latency edges (seconds): 10us .. 30s, roughly x3 per step
DEFAULT_LATENCY_BUCKETS = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
    0.1, 0.3, 1.0, 3.0, 10.0, 30.0,
)
# default size edges (events / queries): powers of 4
DEFAULT_SIZE_BUCKETS = (1, 4, 16, 64, 256, 1024, 4096, 16384, 65536)


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0):
        """Add ``n`` (default 1)."""
        self.value += n


class Gauge:
    """Last-set value; fleet merge takes the max (the only associative,
    commutative, idempotent choice that needs no per-origin bookkeeping)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        """Record the latest value."""
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: ``edges`` are upper bounds (a value lands
    in the first bucket whose edge is >= it; one overflow bucket past the
    last edge), plus running ``sum`` and ``count`` for means."""

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError("histogram edges must be strictly increasing")
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        """Record one sample."""
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    def to_dict(self) -> Dict[str, Any]:
        """The snapshot payload for this histogram alone."""
        return {"type": "histogram", "edges": list(self.edges),
                "counts": list(self.counts), "sum": self.sum,
                "count": self.count}


@dataclasses.dataclass
class MetricsSnapshot:
    """Immutable-by-convention dump of a registry: metric name ->
    ``{"type": ..., ...}`` payload, plus the origins that contributed
    (one for a fresh snapshot, several after fleet merges).  This is the
    unit that flows through :func:`merge2` / ``tree_merge``."""
    metrics: Dict[str, Dict[str, Any]]
    origins: Tuple[str, ...] = ()

    def to_dict(self) -> Dict[str, Any]:
        """JSON-friendly dump (``serve.py --metrics-dump`` format)."""
        return {"origins": list(self.origins), "metrics": self.metrics}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "MetricsSnapshot":
        """Rebuild a snapshot from :meth:`to_dict` output."""
        return MetricsSnapshot(metrics=dict(d["metrics"]),
                               origins=tuple(d.get("origins", ())))

    def value(self, name: str, default: float = 0.0) -> float:
        """Counter/gauge value by name (histograms: use ``hist``)."""
        m = self.metrics.get(name)
        return default if m is None else float(m.get("value", default))

    def hist(self, name: str) -> Optional[Dict[str, Any]]:
        """Histogram payload by name (``edges``/``counts``/``sum``/
        ``count``) or None."""
        m = self.metrics.get(name)
        return m if m is not None and m["type"] == "histogram" else None

    def to_prom_text(self) -> str:
        """The snapshot in Prometheus text exposition format (what
        ``serve.py --metrics-dump out.prom`` writes): dotted metric
        names sanitized to underscores, counters/gauges as a single
        sample, histograms as cumulative ``_bucket{le=...}`` series plus
        ``_sum`` and ``_count`` — scrape-ready for a pushgateway or a
        textfile collector."""
        def sane(name: str) -> str:
            return re.sub(r"[^a-zA-Z0-9_:]", "_", name)

        lines: List[str] = []
        for name in sorted(self.metrics):
            m, pname = self.metrics[name], sane(name)
            if m["type"] in ("counter", "gauge"):
                lines.append(f"# TYPE {pname} {m['type']}")
                lines.append(f"{pname} {m['value']}")
                continue
            lines.append(f"# TYPE {pname} histogram")
            acc = 0
            for edge, count in zip(m["edges"], m["counts"]):
                acc += count
                lines.append(f'{pname}_bucket{{le="{edge}"}} {acc}')
            acc += m["counts"][-1]
            lines.append(f'{pname}_bucket{{le="+Inf"}} {acc}')
            lines.append(f"{pname}_sum {m['sum']}")
            lines.append(f"{pname}_count {m['count']}")
        return "\n".join(lines) + "\n"


def merge2(a: MetricsSnapshot, b: MetricsSnapshot) -> MetricsSnapshot:
    """Combine two snapshots: counters add, gauges max, histograms add
    bucket-wise (edges must match — a mismatch is a config error, not a
    resample).  Associative and commutative, so snapshots reduce through
    :func:`repro.core.merge.tree_merge` like query results do."""
    out: Dict[str, Dict[str, Any]] = {}
    for name in sorted(set(a.metrics) | set(b.metrics)):
        ma, mb = a.metrics.get(name), b.metrics.get(name)
        if ma is None or mb is None:
            src = ma if mb is None else mb
            out[name] = {k: (list(v) if isinstance(v, list) else v)
                         for k, v in src.items()}
            continue
        if ma["type"] != mb["type"]:
            raise ValueError(
                f"metric {name!r}: type mismatch "
                f"{ma['type']!r} vs {mb['type']!r}")
        if ma["type"] == "counter":
            out[name] = {"type": "counter",
                         "value": ma["value"] + mb["value"]}
        elif ma["type"] == "gauge":
            out[name] = {"type": "gauge",
                         "value": max(ma["value"], mb["value"])}
        else:
            if list(ma["edges"]) != list(mb["edges"]):
                raise ValueError(f"metric {name!r}: bucket edges differ")
            out[name] = {
                "type": "histogram",
                "edges": list(ma["edges"]),
                "counts": [x + y for x, y in zip(ma["counts"],
                                                 mb["counts"])],
                "sum": ma["sum"] + mb["sum"],
                "count": ma["count"] + mb["count"],
            }
    return MetricsSnapshot(metrics=out,
                           origins=tuple(sorted(set(a.origins)
                                                | set(b.origins))))


def merge_snapshots(snaps: Sequence[MetricsSnapshot]) -> MetricsSnapshot:
    """Fleet reduction of per-frontend snapshots via the grid's
    ``tree_merge`` (pairwise balanced tree, same machinery as query
    results)."""
    if not snaps:
        return MetricsSnapshot(metrics={})
    return merge_lib.tree_merge(snaps, merge_fn=merge2)


class MetricsRegistry:
    """Get-or-create registry of named counters/gauges/histograms.

    One per process (front-end); the fleet view is
    :func:`merge_snapshots` over every registry's :meth:`snapshot`.
    Re-requesting a histogram with different edges is an error — edges
    are part of the metric's identity."""

    def __init__(self, origin: str = ""):
        self.origin = origin
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(*args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}")
        return m

    def counter(self, name: str) -> Counter:
        """Get-or-create the named counter."""
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        """Get-or-create the named gauge."""
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        """Get-or-create a histogram.  ``edges`` applies (and is checked)
        only when passed explicitly; omitting it fetches whatever edges
        the metric was first registered with (latency default on
        create) — so hot call sites need not re-state bucket config."""
        h = self._get(name, Histogram,
                      DEFAULT_LATENCY_BUCKETS if edges is None else edges)
        if edges is not None and h.edges != tuple(float(e) for e in edges):
            raise ValueError(f"histogram {name!r} edges differ from "
                             "first registration")
        return h

    def value(self, name: str, default: float = 0.0) -> float:
        """Current counter/gauge value (0 default if never touched)."""
        m = self._metrics.get(name)
        return default if m is None else float(m.value)

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> MetricsSnapshot:
        """Serialize the registry for export / fleet merging."""
        out: Dict[str, Dict[str, Any]] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = {"type": "counter", "value": m.value}
            elif isinstance(m, Gauge):
                out[name] = {"type": "gauge", "value": m.value}
            else:
                out[name] = {"type": "histogram",
                             "edges": list(m.edges),
                             "counts": list(m.counts),
                             "sum": m.sum, "count": m.count}
        origins = (self.origin,) if self.origin else ()
        return MetricsSnapshot(metrics=out, origins=origins)
