"""Fleet flight recorder: a causally-ordered decision log of every
nondeterminism-relevant event in a fleet run.

PR 6's spans and metrics answer *what* a run did; this module answers
*why*.  A :class:`FlightRecorder` attached to a
:class:`~repro.fabric.fleet.Fleet` (``Fleet(flight=True)``) captures one
schema-versioned record per fleet decision:

- **driver ops** — every driver call (submit / step / pump / drain /
  bump / node_leave / ...) with its arguments and resolved ids, so the
  run can be re-driven verbatim;
- **bus decisions** — every envelope send with its outcome (delivered /
  dropped / partitioned), keyed by the per-bus send ordinal, and every
  delivery, causally linked to its send;
- **gossip** — epoch advances and liveness flips, per node;
- **leases** — announce / grant / expire / release / revoke, plus the
  adoption and fallback transitions the front-end drives;
- **policy** — node state-machine transitions, re-replication, and the
  per-window decision surface;
- **scheduler** — each dispatch window's ticket composition;
- **results** — a digest of every final and every streamed snapshot,
  the bit-identity surface the replay engine
  (:mod:`repro.obs.replay`) checks.

Causality model: records carry a monotonically increasing ``eid`` and a
``cause`` eid.  The fleet pushes the enclosing driver op (and, during
``pump``, the delivering envelope) on the recorder's cause stack, so a
lease grant applied while handling a gossip round points at the exact
``bus_deliver`` that carried it, which points at its ``bus_send`` —
walking ``cause`` links yields the ancestry chain of any decision
(``scripts/flight_report.py`` automates the walk).

Determinism: records never contain wall-clock times, only virtual
rounds, ordinals and content digests — two runs of the same seeded
workload produce byte-identical logs, which is what makes the log
diffable and replayable.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, List, Optional

# Log format version, stamped on every record.  Bump when a record kind
# changes shape; the replay engine refuses logs from a newer schema.
FLIGHT_SCHEMA_VERSION = 1

# Every record kind the recorder emits (validate_flight rejects others).
FLIGHT_KINDS = (
    "run_header", "store_config", "op",
    "bus_send", "bus_deliver",
    "gossip_epoch", "gossip_liveness",
    "lease_announce", "lease_grant", "lease_expire", "lease_release",
    "lease_revoke", "lease_adopt", "lease_fallback",
    "policy_transition", "policy_decide", "rereplicate",
    "window", "stream_snapshot", "final",
)

_UNSET = object()  # distinguishes "cause not given" from "cause=None"


def result_digest(result) -> str:
    """Content digest of a :class:`~repro.core.merge.QueryResult`:
    sha256 over its sorted JSON ``to_dict`` form.  That form is exact
    (ints plus a repr-round-tripping float), so equal digests mean
    bit-identical results — the replay engine compares these instead of
    shipping full histograms through the log."""
    payload = json.dumps(result.to_dict(), sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


class FlightScope:
    """A :class:`FlightRecorder` view that stamps a fixed ``origin`` on
    every record — components hold one of these in their ``flight``
    attribute so their hook sites stay one-liners."""

    def __init__(self, recorder: "FlightRecorder", origin: str):
        self.recorder = recorder
        self.origin = origin

    def record(self, kind: str, **fields) -> Dict[str, Any]:
        """Append one record with this scope's origin (see
        :meth:`FlightRecorder.record`)."""
        return self.recorder.record(kind, origin=self.origin, **fields)

    def note_send(self, seq: int, eid: int) -> None:
        """Forward to :meth:`FlightRecorder.note_send`."""
        self.recorder.note_send(seq, eid)

    def note_deliver(self, seq: int, eid: int) -> None:
        """Forward to :meth:`FlightRecorder.note_deliver`."""
        self.recorder.note_deliver(seq, eid)

    def send_cause(self, seq: int) -> Optional[int]:
        """Forward to :meth:`FlightRecorder.send_cause`."""
        return self.recorder.send_cause(seq)

    def deliver_cause(self, seq: int) -> Optional[int]:
        """Forward to :meth:`FlightRecorder.deliver_cause`."""
        return self.recorder.deliver_cause(seq)


class FlightRecorder:
    """Collects the causally-ordered flight log of one fleet run.

    The fleet installs :meth:`scoped` views on each component (bus,
    per-node gossip / leases / policy / scheduler); components append
    via ``self.flight.record(...)`` guarded by ``flight is not None``,
    so a recorder-less run pays nothing.  :attr:`records` is the log:
    plain JSON-safe dicts, appended in causal order."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []
        self._cause: List[Optional[int]] = []
        self._send_eids: Dict[int, int] = {}     # envelope seq -> send eid
        self._deliver_eids: Dict[int, int] = {}  # envelope seq -> deliver eid

    # ---------------------------- writing ----------------------------- #
    def record(self, kind: str, *, origin: str = "", cause=_UNSET,
               **fields) -> Dict[str, Any]:
        """Append one record and return it (callers may patch fields in
        place after the fact, e.g. the resolved gtid of a submit op).
        ``cause`` defaults to the top of the cause stack — the enclosing
        driver op or delivering envelope."""
        if cause is _UNSET:
            cause = self._cause[-1] if self._cause else None
        rec: Dict[str, Any] = {"schema": FLIGHT_SCHEMA_VERSION,
                               "eid": len(self.records), "kind": kind,
                               "origin": origin, "cause": cause}
        rec.update(fields)
        self.records.append(rec)
        return rec

    def push(self, eid: Optional[int]) -> None:
        """Push a cause eid; records appended until :meth:`pop` chain to
        it by default."""
        self._cause.append(eid)

    def pop(self) -> None:
        """Pop the top of the cause stack."""
        self._cause.pop()

    def scoped(self, origin: str) -> FlightScope:
        """A view of this recorder that stamps ``origin`` on every
        record (what the fleet installs on each component)."""
        return FlightScope(self, origin)

    # ----------------------- envelope causality ----------------------- #
    def note_send(self, seq: int, eid: int) -> None:
        """Remember the send record of envelope ``seq`` so its delivery
        can point back at it."""
        self._send_eids[seq] = eid

    def note_deliver(self, seq: int, eid: int) -> None:
        """Remember the delivery record of envelope ``seq`` so handler
        effects can point back at it."""
        self._deliver_eids[seq] = eid

    def send_cause(self, seq: int) -> Optional[int]:
        """The send eid of envelope ``seq`` (None if unrecorded)."""
        return self._send_eids.get(seq)

    def deliver_cause(self, seq: int) -> Optional[int]:
        """The delivery eid of envelope ``seq`` (None if unrecorded)."""
        return self._deliver_eids.get(seq)

    # ---------------------------- reading ----------------------------- #
    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        """The recorded log, optionally filtered to one kind."""
        if kind is None:
            return list(self.records)
        return [r for r in self.records if r["kind"] == kind]

    def save_jsonl(self, path) -> None:
        """Write the log to ``path``, one JSON record per line (the
        ``--flight-out`` format; read back with :func:`load_flight`)."""
        save_flight(self.records, path)


def save_flight(records, path) -> None:
    """Write flight records to ``path`` as JSONL, sorted keys so equal
    logs are byte-equal files."""
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")


def load_flight(path) -> List[Dict[str, Any]]:
    """Read a JSONL flight log written by :func:`save_flight`."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def validate_flight(records) -> List[str]:
    """Structural checks on a flight log; returns human-readable
    problems (empty = valid).  Checks: schema version, contiguous eids,
    known kinds, and every ``cause`` pointing at an earlier record."""
    problems = []
    for i, rec in enumerate(records):
        where = f"record {i}"
        if rec.get("schema") != FLIGHT_SCHEMA_VERSION:
            problems.append(f"{where}: schema {rec.get('schema')!r} != "
                            f"{FLIGHT_SCHEMA_VERSION}")
        if rec.get("eid") != i:
            problems.append(f"{where}: eid {rec.get('eid')!r} is not "
                            f"contiguous")
        if rec.get("kind") not in FLIGHT_KINDS:
            problems.append(f"{where}: unknown kind {rec.get('kind')!r}")
        cause = rec.get("cause")
        if cause is not None and not (isinstance(cause, int)
                                      and 0 <= cause < i):
            problems.append(f"{where}: cause {cause!r} does not point at "
                            f"an earlier record")
    return problems
