"""Fleet-wide observability plane: ticket tracing, metrics, node health.

Zero-dependency (stdlib + numpy-free) instrumentation threaded through
every layer of the stack behind one convention: each instrumented object
carries an ``obs`` attribute that defaults to ``None``, and every
instrumentation site is guarded by ``if obs is not None`` — the disabled
path costs one attribute test and allocates nothing.  Enabling is one
constructor argument: pass an :class:`Observability` bundle to
``QueryService`` (which installs it on its backend/engine) or let
``Fleet(obs=True)`` build one per front-end.

See ``docs/observability.md`` for the span taxonomy, metric catalog,
health-state semantics, trace-file format, and the flight-recorder /
replay contract (:mod:`repro.obs.flight`, :mod:`repro.obs.replay`).
"""
from __future__ import annotations

from repro.obs.flight import (FLIGHT_KINDS, FLIGHT_SCHEMA_VERSION,
                              FlightRecorder, FlightScope, load_flight,
                              result_digest, save_flight, validate_flight)
from repro.obs.health import (HEALTH_DEGRADED, HEALTH_OK, HEALTH_STATES,
                              HEALTH_SUSPECT, HealthMonitor, HealthReport,
                              NodeHealth)
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS,
                               DEFAULT_SIZE_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, MetricsSnapshot,
                               merge2, merge_snapshots)
from repro.obs.trace import (SCHEMA_VERSION, SPAN_NAMES, STATUS_ERROR,
                             STATUS_OK, STATUS_OPEN, Span, Tracer,
                             chrome_from_records, comparable_records,
                             load_jsonl, save_chrome, save_jsonl,
                             validate_file, validate_records)


# the replay engine drives a Fleet, whose module imports this package:
# resolve its names lazily (PEP 562) so `import repro.obs` never pulls
# the fabric stack mid-initialization
_REPLAY_NAMES = ("ReplayBus", "ReplayError", "ReplayReport", "replay_run")


def __getattr__(name: str):
    """Lazy re-export of :mod:`repro.obs.replay` (breaks the
    obs -> replay -> fabric -> fleet -> obs import cycle)."""
    if name in _REPLAY_NAMES:
        from repro.obs import replay as _replay
        return getattr(_replay, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class Observability:
    """The per-process observability bundle: one :class:`Tracer`, one
    :class:`MetricsRegistry` and one :class:`HealthMonitor` sharing an
    ``origin`` label (the front-end id in a fleet).  This is the single
    handle instrumented layers accept — ``obs=None`` disables the whole
    plane."""

    def __init__(self, origin: str = "fe0"):
        self.origin = origin
        self.tracer = Tracer(process=origin)
        self.metrics = MetricsRegistry(origin=origin)
        self.health = HealthMonitor(origin=origin)
        # pre-register the size-valued histograms so hot call sites can
        # fetch them by name without re-stating bucket config
        for name in ("packet.events", "window.queries"):
            self.metrics.histogram(name, DEFAULT_SIZE_BUCKETS)


__all__ = [
    "Observability",
    # trace
    "Span", "Tracer", "SCHEMA_VERSION", "SPAN_NAMES",
    "STATUS_OPEN", "STATUS_OK", "STATUS_ERROR",
    "save_jsonl", "load_jsonl", "validate_records", "validate_file",
    "comparable_records", "chrome_from_records", "save_chrome",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricsSnapshot",
    "merge2", "merge_snapshots",
    "DEFAULT_LATENCY_BUCKETS", "DEFAULT_SIZE_BUCKETS",
    # health
    "HealthMonitor", "HealthReport", "NodeHealth",
    "HEALTH_STATES", "HEALTH_OK", "HEALTH_DEGRADED", "HEALTH_SUSPECT",
    # flight recorder / replay
    "FlightRecorder", "FlightScope", "FLIGHT_SCHEMA_VERSION",
    "FLIGHT_KINDS", "result_digest",
    "save_flight", "load_flight", "validate_flight",
    "ReplayBus", "ReplayError", "ReplayReport", "replay_run",
]
