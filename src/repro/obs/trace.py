"""Per-ticket span tracing for the query service and fleet.

The paper's Job Submit Server "distributes the tasks through all the nodes
and retrieves the result"; when a ticket is slow the operator needs to see
*where* the time went — admission, planning, dispatch, a straggling packet,
or stream backpressure.  This module is the zero-dependency span layer the
whole stack reports into:

* A :class:`Span` covers one phase of one ticket or window (``submit``,
  ``plan``, ``dispatch``, ``packet``, ``stream`` ...) with a parent link,
  *both* clocks (deterministic virtual time from the grid simulation, and
  wall time for real profiling), a terminal ``status`` and free-form
  ``attrs``.
* A :class:`Tracer` is the per-process collector.  Callers pass virtual
  timestamps explicitly (every layer has its own notion of virtual time);
  wall stamps are taken automatically from ``time.perf_counter``.  A
  parent *stack* (:meth:`Tracer.push`/:meth:`Tracer.pop`) lets an outer
  layer (the front-end's dispatch span) become the implicit parent of
  spans opened deeper in the stack (the engine's per-packet scans) without
  threading span ids through every call signature.
* Export is JSONL (one record per span, schema-checked by
  :func:`validate_records`) and Chrome-trace JSON
  (:func:`chrome_from_records`) loadable in ``chrome://tracing`` /
  Perfetto — spans are laid out on the virtual-time axis, which is the
  deterministic one.

Determinism contract: with a fixed seed and the simulated backend, every
field except the ``*_wall`` stamps is identical run to run
(:func:`comparable_records` strips the wall fields for such comparisons).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 1

# span taxonomy used by the instrumented layers (docs/observability.md)
SPAN_NAMES = (
    "submit", "admit", "cache_probe", "window", "plan", "dispatch",
    "packet", "merge_prefix", "stream_partial", "stream", "final",
    "node_death", "policy_transition", "speculate", "rereplicate",
    "lease_adopt", "lease_fallback",
)

STATUS_OPEN, STATUS_OK, STATUS_ERROR = "open", "ok", "error"

# required JSONL record fields -> allowed types (None encoded as null)
_SCHEMA: Dict[str, Tuple[type, ...]] = {
    "schema": (int,),
    "span_id": (int,),
    "parent_id": (int, type(None)),
    "name": (str,),
    "kind": (str,),
    "process": (str,),
    "ticket": (int, str, type(None)),  # str = lease key (fabric spans)
    "t0_virtual": (float, int),
    "t1_virtual": (float, int, type(None)),
    "t0_wall": (float, int),
    "t1_wall": (float, int, type(None)),
    "status": (str,),
    "attrs": (dict,),
}

# fields that carry wall-clock (nondeterministic) data
WALL_FIELDS = ("t0_wall", "t1_wall")


@dataclasses.dataclass
class Span:
    """One traced phase: a node in the per-ticket span tree.

    ``kind`` is ``"span"`` for phases with duration and ``"event"`` for
    instantaneous marks (``t1_* == t0_*``).  ``status`` starts ``open``
    and must end ``ok`` or ``error`` — an ``open`` span in an exported
    trace is a leak (the bug class the stream-abort sweep closes)."""
    span_id: int
    name: str
    process: str
    t0_virtual: float
    t0_wall: float
    parent_id: Optional[int] = None
    #: ticket id, or a lease key (str) for fabric-side adoption spans
    ticket: Optional[Any] = None
    kind: str = "span"
    t1_virtual: Optional[float] = None
    t1_wall: Optional[float] = None
    status: str = STATUS_OPEN
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_record(self) -> Dict[str, Any]:
        """The span as a schema-versioned JSONL record (plain dict)."""
        return {
            "schema": SCHEMA_VERSION,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "kind": self.kind,
            "process": self.process,
            "ticket": self.ticket,
            "t0_virtual": self.t0_virtual,
            "t1_virtual": self.t1_virtual,
            "t0_wall": self.t0_wall,
            "t1_wall": self.t1_wall,
            "status": self.status,
            "attrs": dict(self.attrs),
        }


class Tracer:
    """Per-process span collector (one per front-end / engine owner).

    Span ids are a plain counter, so a fixed workload produces the same
    ids every run.  The tracer never samples and never drops; the
    disabled path is simply *no tracer* (``obs is None`` at every call
    site), which keeps tracing cost out of hot loops entirely.
    """

    def __init__(self, process: str = "svc"):
        self.process = process
        self.spans: List[Span] = []
        #: offset layers with a window-relative virtual clock add to their
        #: stamps (the front-end sets this to its cumulative virtual "now"
        #: around each dispatch, so per-packet times from the engine land
        #: on the service's single virtual timeline)
        self.virtual_base = 0.0
        self._next_id = 0
        self._stack: List[Span] = []
        self._wall0 = time.perf_counter()

    # ------------------------------------------------------------------ #
    def _wall(self) -> float:
        return time.perf_counter() - self._wall0

    def begin(self, name: str, *, t_virtual: float = 0.0,
              ticket: Optional[Any] = None,
              parent: Optional[Span] = None, **attrs) -> Span:
        """Open a span.  ``parent`` defaults to the top of the parent
        stack (see :meth:`push`); pass it explicitly to override."""
        if parent is None and self._stack:
            parent = self._stack[-1]
        span = Span(span_id=self._next_id, name=name, process=self.process,
                    t0_virtual=float(t_virtual), t0_wall=self._wall(),
                    parent_id=None if parent is None else parent.span_id,
                    ticket=ticket, attrs=dict(attrs))
        self._next_id += 1
        self.spans.append(span)
        return span

    def end(self, span: Span, *, t_virtual: Optional[float] = None,
            status: str = STATUS_OK, note: Optional[str] = None):
        """Close a span with a terminal status (idempotent: a span
        already closed keeps its first verdict — the error path wins
        races with a later bulk cleanup)."""
        if span.status != STATUS_OPEN:
            return
        span.t1_virtual = (span.t0_virtual if t_virtual is None
                           else float(t_virtual))
        span.t1_wall = self._wall()
        span.status = status
        if note is not None:
            span.attrs["note"] = note

    def event(self, name: str, *, t_virtual: float = 0.0,
              ticket: Optional[Any] = None,
              parent: Optional[Span] = None, **attrs) -> Span:
        """Record an instantaneous mark (a zero-duration closed span)."""
        span = self.begin(name, t_virtual=t_virtual, ticket=ticket,
                          parent=parent, **attrs)
        span.kind = "event"
        self.end(span, t_virtual=t_virtual)
        return span

    # ------------------------------------------------------------------ #
    def push(self, span: Span):
        """Make ``span`` the implicit parent of spans opened until the
        matching :meth:`pop` — how the front-end's dispatch span becomes
        the parent of engine-side packet spans."""
        self._stack.append(span)

    def pop(self) -> Optional[Span]:
        """Undo the matching :meth:`push`."""
        return self._stack.pop() if self._stack else None

    def open_spans(self) -> List[Span]:
        """Spans never closed — must be empty after a clean drain."""
        return [s for s in self.spans if s.status == STATUS_OPEN]

    # ------------------------------- export --------------------------- #
    def records(self) -> List[Dict[str, Any]]:
        """Every span as a schema-versioned record, in open order."""
        return [s.to_record() for s in self.spans]

    def save_jsonl(self, path):
        """Write this tracer's records as JSONL."""
        save_jsonl(self.records(), path)

    def chrome_trace(self) -> Dict[str, Any]:
        """This tracer's records as Chrome-trace JSON (dict)."""
        return chrome_from_records(self.records())

    def save_chrome(self, path):
        """Write this tracer's records as a Chrome-trace file."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


# ---------------------------- record helpers ----------------------------- #
def save_jsonl(records: Iterable[Dict[str, Any]], path):
    """Write span records as JSONL (one JSON object per line)."""
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")


def save_chrome(records: Sequence[Dict[str, Any]], path):
    """Write records as a Chrome-trace JSON file (see
    :func:`chrome_from_records`)."""
    with open(path, "w") as f:
        json.dump(chrome_from_records(records), f)


def load_jsonl(path) -> List[Dict[str, Any]]:
    """Read a JSONL trace file back into records."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def validate_records(records: Sequence[Dict[str, Any]]) -> List[str]:
    """Schema-check span records; returns a list of problems (empty ==
    valid).  Checks field presence/types, status values, parent links
    resolving within the same process, and flags leaked ``open`` spans."""
    problems: List[str] = []
    by_proc: Dict[str, set] = {}
    for i, rec in enumerate(records):
        for field, types in _SCHEMA.items():
            if field not in rec:
                problems.append(f"record {i}: missing field {field!r}")
            elif not isinstance(rec[field], types):
                problems.append(
                    f"record {i}: field {field!r} has type "
                    f"{type(rec[field]).__name__}")
        if rec.get("schema") != SCHEMA_VERSION:
            problems.append(f"record {i}: schema != {SCHEMA_VERSION}")
        if rec.get("status") not in (STATUS_OPEN, STATUS_OK, STATUS_ERROR):
            problems.append(f"record {i}: bad status {rec.get('status')!r}")
        if rec.get("status") == STATUS_OPEN:
            problems.append(
                f"record {i}: leaked open span {rec.get('name')!r}")
        by_proc.setdefault(rec.get("process", ""), set()).add(
            rec.get("span_id"))
    for i, rec in enumerate(records):
        pid = rec.get("parent_id")
        if pid is not None and pid not in by_proc.get(
                rec.get("process", ""), ()):
            problems.append(f"record {i}: dangling parent_id {pid}")
    return problems


def validate_file(path) -> List[str]:
    """Schema-check a JSONL trace file (see :func:`validate_records`)."""
    return validate_records(load_jsonl(path))


def comparable_records(records: Sequence[Dict[str, Any]], *,
                       exclude_attrs: Sequence[str] = (),
                       virtual: bool = True) -> List[Dict[str, Any]]:
    """Strip nondeterministic fields for run-to-run / cross-backend
    comparison: wall stamps always; virtual stamps too when
    ``virtual=False`` (the spmd backend's "virtual" time is wall-derived);
    plus any backend-tagged ``attrs`` keys in ``exclude_attrs``."""
    out = []
    for rec in records:
        r = {k: v for k, v in rec.items() if k not in WALL_FIELDS}
        if not virtual:
            r.pop("t0_virtual", None)
            r.pop("t1_virtual", None)
        r["attrs"] = {k: v for k, v in rec.get("attrs", {}).items()
                      if k not in exclude_attrs}
        out.append(r)
    return out


def chrome_from_records(records: Sequence[Dict[str, Any]]
                        ) -> Dict[str, Any]:
    """Records -> Chrome-trace JSON (the ``traceEvents`` format Perfetto
    and ``chrome://tracing`` load).  Spans map to complete ("X") events
    and instantaneous marks to "i" events, on the *virtual* time axis
    (microseconds); ``pid`` is the emitting process and ``tid`` groups by
    grid node when known, else by ticket."""
    events: List[Dict[str, Any]] = []
    for rec in records:
        t0 = float(rec["t0_virtual"]) * 1e6
        tid = rec["attrs"].get("node")
        if tid is None:
            t = rec["ticket"]
            # string tickets (lease keys) share one lane; args keep the key
            tid = t if isinstance(t, int) else (0 if t is None else -1)
        args = dict(rec["attrs"])
        args["status"] = rec["status"]
        if rec["ticket"] is not None:
            args["ticket"] = rec["ticket"]
        base = {"name": rec["name"], "pid": rec["process"],
                "tid": int(tid), "ts": t0, "cat": rec["name"],
                "args": args}
        if rec["kind"] == "event":
            events.append({**base, "ph": "i", "s": "t"})
        else:
            t1 = rec["t1_virtual"]
            dur = 0.0 if t1 is None else max(0.0, float(t1) * 1e6 - t0)
            events.append({**base, "ph": "X", "dur": dur})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": SCHEMA_VERSION}}
