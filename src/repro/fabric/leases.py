"""Fleet-wide single-flight execution: scan-intent leases over the bus.

The fabric dedups *results* (the shared L2) but not *work*: two
front-ends holding the same canonical query in the same dispatch window
each run a full scan and only then discover the duplicate in the cache.
Under DIAL-style near-duplicate interactive traffic that is the largest
remaining waste at the service tier.  This module closes it with a
single-flight protocol:

- Before dispatching a scan, a front-end **announces a scan intent** on
  the bus (topic :data:`LEASE_TOPIC`), keyed on the SAME canonical
  expression + dataset-epoch keyspace as L1/L2 — the key embeds the
  version-vector fingerprint (``shared_cache.py`` hygiene), so intents
  from different dataset epochs can never collide.
- Every front-end folds received intents into a lease table keyed by
  announcement **priority** ``(bus round, node id)``: the earliest
  announcement wins, and the deterministic bus order (node ids) breaks
  same-round ties — so N simultaneous duplicate submissions resolve to
  exactly ONE lease owner with no extra round trips.
- At dispatch time a front-end that would run an equal scan but sees a
  fresh remote lease **adopts** the owner's in-flight
  :class:`~repro.service.streaming.ResultStream` instead, via the
  existing ``fanout.py`` buffered-prefix replay — a bit-identical
  stream with zero brick I/O.  The owner exports one lease stream per
  won key (whole queries AND materialized fragments, so a lease on a
  shared conjunct turns sibling queries equal to it into fragment
  adoptions).
- Intents are **re-announced every fabric round** (cumulative and
  idempotent, like gossip digests), so drops and healed partitions only
  delay convergence.  A lease therefore carries a **TTL in bus rounds
  tied to the gossip propagation bound** (:func:`lease_ttl`): an owner
  that dies or is banned (PR 7 policy) stops refreshing, the lease
  expires, and the adoptee falls back to the shared cache first (the
  owner's completed result is reachable in-process even when the bus is
  partitioned) and to its own scan only on a miss — never losing a
  final, never surfacing an adopted partial as one.

All lease traffic emits ``lease.*`` metrics and ``lease_adopt`` /
``lease_fallback`` trace events through the observability plane when one
is installed (``obs=None`` disables the whole plane, as everywhere).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.fabric.bus import MessageBus
from repro.fabric.gossip import VersionVector, rounds_bound

LEASE_TOPIC = "lease"


def lease_ttl(n_frontends: int, fanout: Optional[int] = None,
              delay: int = 0) -> int:
    """Default lease TTL in bus rounds for a fleet of ``n_frontends``.

    An alive owner refreshes its intents every fabric round, so a lease
    only expires when refreshes stop arriving.  The TTL must ride out
    one full anti-entropy cycle plus the bus latency (re-announcements
    sent at round ``r`` land at ``r + 1 + delay``), with one extra cycle
    of slack for seeded drops: ``2 * rounds_bound + 2 * (1 + delay)``.
    Shorter values make failover snappier but risk expiring a healthy
    owner on a lossy bus; longer values only delay fallback."""
    return 2 * rounds_bound(n_frontends, fanout) + 2 * (1 + delay)


def lease_key(canonical: str, calib_iters: int, vv: VersionVector) -> str:
    """The fleet-wide lease key: canonical expression + calibration +
    version-vector fingerprint (the L1/L2 keyspace, epoch-disambiguated
    the way ``SharedCacheTier`` keys are).  Two front-ends build the
    same key only when they agree on BOTH the query structure and the
    dataset epoch vector, so an adopted stream can never cross epochs."""
    fp = ",".join(f"{o}:{int(n)}" for o, n in sorted(vv.items()) if n)
    return f"lease:{canonical}|c{int(calib_iters)}|{fp}"


@dataclasses.dataclass
class LeaseRecord:
    """One entry of a front-end's lease table: the winning announcement
    for a key.  ``round`` is the announcement's ORIGINAL bus round (the
    priority — re-announcements never improve it), ``last_seen`` the
    round the owner's latest refresh was observed (freshness for the
    TTL), and ``fp`` the owner's version-vector fingerprint at announce
    time (stale-epoch guard)."""
    key: str
    owner: str
    round: int
    last_seen: int
    fp: str

    @property
    def priority(self) -> Tuple[int, str]:
        """Total order over competing announcements: earliest round
        first, deterministic node-id order breaking same-round ties."""
        return (self.round, self.owner)


@dataclasses.dataclass
class LeaseStats:
    """Monotonic per-front-end lease counters: intents announced, leases
    won (export streams created), remote leases adopted, releases sent,
    records expired by TTL, revocations applied, and adoptions that fell
    back (cache re-probe or rescan)."""
    announced: int = 0
    acquired: int = 0
    adopted: int = 0
    released: int = 0
    expired: int = 0
    revoked: int = 0
    fallbacks: int = 0


class LeaseManager:
    """One front-end's lease endpoint: intent announcer, lease table,
    and export registry for streams this front-end serves to adoptees.

    The Fleet wires one manager per front-end (``single_flight=True``),
    shares the gossip node's version vector via ``vv_source``, injects
    the front-end's :class:`~repro.fabric.fanout.StreamFanout` on
    :attr:`fanout` (adoptees proxy through it), and dispatches
    :data:`LEASE_TOPIC` bus messages to :meth:`on_message` while calling
    :meth:`emit` every fabric round.  The :class:`QueryService` consumes
    the manager at submit time (:meth:`announce`), dispatch time
    (:meth:`holder` / :meth:`export`) and resolution time
    (:meth:`release`)."""

    def __init__(self, node_id: str, bus: MessageBus,
                 vv_source: Callable[[], VersionVector], *,
                 ttl: int = 8, obs=None):
        if ttl < 1:
            raise ValueError("ttl must be at least one bus round")
        self.node_id = node_id
        self.bus = bus
        self.vv_source = vv_source
        self.ttl = ttl
        self.obs = obs
        self.stats = LeaseStats()
        #: the front-end's StreamFanout (Fleet-wired); adoptions proxy
        #: remote lease streams through it
        self.fanout = None
        #: flight-recorder scope (repro.obs.flight.FlightScope); None =
        #: off.  Records announce/grant/expire/release/revoke and the
        #: front-end's adopt/fallback transitions.
        self.flight = None
        #: streams this front-end exports for keys it won, readable by
        #: any adoptee through the fan-out resolve hook
        self.exports: Dict[str, object] = {}
        self._table: Dict[str, LeaseRecord] = {}
        self._intents: Dict[str, LeaseRecord] = {}
        self._released: Dict[str, int] = {}  # own: key -> release round
        self._peer_released: Dict[str, int] = {}  # peers': key -> round

    # --------------------------- keyspace ------------------------------ #
    def current_fp(self) -> str:
        """Fingerprint of this front-end's current epoch version vector
        (the stale-lease guard compares records against it)."""
        vv = self.vv_source()
        return ",".join(f"{o}:{int(n)}" for o, n in sorted(vv.items())
                        if n)

    def key_for(self, canonical: str, calib_iters: int) -> str:
        """The lease key of one canonical query at the CURRENT epoch."""
        return lease_key(canonical, calib_iters, self.vv_source())

    # --------------------------- announcer ----------------------------- #
    def announce(self, canonical: str, calib_iters: int) -> str:
        """Announce (idempotently) a scan intent for one canonical query
        at the current epoch; returns the lease key.  The intent is
        broadcast now and re-broadcast every :meth:`emit` until
        withdrawn or released, so drops only delay propagation."""
        key = self.key_for(canonical, calib_iters)
        if key in self._intents:
            return key
        rec = LeaseRecord(key=key, owner=self.node_id,
                          round=self.bus.round, last_seen=self.bus.round,
                          fp=self.current_fp())
        self._intents[key] = rec
        if self.flight is not None:
            self.flight.record("lease_announce", key=key,
                               round=self.bus.round)
        self._merge(rec)
        self._broadcast_intent(rec)
        self.stats.announced += 1
        if self.obs is not None:
            self.obs.metrics.counter("lease.announced").inc()
        return key

    def intends(self, key: str) -> bool:
        """True while this front-end has an active intent for ``key`` —
        the fan-out's ``defer`` predicate: an adoptee's sub arriving
        before our window dispatches is parked, not aborted (the export
        is coming)."""
        return key in self._intents

    def withdraw(self, key: str) -> None:
        """Stop re-announcing an intent (the loser's move on adopting a
        remote lease).  The local table keeps the winner's record; no
        message is needed — peers only ever treated the winner as the
        holder."""
        self._intents.pop(key, None)

    def emit(self) -> None:
        """One fabric round of lease anti-entropy: refresh and
        re-broadcast every active intent (cumulative, idempotent — the
        gossip-digest discipline), drop own intents announced under a
        superseded epoch fingerprint (peers' ``holder`` ignores them
        anyway — keeping them would re-broadcast dead keys forever), and
        garbage-collect exports whose lease was released more than one
        TTL ago (late adoptees past that point fall back to the shared
        cache)."""
        fp_now = self.current_fp()
        for key in [k for k, r in self._intents.items()
                    if r.fp != fp_now]:
            self._intents.pop(key, None)
            rec = self._table.get(key)
            if rec is not None and rec.owner == self.node_id:
                del self._table[key]
        for rec in self._intents.values():
            rec.last_seen = self.bus.round
            mine = self._table.get(rec.key)
            if mine is not None and mine.owner == self.node_id:
                mine.last_seen = self.bus.round
            self._broadcast_intent(rec)
        for key, rnd in list(self._released.items()):
            if self.bus.round - rnd > self.ttl:
                self._released.pop(key, None)
                self.exports.pop(key, None)
        for key, rnd in list(self._peer_released.items()):
            if self.bus.round - rnd > self.ttl:
                self._peer_released.pop(key, None)

    def _broadcast_intent(self, rec: LeaseRecord) -> None:
        self.bus.broadcast(self.node_id, LEASE_TOPIC,
                           {"kind": "intent", "key": rec.key,
                            "owner": rec.owner, "round": rec.round,
                            "fp": rec.fp})

    # ----------------------------- table ------------------------------- #
    def _merge(self, rec: LeaseRecord) -> None:
        cur = self._table.get(rec.key)
        if cur is None or rec.priority < cur.priority:
            self._table[rec.key] = rec
            if self.flight is not None and (cur is None
                                            or cur.owner != rec.owner):
                self.flight.record("lease_grant", key=rec.key,
                                   owner=rec.owner, round=rec.round)
        elif rec.owner == cur.owner:
            cur.last_seen = max(cur.last_seen, rec.last_seen)

    def holder(self, key: str) -> Optional[str]:
        """The node id currently holding the lease on ``key``, or None.

        A record is usable only while FRESH (refreshed within
        :attr:`ttl` bus rounds — a dead owner stops refreshing and the
        lease expires here) and CURRENT (announced under this
        front-end's present epoch fingerprint — a dataset bump makes
        pre-bump leases invisible, so an adoptee can never attach to a
        stale-epoch stream)."""
        rec = self._table.get(key)
        if rec is None:
            return None
        if self.bus.round - rec.last_seen > self.ttl:
            del self._table[key]
            self._intents.pop(key, None)
            self.stats.expired += 1
            if self.flight is not None:
                self.flight.record("lease_expire", key=key,
                                   owner=rec.owner, round=self.bus.round)
            if self.obs is not None:
                self.obs.metrics.counter("lease.expired").inc()
            return None
        if rec.fp != self.current_fp():
            return None
        return rec.owner

    def remote_holder(self, canonical: str,
                      calib_iters: int) -> Optional[str]:
        """The OTHER front-end holding a fresh lease on this canonical
        query at the current epoch, or None (no lease, expired, stale,
        or held by this front-end).  The scheduler's window-cost
        bounding uses this: a submission another front-end is already
        scanning costs ~0 against the window budget."""
        owner = self.holder(self.key_for(canonical, calib_iters))
        return owner if owner is not None and owner != self.node_id \
            else None

    def released_recently(self, key: str) -> bool:
        """True within one TTL of observing a peer's release of ``key``.
        A release means the owner COMPLETED the scan — the adoptee keeps
        waiting for the in-flight (or re-requested) final instead of
        falling back; past the TTL the marker expires and an adoption
        still incomplete falls back to the shared cache, where a
        completed owner's result is guaranteed to be."""
        rnd = self._peer_released.get(key)
        return rnd is not None and self.bus.round - rnd <= self.ttl

    def fp_current(self, fp: str) -> bool:
        """True while ``fp`` matches this front-end's present epoch
        fingerprint (resolution-time guard: an adoption whose epoch was
        bumped mid-stream must fall back, never serve)."""
        return fp == self.current_fp()

    # --------------------------- owner side ---------------------------- #
    def export(self, key: str, stream) -> None:
        """Register the :class:`~repro.service.streaming.ResultStream`
        this front-end serves for a lease it won; adoptees' ``sub``
        requests resolve to it through the fan-out (subs that arrived
        early and were parked are flushed now — they follow the scan
        live from its first packet)."""
        self.exports[key] = stream
        self.stats.acquired += 1
        if self.obs is not None:
            self.obs.metrics.counter("lease.acquired").inc()
        if self.fanout is not None:
            self.fanout.flush(key)

    def release(self, key: str) -> None:
        """Release one lease (the window that held it resolved): stop
        re-announcing, drop the table record, and broadcast the release
        so adoptees-in-waiting fall back promptly instead of waiting out
        the TTL.  The export stays readable for one TTL (late ``sub``
        requests still get the buffered replay + final) and is then
        garbage-collected by :meth:`emit`."""
        self._intents.pop(key, None)
        rec = self._table.get(key)
        if rec is not None and rec.owner == self.node_id:
            del self._table[key]
        if key in self.exports:
            self._released[key] = self.bus.round
        if self.flight is not None:
            self.flight.record("lease_release", key=key,
                               round=self.bus.round)
        self.bus.broadcast(self.node_id, LEASE_TOPIC,
                           {"kind": "release", "key": key,
                            "owner": self.node_id})
        self.stats.released += 1
        if self.obs is not None:
            self.obs.metrics.counter("lease.released").inc()

    def revoke_owner(self, owner: str) -> int:
        """Revoke every lease held by ``owner`` — the PR 7 policy
        consumption point: banning a front-end drops its leases
        fleet-wide immediately instead of waiting out the TTL.  Applies
        locally and broadcasts; returns the number of local records
        dropped."""
        dropped = self._apply_revoke(owner)
        self.bus.broadcast(self.node_id, LEASE_TOPIC,
                           {"kind": "revoke", "owner": owner})
        return dropped

    def _apply_revoke(self, owner: str) -> int:
        stale = [k for k, r in self._table.items() if r.owner == owner]
        for k in stale:
            del self._table[k]
        if stale:
            if self.flight is not None:
                self.flight.record("lease_revoke", owner=owner,
                                   dropped=len(stale))
            self.stats.revoked += len(stale)
            if self.obs is not None:
                self.obs.metrics.counter("lease.revoked").inc(len(stale))
        return len(stale)

    # --------------------------- dispatch ------------------------------ #
    def on_message(self, payload: dict) -> None:
        """Handle one :data:`LEASE_TOPIC` bus message (``intent``,
        ``release`` or ``revoke`` — see the module docstring for the
        protocol)."""
        kind = payload["kind"]
        if kind == "intent":
            self._merge(LeaseRecord(
                key=payload["key"], owner=payload["owner"],
                round=payload["round"], last_seen=self.bus.round,
                fp=payload["fp"]))
        elif kind == "release":
            rec = self._table.get(payload["key"])
            if rec is not None and rec.owner == payload["owner"]:
                del self._table[payload["key"]]
            # remember the release for one TTL: an adoptee seeing it
            # knows the owner FINISHED (its replayed final is in
            # flight), which is grounds to wait, not to fall back
            self._peer_released[payload["key"]] = self.bus.round
        elif kind == "revoke":
            self._apply_revoke(payload["owner"])

    def table(self) -> Dict[str, Tuple[str, int]]:
        """Read-only view of the lease table for tests and operators:
        ``key -> (owner, announce round)``."""
        return {k: (r.owner, r.round) for k, r in self._table.items()}

    def intents(self) -> List[str]:
        """The keys this front-end is currently announcing (own active
        scan intents, re-broadcast every :meth:`emit`)."""
        return sorted(self._intents)
