"""Fleet: N query front-ends joined into one coherent service by the
fabric.

The paper's scalability story ("freely adding ... any grid computing and
storage node") only holds if the *service* tier scales out too.  A
:class:`Fleet` stands up N :class:`~repro.service.frontend.QueryService`
front-ends over ONE shared brick store, each with its own catalogue view,
and wires them through the fabric's four mechanisms:

- a deterministic :class:`~repro.fabric.bus.MessageBus` simulating the
  inter-front-end network;
- :class:`~repro.fabric.gossip.GossipNode` epoch + liveness gossip, so a
  dataset bump or node death observed anywhere reaches every catalogue
  within :func:`~repro.fabric.gossip.rounds_bound` rounds;
- a :class:`~repro.fabric.shared_cache.SharedCacheTier` L2 under every
  front-end's L1, so whole-query and fragment results computed once are
  zero-I/O hits fleet-wide;
- a fleet-shared :class:`~repro.fabric.registry.FragmentRegistry`
  seeding every window's planner with cross-window hot fragments;
- :class:`~repro.fabric.fanout.StreamFanout` ticket routing, so a tenant
  can read any ticket's progressive stream from any front-end.

Tickets are fleet-global: :meth:`Fleet.submit` returns an id valid at
every front-end (``result``/``stream`` route to the owner), which is the
"any door" property interactive grids need from a load-balanced service
tier.
"""
from __future__ import annotations

import contextlib
import dataclasses
import pathlib
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.brick import BrickStore
from repro.core.catalog import MetadataCatalog
from repro.core.elastic import ElasticManager, MigrationPlan
from repro.fabric.bus import MessageBus
from repro.fabric.fanout import STREAM_TOPIC, StreamFanout
from repro.fabric.gossip import (GOSSIP_TOPIC, GossipNode, adaptive_fanout,
                                 rounds_bound)
from repro.fabric import leases as leases_lib
from repro.fabric.leases import LEASE_TOPIC, LeaseManager
from repro.fabric.registry import FragmentRegistry
from repro.fabric.shared_cache import SharedCacheTier, TieredResultCache
from repro.obs import (HealthMonitor, HealthReport, MetricsRegistry,
                       MetricsSnapshot, Observability, merge_snapshots)
from repro.obs import flight as flight_lib
from repro.obs import trace as trace_lib
from repro.service import streaming as streaming_lib
from repro.service.frontend import QUEUED, QueryService, Ticket
from repro.service.policy import FailurePolicy
from repro.service.scheduler import QueryScheduler


@dataclasses.dataclass
class Frontend:
    """One fleet member: the service plus its fabric endpoints (own
    catalogue view, gossip node, stream fan-out, and — under
    ``single_flight=True`` — the scan-intent lease manager).  ``alive``
    turns False on :meth:`Fleet.frontend_leave`: a dead front-end stops
    emitting/receiving and its leases expire by TTL."""
    index: int
    node_id: str
    service: QueryService
    catalog: MetadataCatalog
    gossip: GossipNode
    fanout: StreamFanout
    obs: Optional[Observability] = None
    leases: Optional[LeaseManager] = None
    alive: bool = True


class Fleet:
    """N coherent query front-ends over one brick store (see module doc).

    Parameters
    ----------
    store:
        The shared brick-sharded event store (the grid's storage fabric).
    n_frontends:
        Fleet width.
    bus:
        Injectable :class:`~repro.fabric.bus.MessageBus` (pass one with
        delay/drop/partition configured for fault experiments).
    shared_cache:
        ``True`` installs one :class:`SharedCacheTier` L2 under every
        front-end; ``False`` gives each front-end an independent L1 only
        (the A/B baseline the fabric benchmark measures against).
    registry:
        Fleet-shared :class:`FragmentRegistry`, or ``None`` for
        per-window planning only.
    backend:
        Execution backend every front-end dispatches on: ``"sim"``
        (default) or ``"spmd"`` — passed by name so each front-end
        constructs its own backend over its own catalogue view (see
        ``core/backend.py``).
    backend_kwargs:
        Tuning kwargs forwarded to every front-end's backend
        constructor — the SPMD performance knobs (``use_pallas``,
        ``interpret``, ``chunk_events``, ``adaptive_chunks``,
        ``mesh_devices``, ``autotune``, ``double_buffer``; see
        ``docs/backends.md``, "Performance tuning").
    gossip_fanout:
        Digest push targets per round; ``None`` (default) adapts to
        fleet size (``max(1, ceil(log2(n)))``).  The propagation bound
        is ``rounds_bound(n_frontends, gossip_fanout)``.
    scheduler_factory:
        Per-front-end :class:`QueryScheduler` constructor (schedulers
        hold queues and cannot be shared).
    service_kwargs:
        Extra keyword arguments applied to every ``QueryService`` (e.g.
        ``stream_ramp``, ``refit_cost_every``, ``use_cache``).
    obs:
        ``True`` stands up the observability plane: one
        :class:`~repro.obs.Observability` bundle per front-end (origin
        ``fe{i}``) wired through the service, its gossip node (health
        digests piggyback on epoch gossip), plus one fleet-level
        :class:`~repro.obs.MetricsRegistry` (origin ``fleet``) installed
        on the shared infrastructure — the bus and the L2 tier.  Default
        ``False`` keeps every hook at ``None`` (zero overhead).
    gossip_repair:
        ``True`` runs the gossip nodes in ack/repair mode (see
        ``fabric/gossip.py``): digests are acknowledged, unacked ones
        re-pushed, and acks from stale senders carry a push-pull reply —
        the hardening that keeps ``rounds_bound_lossy`` honest on a bus
        with sustained seeded loss.
    policy / policy_config:
        ``True`` gives every front-end a
        :class:`~repro.service.policy.FailurePolicy` over its own
        catalogue view (evidence arrives via the gossip-merged health
        digests, so a node banned from one front-end's evidence is soon
        banned fleet-wide).  Requires ``obs=True`` (the policy consumes
        health reports).  ``policy_config`` overrides the default
        :class:`~repro.service.policy.PolicyConfig` thresholds.
    single_flight / lease_ttl:
        ``True`` wires a :class:`~repro.fabric.leases.LeaseManager` into
        every front-end: scan intents are announced at submit, duplicate
        scans are adopted from the lease owner's in-flight stream
        (``fabric/leases.py``), and :meth:`step` pumps one bus
        round-trip before dispatching so same-round intents resolve to
        one owner first.  ``lease_ttl`` (bus rounds) overrides
        :func:`~repro.fabric.leases.lease_ttl`'s gossip-bound default.
    l2_path / l2_checkpoint_every:
        Operational L2 persistence: when ``l2_path`` names an existing
        file the shared tier boots from it (post-restart submissions hit
        with zero I/O), and the fleet checkpoints the tier back to the
        path on :meth:`close` plus every ``l2_checkpoint_every``
        :meth:`step` calls (0 = only on close).  Requires
        ``shared_cache=True`` to matter.
    flight:
        ``True`` (or an existing
        :class:`~repro.obs.flight.FlightRecorder`) arms the flight
        recorder: every driver call, bus send outcome/delivery, gossip
        epoch/liveness change, lease transition, policy decision,
        dispatch window and result digest is appended to a causal
        decision log (:attr:`flight`; write it with
        :meth:`save_flight`).  The log replays bit-identically through
        :func:`repro.obs.replay.replay_run`.  Independent of ``obs``
        and, like it, recorded in virtual time only — arming it leaves
        simulated makespans exactly unchanged.
    """

    def __init__(self, store: BrickStore, n_frontends: int = 2, *,
                 bus: Optional[MessageBus] = None,
                 shared_cache: bool = True,
                 l1_capacity: int = 256,
                 l2_capacity: int = 4096,
                 registry: Optional[FragmentRegistry] = None,
                 backend: str = "sim",
                 backend_kwargs: Optional[dict] = None,
                 gossip_fanout: Optional[int] = None,
                 scheduler_factory: Optional[
                     Callable[[], QueryScheduler]] = None,
                 service_kwargs: Optional[dict] = None,
                 obs: bool = False,
                 gossip_repair: bool = False,
                 policy: bool = False,
                 policy_config=None,
                 single_flight: bool = False,
                 lease_ttl: Optional[int] = None,
                 l2_path: Optional[Union[str, pathlib.Path]] = None,
                 l2_checkpoint_every: int = 0,
                 flight: Union[bool, flight_lib.FlightRecorder] = False):
        if n_frontends < 1:
            raise ValueError("need at least one front-end")
        if policy and not obs:
            raise ValueError(
                "policy=True requires obs=True (the failure policy "
                "consumes the health plane's reports)")
        self.store = store
        self.bus = bus or MessageBus()
        self.single_flight = single_flight
        self.l2_path = pathlib.Path(l2_path) if l2_path is not None else None
        self.l2_checkpoint_every = l2_checkpoint_every
        self._steps_since_ckpt = 0
        if shared_cache and self.l2_path is not None \
                and self.l2_path.exists():
            # boot from the last checkpoint: results computed before the
            # restart are zero-I/O hits immediately
            self.l2 = SharedCacheTier.load(self.l2_path)
        else:
            self.l2 = SharedCacheTier(l2_capacity) if shared_cache else None
        self.fleet_metrics: Optional[MetricsRegistry] = None
        if obs:
            self.fleet_metrics = MetricsRegistry(origin="fleet")
            self.bus.metrics = self.fleet_metrics
            if self.l2 is not None:
                self.l2.metrics = self.fleet_metrics
        #: the armed FlightRecorder, or None (``flight=`` parameter)
        self.flight: Optional[flight_lib.FlightRecorder] = None
        self._flight_depth = 0   # nested driver ops record only the outer
        self._flight_finals: set = set()  # gtids whose final is recorded
        if flight:
            self.flight = (flight
                           if isinstance(flight, flight_lib.FlightRecorder)
                           else flight_lib.FlightRecorder())
            self.bus.flight = self.flight.scoped("bus")
        self.registry = registry
        self.backend = backend
        self.gossip_fanout = (gossip_fanout if gossip_fanout is not None
                              else adaptive_fanout(n_frontends))
        self.frontends: List[Frontend] = []
        self._tickets: Dict[int, Tuple[int, int]] = {}  # gtid -> (fe, tid)
        self._by_local: Dict[Tuple[int, int], int] = {}  # (fe, tid) -> gtid
        self._next_gtid = 0
        self._rr = 0
        kwargs = dict(service_kwargs or {})
        kwargs.setdefault("backend", backend)
        if backend_kwargs:
            # per-frontend backends share the tuning knobs (autotune
            # winners are cached process-wide, so frontends share sweeps)
            kwargs.setdefault("backend_kwargs", dict(backend_kwargs))
        for i in range(n_frontends):
            node_id = f"fe{i}"
            catalog = MetadataCatalog(store.n_nodes)
            # gossip BEFORE the cache: both register catalogue bump hooks,
            # and on a local bump the gossip hook must credit the version
            # vector first so the cache's hook forwards the already-updated
            # vector to the shared tier
            gossip = GossipNode(node_id, catalog, self.bus,
                                fanout=self.gossip_fanout,
                                repair=gossip_repair)
            cache = TieredResultCache(l1_capacity, catalog=catalog,
                                      l2=self.l2,
                                      vv_source=lambda g=gossip: g.vv)
            fe_obs = Observability(origin=node_id) if obs else None
            if fe_obs is not None:
                # health digests ride the gossip digest; gossip counters
                # land in the front-end's own registry
                gossip.health = fe_obs.health
                gossip.metrics = fe_obs.metrics
            pol = None
            if policy:
                pol = FailurePolicy(catalog, store, obs=fe_obs,
                                    config=policy_config)
            lease_mgr = None
            if single_flight:
                ttl = (lease_ttl if lease_ttl is not None
                       else leases_lib.lease_ttl(n_frontends,
                                                 self.gossip_fanout,
                                                 self.bus.delay))
                lease_mgr = LeaseManager(node_id, self.bus,
                                         lambda g=gossip: g.vv,
                                         ttl=ttl, obs=fe_obs)
            svc = QueryService(
                store, catalog, cache=cache,
                scheduler=scheduler_factory() if scheduler_factory else None,
                registry=registry, frontend_id=node_id, obs=fe_obs,
                policy=pol, leases=lease_mgr, **kwargs)
            fanout = StreamFanout(
                node_id, self.bus,
                lambda key, idx=i: self._resolve_stream(key, idx))
            if lease_mgr is not None:
                # adoptees proxy remote lease streams through the same
                # fan-out that serves cross-front-end ticket reads; subs
                # for leases we announced but have not dispatched yet are
                # parked, not aborted (the export is coming)
                lease_mgr.fanout = fanout
                fanout.defer = lease_mgr.intends
            if self.flight is not None:
                scope = self.flight.scoped(node_id)
                gossip.flight = scope
                svc.scheduler.flight = scope
                if pol is not None:
                    pol.flight = scope
                if lease_mgr is not None:
                    lease_mgr.flight = scope
            self.frontends.append(Frontend(i, node_id, svc, catalog,
                                           gossip, fanout, fe_obs,
                                           lease_mgr))
        if self.flight is not None:
            safe_kwargs = {k: v for k, v in (service_kwargs or {}).items()
                           if isinstance(v, (bool, int, float, str,
                                             type(None)))}
            self.flight.record(
                "run_header", origin="fleet",
                n_frontends=n_frontends, backend=backend,
                shared_cache=shared_cache, l1_capacity=l1_capacity,
                l2_capacity=l2_capacity, registry=registry is not None,
                gossip_fanout=self.gossip_fanout,
                gossip_repair=gossip_repair, obs=obs, policy=policy,
                policy_config=policy_config is not None,
                single_flight=single_flight, lease_ttl=lease_ttl,
                scheduler_factory=scheduler_factory is not None,
                l2_path=self.l2_path is not None,
                service_kwargs=safe_kwargs,
                bus_delay=self.bus.delay,
                bus_drop_rate=self.bus.drop_rate)

    # ------------------------------------------------------------------ #
    @property
    def n_frontends(self) -> int:
        """Fleet width."""
        return len(self.frontends)

    @property
    def rounds_bound(self) -> int:
        """Documented gossip propagation bound for this fleet's shape."""
        return rounds_bound(self.n_frontends, self.gossip_fanout)

    def policy_states(self) -> Dict[str, Dict[int, str]]:
        """Per-frontend failure-policy states (``fe id -> {node: state}``);
        empty dict when the fleet was built without ``policy=True``.  Each
        front-end judges independently from its gossip-merged health view,
        so entries can disagree transiently until evidence converges."""
        out: Dict[str, Dict[int, str]] = {}
        for fe in self.frontends:
            pol = fe.service.policy
            if pol is not None:
                out[fe.node_id] = pol.states()
        return out

    def _resolve_stream(self, key: Union[int, str],
                        fe_index: int
                        ) -> Optional[streaming_lib.ResultStream]:
        fe = self.frontends[fe_index]
        if isinstance(key, str):
            # lease keys are strings; integer keys are global ticket ids
            if fe.leases is None:
                return None
            return fe.leases.exports.get(key)
        owner = self._tickets.get(key)
        if owner is None or owner[0] != fe_index:
            return None
        return fe.service.streams.get(owner[1])

    def _owner(self, gtid: int) -> Tuple[Frontend, int]:
        fe_idx, tid = self._tickets[gtid]
        return self.frontends[fe_idx], tid

    def owner_of(self, gtid: int) -> int:
        """Index of the front-end that owns a global ticket (KeyError if
        the id was never issued)."""
        return self._tickets[gtid][0]

    # -------------------------- flight plumbing ----------------------- #
    @contextlib.contextmanager
    def _flight_op(self, op: str, **fields):
        # Record one driver op and make it the causal parent of every
        # record appended while it runs.  Internal nesting (drain->step->
        # pump) records only the OUTERMOST op: replay re-issues driver
        # calls verbatim, so inner calls replay themselves.
        fl = self.flight
        outer = fl is not None and self._flight_depth == 0
        self._flight_depth += 1
        rec = None
        if outer:
            rec = fl.record("op", origin="fleet", op=op, **fields)
            fl.push(rec["eid"])
        try:
            yield rec
        finally:
            if outer:
                fl.pop()
            self._flight_depth -= 1

    def _flight_finalize(self) -> None:
        # Append one "final" digest record per newly resolved ticket, in
        # gtid order — the bit-identity surface replay compares.
        fl = self.flight
        if fl is None:
            return
        for gtid in sorted(self._tickets):
            if gtid in self._flight_finals:
                continue
            fe_idx, tid = self._tickets[gtid]
            t = self.frontends[fe_idx].service.tickets[tid]
            if t.status == QUEUED:
                continue
            self._flight_finals.add(gtid)
            fl.record("final", origin="fleet", gtid=gtid, status=t.status,
                      adopted=t.adopted, cached=t.from_cache,
                      digest=(None if t.result is None
                              else flight_lib.result_digest(t.result)))

    def save_flight(self, path) -> int:
        """Write the flight-recorder log as JSONL (records any
        still-unrecorded finals first); returns records written.
        Raises RuntimeError when the fleet was built without
        ``flight=``."""
        if self.flight is None:
            raise RuntimeError("fleet was built without flight=")
        self._flight_finalize()
        self.flight.save_jsonl(path)
        return len(self.flight.records)

    # ------------------------------------------------------------------ #
    def submit(self, expr: str, *, tenant: str = "default",
               calib_iters: int = 0, stream: bool = False,
               frontend: Optional[int] = None) -> int:
        """Submit to one front-end (round-robin over LIVE front-ends when
        ``frontend`` is None); returns a fleet-global ticket id usable at
        any front-end."""
        with self._flight_op("submit", expr=expr, tenant=tenant,
                             calib_iters=calib_iters, stream=stream,
                             frontend=frontend, gtid=None) as oprec:
            if frontend is None:
                for _ in range(self.n_frontends):
                    idx = self._rr % self.n_frontends
                    self._rr += 1
                    if self.frontends[idx].alive:
                        frontend = idx
                        break
                if frontend is None:
                    raise RuntimeError("no live front-ends")
            fe = self.frontends[frontend]
            tid = fe.service.submit(expr, tenant=tenant,
                                    calib_iters=calib_iters, stream=stream)
            gtid = self._next_gtid
            self._next_gtid += 1
            self._tickets[gtid] = (frontend, tid)
            self._by_local[(frontend, tid)] = gtid
            if oprec is not None:
                # patch in the resolved routing so replay re-targets the
                # same front-end without re-running the round-robin
                oprec["frontend"] = frontend
                oprec["gtid"] = gtid
            if stream and self.flight is not None:
                rs = fe.service.streams.get(tid)
                if rs is not None:
                    fl = self.flight
                    rs.subscribe(lambda snap, g=gtid: fl.record(
                        "stream_snapshot", origin="fleet", gtid=g,
                        seq=snap.seq, final=bool(snap.final),
                        digest=flight_lib.result_digest(snap.result)))
            return gtid

    def result(self, gtid: int) -> Ticket:
        """Ticket lookup routed to the owning front-end (the control
        plane is catalogue-backed, hence visible from any door)."""
        fe, tid = self._owner(gtid)
        return fe.service.result(tid)

    def stream(self, gtid: int, *,
               frontend: Optional[int] = None
               ) -> streaming_lib.ResultStream:
        """The ticket's progressive stream, read from ``frontend`` (the
        owner by default).  A non-owner front-end returns a proxy stream
        fed over the bus — call :meth:`pump` (or :meth:`step`) to move
        snapshots; the proxy honours every local-streaming guarantee (see
        ``fabric/fanout.py``)."""
        fe, tid = self._owner(gtid)
        if frontend is None or frontend == fe.index:
            return fe.service.stream(tid)
        with self._flight_op("stream", gtid=gtid, frontend=frontend):
            # cross-frontend read: the proxy subscription talks over the
            # bus, so the op must be in the log for replay to re-issue it
            return self.frontends[frontend].fanout.proxy(gtid, fe.node_id)

    # ------------------------------------------------------------------ #
    def pump(self, rounds: int = 1) -> None:
        """Advance the fabric ``rounds`` network rounds: every live
        front-end's gossip node pushes its digest (and its lease manager
        re-announces intents, under ``single_flight``), the bus ticks,
        delivered messages are dispatched to their topic handlers, and
        pending stream adoptions are polled.  Dead front-ends
        (:meth:`frontend_leave`) emit nothing; their inboxes are drained
        and discarded so in-flight accounting still quiesces."""
        fl = self.flight
        with self._flight_op("pump", rounds=rounds):
            for _ in range(rounds):
                for fe in self.frontends:
                    if not fe.alive:
                        continue
                    fe.gossip.emit()
                    if fe.leases is not None:
                        fe.leases.emit()
                self.bus.tick()
                for fe in self.frontends:
                    if not fe.alive:
                        self.bus.recv(fe.node_id)  # discard: nobody home
                        continue
                    for env in self.bus.recv(fe.node_id):
                        if fl is not None:
                            # handler effects chain to the delivery that
                            # carried the message, not the pump op
                            fl.push(fl.deliver_cause(env.seq))
                        try:
                            if env.topic == GOSSIP_TOPIC:
                                fe.gossip.on_message(env.payload)
                            elif env.topic == STREAM_TOPIC:
                                fe.fanout.on_message(env.payload)
                            elif env.topic == LEASE_TOPIC \
                                    and fe.leases is not None:
                                fe.leases.on_message(env.payload)
                        finally:
                            if fl is not None:
                                fl.pop()
                for fe in self.frontends:
                    if fe.alive and fe.leases is not None:
                        fe.service.poll_adoptions()

    def step(self, frontend: Optional[int] = None, *,
             failure_script=None, pump_rounds: int = 1) -> List[int]:
        """Run one dispatch window on one (or every live) front-end, then
        pump the fabric; returns the GLOBAL ids of tickets served.  Under
        ``single_flight`` the fabric is pumped one bus round-trip BEFORE
        dispatch, so intents announced at submit time have resolved to
        one owner per duplicated canonical fleet-wide and the losers
        adopt instead of scanning."""
        with self._flight_op("step", frontend=frontend,
                             pump_rounds=pump_rounds,
                             scripted=failure_script is not None):
            if self.single_flight:
                self.pump(1 + self.bus.delay)
            targets = ([self.frontends[frontend]] if frontend is not None
                       else [fe for fe in self.frontends if fe.alive])
            served = []
            for fe in targets:
                for tid in fe.service.step(failure_script=failure_script):
                    served.append(self._by_local[(fe.index, tid)])
            self.pump(pump_rounds)
            if self.l2_checkpoint_every > 0 and self.l2 is not None \
                    and self.l2_path is not None:
                self._steps_since_ckpt += 1
                if self._steps_since_ckpt >= self.l2_checkpoint_every:
                    self._steps_since_ckpt = 0
                    self.l2.save(self.l2_path)
            return served

    def _busy(self) -> bool:
        return any(fe.alive and (fe.service.scheduler.n_pending > 0
                                 or fe.service.adoptions_pending)
                   for fe in self.frontends)

    def drain(self, *, max_windows: int = 10_000) -> None:
        """Dispatch windows on every front-end until no work is pending
        and no adoption is unresolved, pump until the stream fan-out
        traffic quiesces (all snapshots landed), then run one full
        anti-entropy cycle (``rounds_bound`` pumps) so every
        epoch/liveness fact observed before the drain is fleet-wide.
        Quiescence is judged on the stream topic only: every pump emits
        fresh gossip digests, so waiting for a fully idle bus would spin
        forever on a delayed bus.  The outer loop re-enters dispatch when
        the anti-entropy cycle itself creates work — e.g. a lease TTL
        expiry whose fallback requeued a scan."""
        with self._flight_op("drain", max_windows=max_windows):
            for _ in range(max_windows):
                for _ in range(max_windows):
                    if not self._busy():
                        break
                    self.step()
                guard = 0
                while self.bus.in_flight(STREAM_TOPIC) and guard < 1000:
                    self.pump()
                    guard += 1
                self.pump(self.rounds_bound)
                if not self._busy():
                    break
            self._flight_finalize()

    # ------------------------------------------------------------------ #
    def bump_dataset_version(self, frontend: int = 0) -> int:
        """Record a dataset change as observed by one front-end; gossip
        carries it to every peer within :attr:`rounds_bound` pumps."""
        with self._flight_op("bump", frontend=frontend):
            return self.frontends[frontend].catalog.bump_dataset_version()

    def node_leave(self, grid_node: int, *,
                   observed_by: int = 0) -> MigrationPlan:
        """Grid node death observed by one front-end: local failover via
        the ElasticManager, liveness gossip to every peer."""
        with self._flight_op("node_leave", grid_node=grid_node,
                             observed_by=observed_by):
            fe = self.frontends[observed_by]
            plan = ElasticManager(fe.catalog,
                                  self.store).node_leave(grid_node)
            fe.gossip.observe_liveness(grid_node, False)
            return plan

    def node_join(self, grid_node: int, *,
                  observed_by: int = 0) -> MigrationPlan:
        """Grid node (re)join observed by one front-end: local rebalance
        via the ElasticManager, liveness gossip to every peer."""
        with self._flight_op("node_join", grid_node=grid_node,
                             observed_by=observed_by):
            fe = self.frontends[observed_by]
            plan = ElasticManager(fe.catalog,
                                  self.store).node_join(grid_node)
            fe.gossip.observe_liveness(grid_node, True)
            return plan

    def frontend_leave(self, index: int) -> None:
        """Silent FRONT-END crash: the member stops emitting gossip and
        lease refreshes and stops receiving (its inbox is discarded).  No
        message is sent — peers find out the slow way: leases it held
        expire after one TTL, and adoptees of its streams fall back
        (shared cache first, own rescan on a miss).  Its own queued work
        is stranded, as a real crash strands it."""
        with self._flight_op("frontend_leave", index=index):
            self.frontends[index].alive = False

    def ban_frontend(self, index: int, *, by: int = 0) -> None:
        """Policy ban of a front-end (the PR 7 state machine's verdict
        applied at the service tier): the member leaves as in
        :meth:`frontend_leave`, AND front-end ``by`` broadcasts a lease
        revocation for it — adoptees fall back on the next pump instead
        of waiting out the TTL (the fast path for *known*-bad owners)."""
        with self._flight_op("ban_frontend", index=index, by=by):
            self.frontend_leave(index)
            observer = self.frontends[by]
            if observer.leases is not None:
                observer.leases.revoke_owner(self.frontends[index].node_id)

    # ------------------------------------------------------------------ #
    def fleet_stats(self) -> dict:
        """Aggregated service/cache counters across the fleet (plus the
        shared tier's own counters when enabled)."""
        agg = {"submitted": 0, "served": 0, "rejected": 0, "cache_hits": 0,
               "l2_hits": 0, "events_scanned": 0, "fragment_evals": 0,
               "adopted": 0, "lease_fallbacks": 0}
        for fe in self.frontends:
            s = fe.service.stats
            agg["submitted"] += s.submitted
            agg["served"] += s.served
            agg["rejected"] += s.rejected
            agg["cache_hits"] += s.cache_hits
            agg["events_scanned"] += s.events_scanned
            agg["fragment_evals"] += s.fragment_evals
            agg["adopted"] += s.adopted
            agg["lease_fallbacks"] += s.lease_fallbacks
            agg["l2_hits"] += fe.service.cache.stats.l2_hits
        agg["hit_rate"] = agg["cache_hits"] / max(1, agg["submitted"])
        if self.l2 is not None:
            agg["l2_entries"] = len(self.l2)
            agg["l2_fragment_puts"] = self.l2.stats.fragment_puts
        return agg

    # ------------------------- observability -------------------------- #
    def metrics_snapshot(self) -> Optional[MetricsSnapshot]:
        """Fleet-merged metrics: every front-end's registry plus the
        fleet-level registry (bus/L2 counters), combined through the same
        ``tree_merge`` machinery the result path uses.  ``None`` when the
        fleet was built without ``obs=True``."""
        snaps = [fe.obs.metrics.snapshot() for fe in self.frontends
                 if fe.obs is not None]
        if self.fleet_metrics is not None:
            snaps.append(self.fleet_metrics.snapshot())
        if not snaps:
            return None
        return merge_snapshots(snaps)

    def trace_records(self) -> List[dict]:
        """All front-ends' span/event records merged and ordered by
        virtual start time — one fleet-wide timeline (span ids stay
        unique per ``process``, which is how the schema scopes them)."""
        recs: List[dict] = []
        for fe in self.frontends:
            if fe.obs is not None:
                recs.extend(fe.obs.tracer.records())
        recs.sort(key=lambda r: (r["t0_virtual"], r["process"],
                                 r["span_id"]))
        return recs

    def save_trace_jsonl(self, path) -> int:
        """Write the fleet-merged JSONL trace; returns records written."""
        recs = self.trace_records()
        trace_lib.save_jsonl(recs, path)
        return len(recs)

    def save_chrome_trace(self, path) -> int:
        """Write the fleet-merged Chrome/Perfetto trace; returns records
        exported."""
        recs = self.trace_records()
        trace_lib.save_chrome(recs, path)
        return len(recs)

    def health_report(self) -> Optional[HealthReport]:
        """Fleet-wide node health: every front-end monitor's digest merged
        into one view (the converged picture gossip drives each member
        toward).  ``None`` without ``obs=True``."""
        monitors = [fe.obs.health for fe in self.frontends
                    if fe.obs is not None]
        if not monitors:
            return None
        agg = HealthMonitor(origin="fleet")
        for m in monitors:
            agg.merge_digest(m.digest())
        return agg.report()

    def close(self) -> None:
        """Shut the fleet down: checkpoint the L2 (when ``l2_path`` is
        configured), close every front-end's service (cache hooks
        detached) and detach every gossip node from its catalogue — a
        long-lived catalogue accumulates no dead hooks."""
        with self._flight_op("close"):
            self._flight_finalize()
            if self.l2 is not None and self.l2_path is not None:
                self.l2.save(self.l2_path)
            for fe in self.frontends:
                fe.service.close()
                fe.gossip.detach()
