"""Cross-front-end stream fan-out: ticket routing for progressive
results.

The streaming layer (PR 3) binds a ticket's
:class:`~repro.service.streaming.ResultStream` to the front-end that runs
its scan.  In a fleet, the tenant that submitted on front-end A may be
load-balanced to front-end B for reads — DIAL's "any door" interactive
rule — so B must be able to serve A's stream with the *same* delivery
guarantees as local streaming:

- snapshots arrive in publish order and are the same objects the local
  stream published (bit-identical progressive results);
- a remote reader that attaches mid-scan sees exactly what a local
  late reader would: the currently buffered snapshots, then live ones;
- ``final=True`` is forwarded only for the owner's final snapshot, and an
  owner-side abort arrives as an abort — a partial is NEVER surfaced as
  final, no matter what the bus dropped (a lost final leaves the proxy
  OPEN/incomplete rather than wrongly complete).

Protocol (all over the fabric bus, topic ``stream``): the reader's
front-end sends ``sub`` to the owner; the owner replays the buffered
prefix and subscribes the bus to future publishes, forwarding ``snap``
messages and a ``close`` on finish/abort.  The proxy is an ordinary
:class:`~repro.service.streaming.ResultStream`, so tenant code
(``poll``/``latest``/``subscribe``/iteration) is identical either way.
Out-of-order or duplicated snapshots (possible under exotic per-link
delays) are guarded by per-snapshot sequence numbers on the proxy side.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.fabric.bus import MessageBus
from repro.service import streaming as streaming_lib

STREAM_TOPIC = "stream"


@dataclasses.dataclass
class FanoutStats:
    """Monotonic fan-out counters per front-end: subscriptions served,
    snapshots forwarded/received, closes forwarded, and out-of-order
    snapshots discarded by a proxy."""
    subs_served: int = 0
    snaps_sent: int = 0
    snaps_received: int = 0
    closes_sent: int = 0
    stale_dropped: int = 0


class StreamFanout:
    """One front-end's fan-out endpoint: exporter for locally owned
    streams, proxy factory for remotely owned ones.

    ``resolve`` maps a fleet-level stream key to the local
    :class:`~repro.service.streaming.ResultStream` (or None), supplied by
    the Fleet; everything else is self-contained.
    """

    def __init__(self, node_id: str, bus: MessageBus,
                 resolve: Callable[[int],
                                   Optional[streaming_lib.ResultStream]],
                 *, proxy_capacity: int = 64):
        self.node_id = node_id
        self.bus = bus
        self.resolve = resolve
        self.proxy_capacity = proxy_capacity
        self.stats = FanoutStats()
        #: optional predicate over keys: True defers an unresolvable
        #: ``sub`` instead of aborting it (the stream is EXPECTED to
        #: appear — e.g. a lease this front-end announced but whose
        #: window has not dispatched yet); :meth:`flush` serves the
        #: parked subs once the stream exists
        self.defer: Optional[Callable[[object], bool]] = None
        self._proxies: Dict[int, streaming_lib.ResultStream] = {}
        self._proxy_seq: Dict[int, int] = {}  # last seq applied per proxy
        self._exports: Dict[Tuple[int, str], bool] = {}  # dedup subs
        self._pending_subs: Dict[int, List[str]] = {}  # key -> readers
        bus.register(node_id)

    # ---------------------------- reader side -------------------------- #
    def proxy(self, key: int, owner: str) -> streaming_lib.ResultStream:
        """Return (creating on first use) the local proxy stream for a
        ticket owned by ``owner``, and send the subscription request.  The
        proxy fills as bus rounds deliver; re-calls reuse one proxy."""
        if key in self._proxies:
            return self._proxies[key]
        proxy = streaming_lib.ResultStream(key,
                                           capacity=self.proxy_capacity)
        self._proxies[key] = proxy
        self._proxy_seq[key] = -1
        self.bus.send(self.node_id, owner, STREAM_TOPIC,
                      {"kind": "sub", "key": key, "reader": self.node_id})
        return proxy

    def resubscribe(self, key: int, owner: str) -> None:
        """Re-send the subscription for an existing proxy — the healing
        move when a partition/drop may have swallowed snapshots (or the
        original ``sub``) mid-adoption.  The owner replays its buffered
        prefix (and the final, if the stream already finished); the
        proxy's sequence guard discards whatever it already has, so
        re-subscribing is always safe."""
        if key in self._proxies:
            self.bus.send(self.node_id, owner, STREAM_TOPIC,
                          {"kind": "sub", "key": key,
                           "reader": self.node_id})

    # ---------------------------- owner side --------------------------- #
    def _export(self, key: int, reader: str) -> None:
        stream = self.resolve(key)
        if stream is None:
            if self.defer is not None and self.defer(key):
                # the stream is expected (an announced-but-undispatched
                # lease): park the sub; flush() serves it — live from
                # the first packet — once the export registers
                readers = self._pending_subs.setdefault(key, [])
                if reader not in readers:
                    readers.append(reader)
                return
            self.bus.send(self.node_id, reader, STREAM_TOPIC,
                          {"kind": "close", "key": key, "state": "ABORTED",
                           "note": f"no stream for ticket {key} on "
                                   f"{self.node_id}"})
            self.stats.closes_sent += 1
            return
        self.stats.subs_served += 1

        def forward(snap: streaming_lib.StreamSnapshot) -> None:
            self.bus.send(self.node_id, reader, STREAM_TOPIC,
                          {"kind": "snap", "key": key, "snap": snap})
            self.stats.snaps_sent += 1

        def closed(s: streaming_lib.ResultStream) -> None:
            self.bus.send(self.node_id, reader, STREAM_TOPIC,
                          {"kind": "close", "key": key, "state": s.state,
                           "note": s.note})
            self.stats.closes_sent += 1

        # ALWAYS replay what a local late reader would drain (a reader
        # that released its proxy and re-subscribed starts from seq -1
        # again, so it needs the prefix; a still-attached reader's proxy
        # discards the duplicates by sequence number), then follow live
        # publishes — but register the live listeners only once per
        # (ticket, reader) or every re-subscribe would duplicate them
        replayed = stream.buffered()
        for snap in replayed:
            forward(snap)
        if stream.closed:
            if (stream.done and not any(s.final for s in replayed)
                    and stream.latest() is not None):
                # local tenant already drained the final from the buffer;
                # a DONE stream must still hand the remote reader its final
                forward(stream.latest())
            closed(stream)
            return
        if not self._exports.get((key, reader)):
            self._exports[(key, reader)] = True
            stream.subscribe(forward)
            stream.on_close(closed)

    def flush(self, key: int) -> None:
        """Serve every sub parked on ``key`` (call when the key's stream
        has become resolvable): deferred readers subscribe live from the
        stream's first publish, exactly as if the sub had arrived after
        the export."""
        for reader in self._pending_subs.pop(key, []):
            self._export(key, reader)

    # ---------------------------- dispatch ----------------------------- #
    def on_message(self, payload: dict) -> None:
        """Handle one ``stream``-topic bus message (both directions)."""
        kind, key = payload["kind"], payload["key"]
        if kind == "sub":
            self._export(key, payload["reader"])
            return
        proxy = self._proxies.get(key)
        if proxy is None:
            return  # reader released the proxy; drop silently
        if kind == "snap":
            snap = payload["snap"]
            self.stats.snaps_received += 1
            if snap.seq <= self._proxy_seq[key] and not snap.final:
                self.stats.stale_dropped += 1  # reordered duplicate
                return
            self._proxy_seq[key] = max(self._proxy_seq[key], snap.seq)
            if snap.final:
                proxy.finish(snap)  # the ONLY path that closes as DONE
            else:
                proxy.publish(snap)
        elif kind == "close":
            if payload["state"] == streaming_lib.ABORTED:
                proxy.abort(payload.get("note", "owner aborted"))
            # a DONE close needs no action: finish() already ran when the
            # final snapshot arrived; if the final was lost in transit the
            # proxy deliberately stays OPEN (never fabricate a final)

    def release(self, key: int) -> None:
        """Drop a proxy (reader done); later messages for it are ignored."""
        self._proxies.pop(key, None)
        self._proxy_seq.pop(key, None)
