"""Epoch gossip + anti-entropy: fleet-wide cache invalidation with a
bounded propagation delay.

The single-process service invalidates its cache through the catalogue's
``bump_dataset_version()`` hook.  In a fleet, each front-end has its own
catalogue *view*, so a bump observed on one front-end must reach every
peer — otherwise a sibling keeps serving results computed over the old
dataset forever.  This module closes that loop with the classic
interactive-grid recipe (DIAL's shared metadata tier, Grid-enabled
database lessons): a small, periodic, idempotent digest exchange.

**Version vectors.**  Each front-end keeps a vector ``{origin: bumps}``
counting how many dataset bumps each fleet member has *originated*.  The
effective dataset epoch is the SUM of the vector's entries.  Summing (not
max-ing) is what makes reconciliation after a partition correct: if both
sides of a split bump once, the healed vector merges to both entries and
the effective epoch exceeds *each* side's partition-era epoch, so every
entry cached during the split is invalidated on every member.

**Propagation bound.**  Every gossip round, the node at index ``i`` of
the sorted peer list pushes its full digest to peers ``i+1 .. i+fanout``
(mod n).  Information therefore advances at least ``fanout`` ring
positions per round, giving the documented bound
:func:`rounds_bound` ``= ceil((n-1)/fanout)`` rounds from any bump to
fleet-wide visibility (loss-free bus; message drops only delay
convergence because digests are cumulative and idempotent).  The default
fanout is **adaptive to fleet size**: :func:`adaptive_fanout` ``=
max(1, ceil(log2(n)))``, so the bound scales as ``O(n / log n)`` rounds
while per-round traffic stays ``O(n log n)`` messages — a fixed constant
either floods small fleets or crawls on large ones.

**Anti-entropy.**  Digests always carry the full vector and the full
liveness map, never deltas.  A front-end that was partitioned needs no
special recovery path: the first digest it receives after healing carries
everything it missed, and :func:`rounds_bound` applies again from the
heal.

The same digest piggybacks grid-node liveness (a per-node monotonic
``(version, origin)`` stamp — highest wins, origin id breaking ties
between concurrent observations), so a ``node_leave`` observed by one
front-end reaches every peer's catalogue and redirects their packet
scheduling to surviving replicas within the same bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.catalog import MetadataCatalog
from repro.fabric.bus import MessageBus

GOSSIP_TOPIC = "gossip"

VersionVector = Dict[str, int]


def effective_epoch(vv: VersionVector) -> int:
    """Dataset epoch implied by a version vector: the sum of per-origin
    bump counts (see module docstring for why sum, not max)."""
    return sum(vv.values())


def merge_vv(mine: VersionVector, theirs: VersionVector) -> bool:
    """Element-wise max merge of ``theirs`` into ``mine`` (in place);
    returns True when ``mine`` changed."""
    changed = False
    for origin, n in theirs.items():
        if n > mine.get(origin, 0):
            mine[origin] = n
            changed = True
    return changed


def adaptive_fanout(n_frontends: int) -> int:
    """Default gossip fanout for a fleet of ``n``: ``max(1, ceil(log2(n)))``.

    Scales push width with fleet size so the propagation bound stays
    ``O(n / log n)`` rounds without flooding small fleets: n<=2 -> 1,
    3..4 -> 2, 5..8 -> 3, 9..16 -> 4, ...  Used whenever a fanout of
    ``None`` is passed (GossipNode, Fleet, :func:`rounds_bound`)."""
    if n_frontends <= 2:
        return 1
    return max(1, math.ceil(math.log2(n_frontends)))


def rounds_bound(n_frontends: int, fanout: Optional[int] = None) -> int:
    """Worst-case gossip rounds from a bump on any member to fleet-wide
    visibility on a loss-free bus: ``ceil((n-1)/fanout)``.

    ``fanout=None`` means the adaptive default
    (:func:`adaptive_fanout`), matching what a Fleet built without an
    explicit ``gossip_fanout`` actually pushes — e.g. n=16 gossips at
    fanout 4 and is fleet-wide within ``ceil(15/4) = 4`` rounds."""
    if n_frontends <= 1:
        return 0
    if fanout is None:
        fanout = adaptive_fanout(n_frontends)
    return math.ceil((n_frontends - 1) / max(1, fanout))


@dataclasses.dataclass
class GossipStats:
    """Monotonic gossip counters: digests sent/received, digests that
    changed local state, and epoch/liveness updates applied."""
    digests_sent: int = 0
    digests_received: int = 0
    digests_stale: int = 0       # received digests that taught us nothing
    epoch_updates: int = 0       # catalog epochs advanced by gossip
    liveness_updates: int = 0    # node alive/dead flips applied by gossip


class GossipNode:
    """One front-end's membership in the epoch-gossip protocol.

    Attaches to the front-end's catalogue: a local
    ``bump_dataset_version()`` (from any code path) is credited to this
    node's entry of the version vector via the catalogue's bump hook, and
    remote digests that advance the vector are applied back to the
    catalogue with ``set_dataset_epoch`` — which fires the same hook
    chain, so the front-end's result cache invalidates exactly as it
    would for a local bump.

    Call :meth:`emit` once per gossip round (the Fleet does this inside
    ``pump``), and :meth:`on_message` for every received digest.
    """

    def __init__(self, node_id: str, catalog: MetadataCatalog,
                 bus: MessageBus, *, fanout: Optional[int] = None):
        self.node_id = node_id
        self.catalog = catalog
        self.bus = bus
        # None = adaptive: resolved from the registered ring size at each
        # emit, so late-joining fabric nodes widen the push automatically
        self.fanout = max(1, fanout) if fanout is not None else None
        self.vv: VersionVector = {}
        # grid node liveness: node -> (version, origin, alive).  Highest
        # (version, origin) wins — the origin id breaks ties between
        # concurrent equal-version observations on different front-ends,
        # so conflicting join/leave reports still converge fleet-wide
        # instead of each observer keeping its own view forever.
        self.liveness: Dict[int, Tuple[int, str, bool]] = {}
        self.stats = GossipStats()
        # optional observability handles (installed by the Fleet):
        # ``health`` is a repro.obs.HealthMonitor whose digest piggybacks
        # on the gossip digest (anti-entropy carries health for free),
        # ``metrics`` a MetricsRegistry for gossip counters.  None = off.
        self.health = None
        self.metrics = None
        bus.register(node_id)
        catalog.on_dataset_bump(self._on_local_bump)

    # ------------------------------------------------------------------ #
    def _on_local_bump(self, epoch: int) -> None:
        """Catalogue bump hook: credit locally originated bumps to our own
        version-vector entry.  When the epoch change came from gossip
        itself (``set_dataset_epoch`` after a merge) the vector already
        accounts for it and the delta is zero."""
        known = effective_epoch(self.vv)
        if epoch > known:
            self.vv[self.node_id] = \
                self.vv.get(self.node_id, 0) + (epoch - known)

    def observe_liveness(self, grid_node: int, alive: bool) -> None:
        """Record a locally observed grid-node join/leave and stamp it
        with a fresh (version, origin) so gossip propagates it to every
        peer and concurrent observations resolve deterministically.  The
        caller is responsible for the local catalogue mark (the
        ElasticManager already did it)."""
        ver = self.liveness.get(grid_node, (0, "", True))[0]
        self.liveness[grid_node] = (ver + 1, self.node_id, alive)

    # ------------------------------------------------------------------ #
    def digest(self) -> dict:
        """The full anti-entropy digest this node pushes every round.
        When a health monitor is attached its digest rides along, so
        node-health telemetry converges fleet-wide under the same
        :func:`rounds_bound` as epochs and liveness."""
        out = {
            "vv": dict(self.vv),
            "live": {n: list(v) for n, v in self.liveness.items()},
        }
        if self.health is not None:
            out["health"] = self.health.digest()
        return out

    def targets(self) -> List[str]:
        """This round's push targets: the next ``fanout`` peers after us
        on the sorted ring of registered fabric nodes (adaptive
        ``max(1, ceil(log2(ring)))`` when no fanout was fixed)."""
        ring = self.bus.nodes
        if len(ring) <= 1:
            return []
        fanout = (self.fanout if self.fanout is not None
                  else adaptive_fanout(len(ring)))
        i = ring.index(self.node_id)
        return [ring[(i + 1 + k) % len(ring)]
                for k in range(min(fanout, len(ring) - 1))]

    def emit(self) -> None:
        """Push the digest to this round's ring targets."""
        payload = self.digest()
        for dst in self.targets():
            self.bus.send(self.node_id, dst, GOSSIP_TOPIC, payload)
            self.stats.digests_sent += 1
            if self.metrics is not None:
                self.metrics.counter("gossip.digests_sent").inc()

    def on_message(self, payload: dict) -> None:
        """Merge one received digest into local state, applying epoch and
        liveness changes to the catalogue (which fans out to the caches
        through the ordinary bump-hook chain)."""
        self.stats.digests_received += 1
        if self.metrics is not None:
            self.metrics.counter("gossip.digests_received").inc()
        if self.health is not None and "health" in payload:
            self.health.merge_digest(payload["health"])
        changed = merge_vv(self.vv, payload.get("vv", {}))
        if changed:
            self.catalog.set_dataset_epoch(effective_epoch(self.vv))
            self.stats.epoch_updates += 1
        live_changed = False
        for node, (ver, origin, alive) in payload.get("live", {}).items():
            node = int(node)
            cur = self.liveness.get(node, (0, "", True))
            if (ver, origin) > (cur[0], cur[1]):
                self.liveness[node] = (ver, origin, alive)
                if alive:
                    self.catalog.mark_alive(node)
                else:
                    self.catalog.mark_dead(node)
                self.stats.liveness_updates += 1
                live_changed = True
        if not changed and not live_changed:
            self.stats.digests_stale += 1
        elif self.metrics is not None:
            self.metrics.counter("gossip.updates_applied").inc()

    def detach(self) -> None:
        """Unhook from the catalogue (shutdown path — a long-lived
        catalogue must not accumulate dead gossip hooks)."""
        self.catalog.off_dataset_bump(self._on_local_bump)
