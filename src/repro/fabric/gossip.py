"""Epoch gossip + anti-entropy: fleet-wide cache invalidation with a
bounded propagation delay.

The single-process service invalidates its cache through the catalogue's
``bump_dataset_version()`` hook.  In a fleet, each front-end has its own
catalogue *view*, so a bump observed on one front-end must reach every
peer — otherwise a sibling keeps serving results computed over the old
dataset forever.  This module closes that loop with the classic
interactive-grid recipe (DIAL's shared metadata tier, Grid-enabled
database lessons): a small, periodic, idempotent digest exchange.

**Version vectors.**  Each front-end keeps a vector ``{origin: bumps}``
counting how many dataset bumps each fleet member has *originated*.  The
effective dataset epoch is the SUM of the vector's entries.  Summing (not
max-ing) is what makes reconciliation after a partition correct: if both
sides of a split bump once, the healed vector merges to both entries and
the effective epoch exceeds *each* side's partition-era epoch, so every
entry cached during the split is invalidated on every member.

**Propagation bound.**  Every gossip round, the node at index ``i`` of
the sorted peer list pushes its full digest to peers ``i+1 .. i+fanout``
(mod n).  Information therefore advances at least ``fanout`` ring
positions per round, giving the documented bound
:func:`rounds_bound` ``= ceil((n-1)/fanout)`` rounds from any bump to
fleet-wide visibility (loss-free bus; message drops only delay
convergence because digests are cumulative and idempotent).  The default
fanout is **adaptive to fleet size**: :func:`adaptive_fanout` ``=
max(1, ceil(log2(n)))``, so the bound scales as ``O(n / log n)`` rounds
while per-round traffic stays ``O(n log n)`` messages — a fixed constant
either floods small fleets or crawls on large ones.

**Anti-entropy.**  Digests always carry the full vector and the full
liveness map, never deltas.  A front-end that was partitioned needs no
special recovery path: the first digest it receives after healing carries
everything it missed, and :func:`rounds_bound` applies again from the
heal.

The same digest piggybacks grid-node liveness (a per-node monotonic
``(version, origin)`` stamp — highest wins, origin id breaking ties
between concurrent observations), so a ``node_leave`` observed by one
front-end reaches every peer's catalogue and redirects their packet
scheduling to surviving replicas within the same bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.catalog import MetadataCatalog
from repro.fabric.bus import MessageBus

GOSSIP_TOPIC = "gossip"

VersionVector = Dict[str, int]


def effective_epoch(vv: VersionVector) -> int:
    """Dataset epoch implied by a version vector: the sum of per-origin
    bump counts (see module docstring for why sum, not max)."""
    return sum(vv.values())


def merge_vv(mine: VersionVector, theirs: VersionVector) -> bool:
    """Element-wise max merge of ``theirs`` into ``mine`` (in place);
    returns True when ``mine`` changed."""
    changed = False
    for origin, n in theirs.items():
        if n > mine.get(origin, 0):
            mine[origin] = n
            changed = True
    return changed


def adaptive_fanout(n_frontends: int) -> int:
    """Default gossip fanout for a fleet of ``n``: ``max(1, ceil(log2(n)))``.

    Scales push width with fleet size so the propagation bound stays
    ``O(n / log n)`` rounds without flooding small fleets: n<=2 -> 1,
    3..4 -> 2, 5..8 -> 3, 9..16 -> 4, ...  Used whenever a fanout of
    ``None`` is passed (GossipNode, Fleet, :func:`rounds_bound`)."""
    if n_frontends <= 2:
        return 1
    return max(1, math.ceil(math.log2(n_frontends)))


def rounds_bound(n_frontends: int, fanout: Optional[int] = None) -> int:
    """Worst-case gossip rounds from a bump on any member to fleet-wide
    visibility on a loss-free bus: ``ceil((n-1)/fanout)``.

    ``fanout=None`` means the adaptive default
    (:func:`adaptive_fanout`), matching what a Fleet built without an
    explicit ``gossip_fanout`` actually pushes — e.g. n=16 gossips at
    fanout 4 and is fleet-wide within ``ceil(15/4) = 4`` rounds."""
    if n_frontends <= 1:
        return 0
    if fanout is None:
        fanout = adaptive_fanout(n_frontends)
    return math.ceil((n_frontends - 1) / max(1, fanout))


def rounds_bound_lossy(n_frontends: int, fanout: Optional[int] = None, *,
                       drop_rate: float = 0.0,
                       confidence: float = 0.999) -> int:
    """Probabilistic propagation bound under sustained i.i.d. message
    loss: rounds after which a bump is fleet-wide with probability at
    least ``confidence``.

    Derivation: on the loss-free bus information crosses the ring in
    ``R = rounds_bound(n, fanout)`` sequential hops.  Digests are
    cumulative and re-pushed every round (and the ack/repair variant
    additionally resends unacknowledged digests), so a hop that needs
    ``m`` rounds to land a message fails with probability
    ``drop_rate**m`` — each round is an independent Bernoulli trial.
    Choosing ``m = ceil(log((1-confidence)/R) / log(drop_rate))`` makes
    each hop's failure probability at most ``(1-confidence)/R``; a union
    bound over the ``R`` sequential hops caps the total failure
    probability at ``1-confidence``.  The bound is ``R * m`` rounds —
    loss multiplies the loss-free bound by a log factor, it does not
    break convergence (the anti-entropy property the test matrix
    seeds loss to verify)."""
    base = rounds_bound(n_frontends, fanout)
    if base == 0 or drop_rate <= 0.0:
        return base
    if not (0.0 < drop_rate < 1.0):
        raise ValueError("drop_rate must be in [0, 1)")
    if not (0.0 < confidence < 1.0):
        raise ValueError("confidence must be in (0, 1)")
    fail_per_hop = (1.0 - confidence) / base
    m = math.ceil(math.log(fail_per_hop) / math.log(drop_rate))
    return base * max(1, m)


@dataclasses.dataclass
class GossipStats:
    """Monotonic gossip counters: digests sent/received, digests that
    changed local state, and epoch/liveness updates applied."""
    digests_sent: int = 0
    digests_received: int = 0
    digests_stale: int = 0       # received digests that taught us nothing
    epoch_updates: int = 0       # catalog epochs advanced by gossip
    liveness_updates: int = 0    # node alive/dead flips applied by gossip
    # ack/repair protocol (GossipNode(repair=True)):
    acks_sent: int = 0           # acks returned for want_ack digests
    acks_received: int = 0       # our digests confirmed delivered
    repairs: int = 0             # unacked digests re-pushed after timeout
    replies_sent: int = 0        # push-pull replies to stale senders


class GossipNode:
    """One front-end's membership in the epoch-gossip protocol.

    Attaches to the front-end's catalogue: a local
    ``bump_dataset_version()`` (from any code path) is credited to this
    node's entry of the version vector via the catalogue's bump hook, and
    remote digests that advance the vector are applied back to the
    catalogue with ``set_dataset_epoch`` — which fires the same hook
    chain, so the front-end's result cache invalidates exactly as it
    would for a local bump.

    Call :meth:`emit` once per gossip round (the Fleet does this inside
    ``pump``), and :meth:`on_message` for every received digest.
    """

    def __init__(self, node_id: str, catalog: MetadataCatalog,
                 bus: MessageBus, *, fanout: Optional[int] = None,
                 repair: bool = False, ack_rounds: int = 2):
        self.node_id = node_id
        self.catalog = catalog
        self.bus = bus
        # None = adaptive: resolved from the registered ring size at each
        # emit, so late-joining fabric nodes widen the push automatically
        self.fanout = max(1, fanout) if fanout is not None else None
        # ack/repair hardening (off by default — the plain protocol's
        # counters stay untouched): digests carry a sequence number and
        # want an ack; a digest unacked after ``ack_rounds`` emits is
        # re-pushed once (repair), and an ack from a peer whose digest
        # shows it is stale carries our full digest back (push-pull) —
        # what keeps rounds_bound_lossy honest under sustained loss
        self.repair = repair
        self.ack_rounds = max(1, ack_rounds)
        self._round = 0
        self._next_seq = 0
        self._unacked: Dict[Tuple[str, int], int] = {}  # (dst, seq) -> round
        self.vv: VersionVector = {}
        # grid node liveness: node -> (version, origin, alive).  Highest
        # (version, origin) wins — the origin id breaks ties between
        # concurrent equal-version observations on different front-ends,
        # so conflicting join/leave reports still converge fleet-wide
        # instead of each observer keeping its own view forever.
        self.liveness: Dict[int, Tuple[int, str, bool]] = {}
        self.stats = GossipStats()
        # optional observability handles (installed by the Fleet):
        # ``health`` is a repro.obs.HealthMonitor whose digest piggybacks
        # on the gossip digest (anti-entropy carries health for free),
        # ``metrics`` a MetricsRegistry for gossip counters.  None = off.
        self.health = None
        self.metrics = None
        # flight-recorder scope (repro.obs.flight.FlightScope); None =
        # off.  Records epoch advances and liveness flips.
        self.flight = None
        bus.register(node_id)
        catalog.on_dataset_bump(self._on_local_bump)

    # ------------------------------------------------------------------ #
    def _on_local_bump(self, epoch: int) -> None:
        """Catalogue bump hook: credit locally originated bumps to our own
        version-vector entry.  When the epoch change came from gossip
        itself (``set_dataset_epoch`` after a merge) the vector already
        accounts for it and the delta is zero."""
        known = effective_epoch(self.vv)
        if epoch > known:
            self.vv[self.node_id] = \
                self.vv.get(self.node_id, 0) + (epoch - known)
            if self.flight is not None:
                self.flight.record("gossip_epoch", epoch=epoch, via="local")

    def observe_liveness(self, grid_node: int, alive: bool) -> None:
        """Record a locally observed grid-node join/leave and stamp it
        with a fresh (version, origin) so gossip propagates it to every
        peer and concurrent observations resolve deterministically.  The
        caller is responsible for the local catalogue mark (the
        ElasticManager already did it)."""
        ver = self.liveness.get(grid_node, (0, "", True))[0]
        self.liveness[grid_node] = (ver + 1, self.node_id, alive)
        if self.flight is not None:
            self.flight.record("gossip_liveness", grid_node=grid_node,
                               alive=alive, version=ver + 1, via="local")

    # ------------------------------------------------------------------ #
    def digest(self) -> dict:
        """The full anti-entropy digest this node pushes every round.
        When a health monitor is attached its digest rides along, so
        node-health telemetry converges fleet-wide under the same
        :func:`rounds_bound` as epochs and liveness."""
        out = {
            "vv": dict(self.vv),
            "live": {n: list(v) for n, v in self.liveness.items()},
        }
        if self.health is not None:
            out["health"] = self.health.digest()
        return out

    def targets(self) -> List[str]:
        """This round's push targets: the next ``fanout`` peers after us
        on the sorted ring of registered fabric nodes (adaptive
        ``max(1, ceil(log2(ring)))`` when no fanout was fixed)."""
        ring = self.bus.nodes
        if len(ring) <= 1:
            return []
        fanout = (self.fanout if self.fanout is not None
                  else adaptive_fanout(len(ring)))
        i = ring.index(self.node_id)
        return [ring[(i + 1 + k) % len(ring)]
                for k in range(min(fanout, len(ring) - 1))]

    def _send_digest(self, dst: str, payload: dict) -> None:
        body = payload
        if self.repair:
            seq = self._next_seq
            self._next_seq += 1
            body = dict(payload, seq=seq, src=self.node_id, want_ack=True)
            self._unacked[(dst, seq)] = self._round
        self.bus.send(self.node_id, dst, GOSSIP_TOPIC, body)
        self.stats.digests_sent += 1
        if self.metrics is not None:
            self.metrics.counter("gossip.digests_sent").inc()

    def emit(self) -> None:
        """Push the digest to this round's ring targets; in repair mode,
        additionally re-push to peers whose previous digest went unacked
        for ``ack_rounds`` emits (the bus ate it — send a fresh one)."""
        payload = self.digest()
        targets = self.targets()
        overdue: List[str] = []
        if self.repair:
            self._round += 1
            for (dst, seq), sent_round in list(self._unacked.items()):
                if self._round - sent_round >= self.ack_rounds:
                    del self._unacked[(dst, seq)]
                    overdue.append(dst)
        for dst in targets:
            self._send_digest(dst, payload)
        for dst in overdue:
            if dst not in targets:
                self._send_digest(dst, payload)
            self.stats.repairs += 1
            if self.metrics is not None:
                self.metrics.counter("gossip.repairs").inc()

    # ------------------------------------------------------------------ #
    def _apply_digest(self, payload: dict) -> Tuple[bool, bool]:
        """Merge a digest body into local state; returns (epoch changed,
        liveness changed)."""
        if self.health is not None and "health" in payload:
            self.health.merge_digest(payload["health"])
        changed = merge_vv(self.vv, payload.get("vv", {}))
        if changed:
            self.catalog.set_dataset_epoch(effective_epoch(self.vv))
            self.stats.epoch_updates += 1
            if self.flight is not None:
                self.flight.record("gossip_epoch",
                                   epoch=effective_epoch(self.vv),
                                   via="gossip")
        live_changed = False
        for node, (ver, origin, alive) in payload.get("live", {}).items():
            node = int(node)
            cur = self.liveness.get(node, (0, "", True))
            if (ver, origin) > (cur[0], cur[1]):
                self.liveness[node] = (ver, origin, alive)
                if alive:
                    self.catalog.mark_alive(node)
                else:
                    self.catalog.mark_dead(node)
                self.stats.liveness_updates += 1
                live_changed = True
                if self.flight is not None:
                    self.flight.record("gossip_liveness", grid_node=node,
                                       alive=alive, version=ver,
                                       via="gossip")
        return changed, live_changed

    def _sender_stale(self, payload: dict) -> bool:
        """Does the sender's digest show it is missing something we
        know?  (The push-pull trigger: loss is bidirectional, so an ack
        is the cheapest place to carry the missing state back.)"""
        theirs_vv = payload.get("vv", {})
        if any(n > theirs_vv.get(origin, 0)
               for origin, n in self.vv.items()):
            return True
        theirs_live = payload.get("live", {})
        for node, mine in self.liveness.items():
            t = theirs_live.get(node, theirs_live.get(str(node)))
            if t is None or (mine[0], mine[1]) > (t[0], t[1]):
                return True
        return False

    def on_message(self, payload: dict) -> None:
        """Merge one received digest into local state, applying epoch and
        liveness changes to the catalogue (which fans out to the caches
        through the ordinary bump-hook chain).  In repair mode this also
        handles protocol messages: acks (confirming our digests, possibly
        carrying a push-pull reply) and digests wanting an ack."""
        if "ack" in payload:
            self.stats.acks_received += 1
            self._unacked.pop((payload.get("src", ""), payload["ack"]),
                              None)
            reply = payload.get("reply")
            if reply:
                self._apply_digest(reply)
            return
        self.stats.digests_received += 1
        if self.metrics is not None:
            self.metrics.counter("gossip.digests_received").inc()
        changed, live_changed = self._apply_digest(payload)
        if not changed and not live_changed:
            self.stats.digests_stale += 1
        elif self.metrics is not None:
            self.metrics.counter("gossip.updates_applied").inc()
        if self.repair and payload.get("want_ack") \
                and payload.get("src") in self.bus.nodes:
            ack = {"ack": payload.get("seq"), "src": self.node_id}
            if self._sender_stale(payload):
                ack["reply"] = self.digest()
                self.stats.replies_sent += 1
            self.bus.send(self.node_id, payload["src"], GOSSIP_TOPIC, ack)
            self.stats.acks_sent += 1
            if self.metrics is not None:
                self.metrics.counter("gossip.acks_sent").inc()

    def detach(self) -> None:
        """Unhook from the catalogue (shutdown path — a long-lived
        catalogue must not accumulate dead gossip hooks)."""
        self.catalog.off_dataset_bump(self._on_local_bump)
