"""Persistent fragment registry: cross-window, cross-front-end memory of
which query fragments are hot.

The planner's common-subexpression factoring is per-window: a fragment
shared by two queries *inside* one dispatch window is evaluated once and
(if boolean) materialized into the result cache.  But interactive traffic
repeats across windows and across fleet members — the same
``count(pt > 15) >= 2`` conjunct shows up all day, often only once per
window, so the ≥2-references materialization rule never fires and the
fragment is recomputed forever.  The registry closes that gap
(ROADMAP: "Cross-window fragment reuse"):

- every planned window is :meth:`observed <FragmentRegistry.observe_plan>`
  — each boolean scalar-context fragment's reference count and
  windows-seen count accumulate fleet-wide (one registry serves every
  front-end);
- each NEW window's planning :meth:`seeds <FragmentRegistry.seed_interner>`
  its :class:`~repro.core.query.Interner` with the hot fragments, so a
  hot fragment occurring in the window shares node identity with the
  registry's copy and can be recognized by ``id()``;
- hot fragments present in the window are *pre-warmed*: marked for
  materialization even when referenced by a single query, so the scan's
  by-product lands in the (shared) fragment cache and the next
  submission equal to that fragment — on any front-end — is a zero-I/O
  hit.

The registry is plain data (canonical fragment strings + counters) and
serializes to JSON (:meth:`save`/:meth:`load`), surviving front-end
restarts the way the paper's metadata catalogue survives JSE restarts.

Pre-warming never changes results: a materialized fragment is an extra
plan target evaluated from the same shared memo, and per-query roots are
untouched (``tests/test_fabric.py`` pins registry-seeded windows
bit-identical to unseeded planning).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Union

from repro.core import query as query_lib


@dataclasses.dataclass
class FragmentRecord:
    """Accumulated history of one canonical fragment: total references
    across all observed windows, number of distinct windows it appeared
    in, and the last window index that referenced it."""
    key: str
    refs: int = 0
    windows: int = 0
    last_window: int = -1


class FragmentRegistry:
    """Fleet-wide fragment heat tracker + interner seeder (see module
    docstring).

    Parameters
    ----------
    hot_min_windows:
        A fragment becomes *hot* once it has appeared in at least this
        many distinct windows (2 by default: one window of history is
        enough to start pre-warming, zero history never is).
    max_hot:
        Upper bound on fragments returned by :meth:`hot` / seeded into an
        interner — keeps per-window planning overhead bounded no matter
        how long the registry lives.
    """

    def __init__(self, *, hot_min_windows: int = 2, max_hot: int = 16):
        self.hot_min_windows = hot_min_windows
        self.max_hot = max_hot
        self.records: Dict[str, FragmentRecord] = {}
        self.windows_observed = 0

    # ------------------------------------------------------------------ #
    def observe_plan(self, plan: "query_lib.FragmentPlan") -> None:
        """Fold one planned window into the registry: every boolean
        scalar-context fragment of the plan (root or not) gets its
        reference and window counters advanced."""
        from repro.service import planner as planner_lib
        window = self.windows_observed
        self.windows_observed += 1
        for node, nrefs in planner_lib.boolean_fragment_refs(plan):
            key = query_lib.node_key(node)
            rec = self.records.get(key)
            if rec is None:
                rec = self.records[key] = FragmentRecord(key)
            rec.refs += nrefs
            if rec.last_window != window:
                rec.windows += 1
                rec.last_window = window

    def hot(self, limit: Optional[int] = None) -> List[str]:
        """Canonical keys of the hottest fragments (appeared in >=
        ``hot_min_windows`` windows), most-referenced first, bounded by
        ``limit`` (default ``max_hot``)."""
        limit = self.max_hot if limit is None else limit
        cands = [r for r in self.records.values()
                 if r.windows >= self.hot_min_windows]
        cands.sort(key=lambda r: (-r.refs, -r.windows, r.key))
        return [r.key for r in cands[:limit]]

    def seed_interner(self, interner: "query_lib.Interner"
                      ) -> Dict[str, "query_lib.Node"]:
        """Intern every hot fragment into ``interner`` (BEFORE the window's
        queries are interned) and return ``{canonical key: shared node}``.
        Any query in the window containing a hot fragment then shares the
        returned node object, so the planner can recognize hot fragments
        by identity and mark them for materialization."""
        out = {}
        for key in self.hot():
            try:
                out[key] = interner.intern(query_lib.parse(key))
            except query_lib.QueryError:  # never let a corrupt record plan
                continue
        return out

    # ------------------------------------------------------------------ #
    def to_json(self) -> str:
        """Serialize the registry (records + window counter) to JSON."""
        return json.dumps({
            "windows_observed": self.windows_observed,
            "hot_min_windows": self.hot_min_windows,
            "max_hot": self.max_hot,
            "records": {k: dataclasses.asdict(v)
                        for k, v in self.records.items()},
        })

    @classmethod
    def from_json(cls, text: str) -> "FragmentRegistry":
        """Rebuild a registry from :meth:`to_json` output."""
        data = json.loads(text)
        reg = cls(hot_min_windows=data.get("hot_min_windows", 2),
                  max_hot=data.get("max_hot", 16))
        reg.windows_observed = data.get("windows_observed", 0)
        for k, v in data.get("records", {}).items():
            reg.records[k] = FragmentRecord(**v)
        return reg

    def save(self, path: Union[str, pathlib.Path]) -> None:
        """Persist to ``path`` (restart survival, like the catalogue)."""
        pathlib.Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path: Union[str, pathlib.Path]) -> "FragmentRegistry":
        """Load a registry persisted by :meth:`save`."""
        return cls.from_json(pathlib.Path(path).read_text())

    def __len__(self) -> int:
        return len(self.records)
