"""Deterministic in-process message bus — the simulated inter-frontend
network of the coherence fabric.

Every fleet experiment needs the same three network behaviours the real
deployment would see — latency, loss, and partitions — without giving up
reproducibility.  The bus delivers in discrete *rounds* (the fabric's
coarse network clock): a message sent during round ``r`` becomes visible
in the destination inbox at round ``r + 1 + delay``.  Within one round,
deliveries are ordered by a global send sequence number, so two runs with
the same seed and the same send pattern drain identically.  With a
constant per-link delay the bus is FIFO per (src, dst) link, which is the
ordering contract the stream fan-out layer relies on (it additionally
guards against reordering with per-snapshot sequence numbers).

Faults are injected deterministically: ``drop_rate`` uses a seeded RNG,
and :meth:`MessageBus.partition` splits the fleet into groups whose
cross-group messages are silently lost until :meth:`MessageBus.heal` —
exactly the scenario the gossip layer's anti-entropy reconciliation
(``fabric/gossip.py``) has to recover from.
"""
from __future__ import annotations

import dataclasses
import random
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One message in flight: source/destination fabric node ids, a topic
    string the receiver dispatches on, an arbitrary payload (treated as
    immutable by convention — the simulated network never copies), the
    send round, and the round at which it becomes deliverable."""
    seq: int
    src: str
    dst: str
    topic: str
    payload: Any
    sent_round: int
    deliver_round: int


@dataclasses.dataclass
class BusStats:
    """Monotonic bus counters: messages sent, delivered, dropped by the
    seeded loss process, and blocked by an active partition."""
    sent: int = 0
    delivered: int = 0
    dropped: int = 0
    partitioned: int = 0


class MessageBus:
    """Round-based deterministic message fabric between fleet front-ends.

    Parameters
    ----------
    delay:
        Extra delivery rounds per message beyond the minimum of one (a
        message can never be read in the round it was sent — the fabric
        has no zero-latency links).
    drop_rate:
        Probability in [0, 1) that a message is lost, drawn from a
        dedicated ``random.Random(seed)`` so loss patterns replay
        identically run to run.
    seed:
        Seed for the loss process.
    """

    def __init__(self, *, delay: int = 0, drop_rate: float = 0.0,
                 seed: int = 0):
        if delay < 0:
            raise ValueError("delay must be >= 0")
        if not (0.0 <= drop_rate < 1.0):
            raise ValueError("drop_rate must be in [0, 1)")
        self.delay = delay
        self.drop_rate = drop_rate
        self.round = 0
        self.stats = BusStats()
        # observability metrics registry (repro.obs.MetricsRegistry);
        # None = disabled.  The fleet installs its fleet-level registry
        # here — the bus is shared infrastructure, not per-frontend.
        self.metrics = None
        # flight-recorder scope (repro.obs.flight.FlightScope); None =
        # disabled.  Records every send outcome and delivery.
        self.flight = None
        self._rng = random.Random(seed)
        self._inboxes: Dict[str, Deque[Envelope]] = {}
        self._inflight: List[Envelope] = []
        self._groups: Optional[List[set]] = None
        self._link_loss: Dict[Tuple[str, str], float] = {}
        self._seq = 0

    def set_link_loss(self, src: str, dst: str, rate: float) -> None:
        """Override the loss rate for ONE directed link (0 restores the
        bus-wide ``drop_rate``) — the lossy-link scenario the gossip
        ack/repair protocol exists for.  Draws come from the same seeded
        RNG as global loss, so runs stay reproducible."""
        if not (0.0 <= rate < 1.0):
            raise ValueError("rate must be in [0, 1)")
        if rate == 0.0:
            self._link_loss.pop((src, dst), None)
        else:
            self._link_loss[(src, dst)] = rate

    # ------------------------------------------------------------------ #
    def register(self, node_id: str) -> None:
        """Create the inbox for a fabric node (idempotent)."""
        self._inboxes.setdefault(node_id, deque())

    @property
    def nodes(self) -> List[str]:
        """Registered fabric node ids, sorted (the gossip peer list)."""
        return sorted(self._inboxes)

    # ------------------------------------------------------------------ #
    def _same_side(self, a: str, b: str) -> bool:
        if self._groups is None:
            return True
        for g in self._groups:
            if a in g:
                return b in g
        return False  # unknown nodes are isolated while partitioned

    def partition(self, *groups) -> None:
        """Split the fleet: messages between different ``groups`` (iterables
        of node ids) are lost until :meth:`heal`.  Nodes not named in any
        group are isolated from everyone."""
        self._groups = [set(g) for g in groups]

    def heal(self) -> None:
        """Remove the partition; traffic sent *after* healing flows again
        (messages lost during the partition stay lost — recovering their
        information is the gossip layer's anti-entropy job)."""
        self._groups = None

    # ------------------------------------------------------------------ #
    def _send_outcome(self, src: str, dst: str, topic: str) -> str:
        # The single nondeterminism-relevant decision point of the bus:
        # "partitioned" | "dropped" | "delivered".  The replay engine
        # (repro.obs.replay.ReplayBus) overrides exactly this method to
        # substitute recorded outcomes, which also covers partitions and
        # per-link loss without re-driving partition()/set_link_loss().
        if not self._same_side(src, dst):
            return "partitioned"
        loss = self._link_loss.get((src, dst), self.drop_rate)
        if loss and self._rng.random() < loss:
            return "dropped"
        return "delivered"

    def send(self, src: str, dst: str, topic: str, payload: Any) -> bool:
        """Queue one message; returns False when the loss process or an
        active partition ate it (callers never retry — the fabric's
        protocols are periodic and idempotent instead)."""
        if dst not in self._inboxes:
            raise KeyError(f"unknown fabric node {dst!r}")
        self.stats.sent += 1
        if self.metrics is not None:
            self.metrics.counter("bus.sent").inc()
        outcome = self._send_outcome(src, dst, topic)
        if outcome != "delivered":
            if outcome == "partitioned":
                self.stats.partitioned += 1
            else:
                self.stats.dropped += 1
            if self.metrics is not None:
                self.metrics.counter(f"bus.{outcome}").inc()
            if self.flight is not None:
                self.flight.record("bus_send", n=self.stats.sent, src=src,
                                   dst=dst, topic=topic, outcome=outcome,
                                   round=self.round)
            return False
        env = Envelope(self._seq, src, dst, topic, payload, self.round,
                       self.round + 1 + self.delay)
        self._seq += 1
        self._inflight.append(env)
        if self.flight is not None:
            rec = self.flight.record(
                "bus_send", n=self.stats.sent, src=src, dst=dst,
                topic=topic, outcome=outcome, round=self.round,
                seq=env.seq, deliver_round=env.deliver_round)
            self.flight.note_send(env.seq, rec["eid"])
        return True

    def broadcast(self, src: str, topic: str, payload: Any) -> int:
        """Send to every registered node except ``src``; returns the number
        of messages that survived loss/partition."""
        return sum(self.send(src, dst, topic, payload)
                   for dst in self.nodes if dst != src)

    def tick(self) -> int:
        """Advance one network round: deliver every due message into its
        destination inbox in global send order; returns deliveries made."""
        self.round += 1
        due = [e for e in self._inflight if e.deliver_round <= self.round]
        self._inflight = [e for e in self._inflight
                          if e.deliver_round > self.round]
        due.sort(key=lambda e: e.seq)
        for env in due:
            self._inboxes[env.dst].append(env)
            if self.flight is not None:
                rec = self.flight.record(
                    "bus_deliver", seq=env.seq, src=env.src, dst=env.dst,
                    topic=env.topic, round=self.round,
                    cause=self.flight.send_cause(env.seq))
                self.flight.note_deliver(env.seq, rec["eid"])
        self.stats.delivered += len(due)
        if self.metrics is not None and due:
            self.metrics.counter("bus.delivered").inc(len(due))
        return len(due)

    def recv(self, node_id: str) -> List[Envelope]:
        """Drain and return the node's inbox (delivery order)."""
        box = self._inboxes[node_id]
        out = list(box)
        box.clear()
        return out

    def pending(self, node_id: str) -> int:
        """Messages currently waiting in a node's inbox."""
        return len(self._inboxes[node_id])

    @property
    def idle(self) -> bool:
        """True when nothing is in flight and every inbox is empty."""
        return not self._inflight and all(
            not b for b in self._inboxes.values())

    def in_flight(self, topic: Optional[str] = None) -> int:
        """Messages not yet drained by their destination (in flight or
        sitting in an inbox), optionally filtered by topic.  Lets a
        caller wait for quiescence of ONE protocol (e.g. stream fan-out)
        without being fooled by periodic traffic (gossip emits every
        round, so the bus as a whole is almost never idle)."""
        envs = list(self._inflight)
        for box in self._inboxes.values():
            envs.extend(box)
        if topic is None:
            return len(envs)
        return sum(1 for e in envs if e.topic == topic)
