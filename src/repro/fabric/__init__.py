"""Coherence fabric: the layer that turns N single-process query
front-ends into one coherent fleet — a deterministic inter-front-end
message bus, epoch + liveness gossip with a bounded propagation delay,
a fleet-shared L2 result/fragment cache tier under every front-end's L1,
a persistent cross-window fragment registry, and cross-front-end
progressive-stream fan-out.  ``docs/fabric.md`` documents the coherence
and staleness model."""
from repro.fabric.bus import BusStats, Envelope, MessageBus
from repro.fabric.fanout import FanoutStats, StreamFanout
from repro.fabric.fleet import Fleet, Frontend
from repro.fabric.gossip import (GossipNode, GossipStats, adaptive_fanout,
                                 effective_epoch, merge_vv, rounds_bound)
from repro.fabric.leases import (LEASE_TOPIC, LeaseManager, LeaseRecord,
                                 LeaseStats, lease_key, lease_ttl)
from repro.fabric.registry import FragmentRecord, FragmentRegistry
from repro.fabric.shared_cache import (SharedCacheStats, SharedCacheTier,
                                       TieredResultCache)

__all__ = [
    "BusStats", "Envelope", "FanoutStats", "Fleet", "FragmentRecord",
    "FragmentRegistry", "Frontend", "GossipNode", "GossipStats",
    "LEASE_TOPIC", "LeaseManager", "LeaseRecord", "LeaseStats",
    "MessageBus", "SharedCacheStats", "SharedCacheTier", "StreamFanout",
    "TieredResultCache", "adaptive_fanout", "effective_epoch",
    "lease_key", "lease_ttl", "merge_vv", "rounds_bound",
]
