"""Fleet-wide shared cache tier: the catalog-backed L2 under every
front-end's private L1.

The per-process :class:`~repro.service.cache.ResultCache` dies with its
front-end and is invisible to siblings, so a fleet re-scans queries a
peer already answered — the exact failure mode the LHC-databases-on-the-
Grid experience warns about.  The fabric adds a second tier:

- **L1** — the existing per-front-end ``ResultCache``, unchanged
  semantics, hit with zero coordination.
- **L2** — :class:`SharedCacheTier`, one logical store for the whole
  fleet (in deployment: a results table next to the paper's PostgreSQL
  metadata catalogue; here: one in-process object every front-end
  holds a handle to).  Keyed on the SAME canonical keyspace as L1 —
  ``(canonical expression, calib_iters, dataset epoch)`` — so whole-query
  results *and* fragment-level entries produced as scan by-products on
  one front-end are zero-I/O hits on all others, with no key
  translation anywhere.

:class:`TieredResultCache` is the composition the fleet installs into
each ``QueryService``: an L1 that fills misses from L2 and write-throughs
puts, so the service layer above needs no fleet awareness at all.  The
tier persists to JSON (``save``/``load``), so the fleet's L2 survives
restarts the way the fragment registry and the metadata catalogue do.

**Epoch safety.**  Scalar epochs are ambiguous in a fleet: two
*different* front-ends' first bumps both produce effective epoch 1 while
denoting different dataset states, so the shared tier keys and guards on
the full **version vector** (as a sorted fingerprint), not the scalar
sum.  L2 maintains the join (element-wise max) of every vector any
front-end has mentioned — on get, put, or the bump hook — and refuses
gets and puts whose vector differs from the join: a probe that is
missing bumps someone else knows about is stale, and two incomparable
vectors (concurrent independent bumps) refuse EACH OTHER until gossip
reconciles them, which is the safe direction.  A front-end that has not
yet heard a bump can therefore serve from L2 only until ANY member
mentions the newer vector — after that the tier is closed to stale
traffic fleet-wide.  Combined with the gossip bound
(``fabric/gossip.py``), staleness is bounded by
``rounds_bound(n, fanout)`` gossip rounds after a bump.  Standalone use
(no fleet) passes scalar epochs, which degrade to the single-origin
vector ``{"": epoch}`` with identical semantics to a plain watermark.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple, Union

from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.catalog import MetadataCatalog
from repro.service.cache import ResultCache


@dataclasses.dataclass
class SharedCacheStats:
    """Monotonic L2 counters: hits/misses, installs (whole-query and
    fragment), entries purged by epoch advance, and stale-epoch gets/puts
    refused."""
    hits: int = 0
    misses: int = 0
    puts: int = 0
    fragment_puts: int = 0
    evictions: int = 0
    invalidated: int = 0
    stale_refused: int = 0


class SharedCacheTier:
    """The fleet-shared L2: LRU over the canonical L1 keyspace with
    version-vector hygiene (see module docstring)."""

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.stats = SharedCacheStats()
        # observability metrics registry (repro.obs.MetricsRegistry);
        # None = disabled.  Fleet-level like the bus: one tier serves
        # every front-end, so its counters live in the fleet registry.
        self.metrics = None
        self._join: Dict[str, int] = {}  # element-wise max of seen vectors
        self._entries: "OrderedDict[Tuple, merge_lib.QueryResult]" = \
            OrderedDict()

    @staticmethod
    def _fp(vv: Dict[str, int]) -> Tuple:
        """Canonical fingerprint of a version vector (zero entries are
        identity and dropped, so ``{}`` and ``{"fe0": 0}`` agree)."""
        return tuple(sorted((o, int(n)) for o, n in vv.items() if n))

    @property
    def max_epoch(self) -> int:
        """Scalar effective epoch of the join of every observed vector
        (reporting only — hygiene decisions use the full vector)."""
        return sum(self._join.values())

    def _resolve(self, epoch: int,
                 vv: Optional[Dict[str, int]]) -> Dict[str, int]:
        return dict(vv) if vv is not None else ({"": int(epoch)} if epoch
                                                else {})

    # ------------------------------------------------------------------ #
    def observe_vv(self, vv: Dict[str, int]) -> None:
        """Merge one member's version vector into the join; if the join
        advanced, purge every entry keyed under a different vector (they
        are unreachable for any converged member — purging just frees the
        memory eagerly)."""
        changed = False
        for origin, n in vv.items():
            if n > self._join.get(origin, 0):
                self._join[origin] = n
                changed = True
        if not changed:
            return
        fp = self._fp(self._join)
        stale = [k for k in self._entries if k[2] != fp]
        for k in stale:
            del self._entries[k]
        self.stats.invalidated += len(stale)

    def observe_epoch(self, epoch: int) -> None:
        """Scalar-epoch convenience for standalone (non-fleet) use: the
        epoch becomes the single-origin vector ``{"": epoch}``."""
        self.observe_vv({"": int(epoch)})

    def _current(self, vv: Dict[str, int]) -> bool:
        """Merge ``vv`` and report whether it matches the join — i.e. the
        caller knows every bump the fleet has mentioned so far."""
        self.observe_vv(vv)
        return self._fp(vv) == self._fp(self._join)

    def get(self, canonical: str, calib_iters: int, epoch: int, *,
            vv: Optional[Dict[str, int]] = None
            ) -> Optional[merge_lib.QueryResult]:
        """Probe the shared tier (``canonical`` must already be canonical
        — the L1 layer canonicalized).  A get whose epoch vector differs
        from the join of all observed vectors is refused as stale."""
        vv = self._resolve(epoch, vv)
        if not self._current(vv):
            self.stats.stale_refused += 1
            if self.metrics is not None:
                self.metrics.counter("l2.stale_refused").inc()
            return None
        k = (canonical, int(calib_iters), self._fp(vv))
        hit = self._entries.get(k)
        if hit is None:
            self.stats.misses += 1
            if self.metrics is not None:
                self.metrics.counter("l2.misses").inc()
            return None
        self._entries.move_to_end(k)
        self.stats.hits += 1
        if self.metrics is not None:
            self.metrics.counter("l2.hits").inc()
        return hit

    def put(self, canonical: str, calib_iters: int, epoch: int,
            result: merge_lib.QueryResult, *, fragment: bool = False,
            vv: Optional[Dict[str, int]] = None):
        """Install one result under the canonical keyspace.  A put whose
        epoch vector differs from the join is refused — a slow front-end
        that finished a scan after a bump (or before hearing one) must
        not install data the fleet could mistake for current."""
        vv = self._resolve(epoch, vv)
        if not self._current(vv):
            self.stats.stale_refused += 1
            if self.metrics is not None:
                self.metrics.counter("l2.stale_refused").inc()
            return
        k = (canonical, int(calib_iters), self._fp(vv))
        self._entries[k] = result
        self._entries.move_to_end(k)
        self.stats.puts += 1
        if self.metrics is not None:
            self.metrics.counter("l2.puts").inc()
        if fragment:
            self.stats.fragment_puts += 1
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)

    # --------------------------- persistence -------------------------- #
    def to_json(self) -> str:
        """Serialize the tier (capacity, version-vector join, entries in
        LRU order) to JSON — restart survival for the fleet's L2, like
        the fragment registry and the metadata catalogue.  Entry keys
        round-trip exactly (canonical string, calib_iters, vv
        fingerprint) and results round-trip bit-identically
        (:meth:`~repro.core.merge.QueryResult.to_dict`); stats are
        runtime counters and start fresh on load."""
        return json.dumps({
            "capacity": self.capacity,
            "join": dict(self._join),
            "entries": [
                {"canonical": k[0], "calib_iters": k[1],
                 "vv": [list(p) for p in k[2]],
                 "result": v.to_dict()}
                for k, v in self._entries.items()],
        })

    @classmethod
    def from_json(cls, text: str) -> "SharedCacheTier":
        """Rebuild a tier from :meth:`to_json` output.  Entries keyed
        under vectors older than the persisted join were already purged
        at save time; the rebuilt tier re-applies the join so any
        straggler is purged again on load."""
        data = json.loads(text)
        tier = cls(data.get("capacity", 4096))
        for e in data.get("entries", []):
            fp = tuple(tuple(p) for p in e["vv"])
            tier._entries[(e["canonical"], int(e["calib_iters"]), fp)] = \
                merge_lib.QueryResult.from_dict(e["result"])
        tier.observe_vv({o: int(n) for o, n in
                         data.get("join", {}).items()})
        return tier

    def save(self, path: Union[str, pathlib.Path]) -> None:
        """Persist the tier to ``path`` (see :meth:`to_json`)."""
        pathlib.Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path: Union[str, pathlib.Path]) -> "SharedCacheTier":
        """Load a tier persisted by :meth:`save`."""
        return cls.from_json(pathlib.Path(path).read_text())


class TieredResultCache(ResultCache):
    """A front-end's L1 backed by the fleet's shared L2.

    Drop-in for :class:`~repro.service.cache.ResultCache` (the
    ``QueryService`` is fleet-unaware): misses fall through to L2 and
    hits are promoted into L1; puts (whole-query and fragment) write
    through so scan by-products become fleet-visible immediately.  L2
    hits count as ordinary cache hits in ``stats`` plus ``stats.l2_hits``
    for attribution.  A catalogue dataset bump purges L1 (inherited) and
    forwards the new epoch vector to L2's hygiene join.

    ``vv_source`` supplies this front-end's current epoch version vector
    (the Fleet wires it to the gossip node) so L2 traffic is tagged with
    the unambiguous vector rather than the scalar epoch; without one
    (standalone use) the scalar-epoch degradation applies."""

    def __init__(self, capacity: int = 256,
                 catalog: Optional[MetadataCatalog] = None,
                 l2: Optional[SharedCacheTier] = None,
                 vv_source: Optional[Callable[[], Dict[str, int]]] = None):
        super().__init__(capacity, catalog)
        self.l2 = l2
        self.vv_source = vv_source

    def _vv(self) -> Optional[Dict[str, int]]:
        return dict(self.vv_source()) if self.vv_source is not None \
            else None

    def get(self, expr: str, calib_iters: int, epoch: int, *,
            canonical: Optional[str] = None
            ) -> Optional[merge_lib.QueryResult]:
        """L1 probe, then L2 on miss (promoting the hit into L1)."""
        if canonical is None:
            canonical = query_lib.canonical_expr(expr)
        hit = super().get(expr, calib_iters, epoch, canonical=canonical)
        if hit is not None or self.l2 is None:
            return hit
        remote = self.l2.get(canonical, calib_iters, epoch, vv=self._vv())
        if remote is None:
            return None
        # promote: future probes hit L1 directly; reclassify the miss
        super().put(expr, calib_iters, epoch, remote, canonical=canonical)
        self.stats.misses -= 1
        self.stats.hits += 1
        self.stats.l2_hits += 1
        return remote

    def put(self, expr: str, calib_iters: int, epoch: int,
            result: merge_lib.QueryResult, *,
            canonical: Optional[str] = None):
        """Install in L1 and write through to the shared tier."""
        if canonical is None:
            canonical = query_lib.canonical_expr(expr)
        super().put(expr, calib_iters, epoch, result, canonical=canonical)
        if self.l2 is not None:
            self.l2.put(canonical, calib_iters, epoch, result,
                        vv=self._vv())

    def put_fragment(self, fragment_key: str, calib_iters: int, epoch: int,
                     result: merge_lib.QueryResult):
        """Install a fragment-level scan by-product in both tiers (the
        shared tier is what makes it a zero-I/O hit on sibling
        front-ends)."""
        before = self.l2.stats.puts if self.l2 is not None else 0
        super().put_fragment(fragment_key, calib_iters, epoch, result)
        if self.l2 is not None and self.l2.stats.puts > before:
            # the L1 super() call wrote the entry through `put`; when the
            # tier actually accepted it (not refused as stale) reclassify
            # it as a fragment install in the L2 stats
            self.l2.stats.fragment_puts += 1

    def _on_dataset_bump(self, epoch: int):
        super()._on_dataset_bump(epoch)
        if self.l2 is not None:
            vv = self._vv()
            if vv is not None:
                self.l2.observe_vv(vv)
            else:
                self.l2.observe_epoch(epoch)
