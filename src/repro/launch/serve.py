"""Serving launcher: batched prefill -> decode loop over the brick-sharded
KV cache.  ``python -m repro.launch.serve --arch <id> --reduced``.

The serve path is the GEPS query flow applied to generation: the prompt
batch is the "job", the KV bricks hold the per-chip context shards, each
decode step computes locally and merges the per-brick softmax partials
(core/brick_attention.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs, reduced_config
from repro.launch.mesh import make_mesh_of, make_production_mesh
from repro.models import model_zoo
from repro.parallel.sharding import Sharder
from repro.train import steps as steps_lib


def prefill_into_cache(cfg, model, params, cache, tokens, shd):
    """Feed a prompt through decode steps to fill the ring cache.

    (Chunked prefill via the forward path is the production fast path; the
    token-by-token fill is used for correctness and small prompts.)"""
    dec = lambda c, t: model.decode_step(params, c, t, shd)
    for i in range(tokens.shape[1]):
        logits, cache = dec(cache, tokens[:, i:i + 1])
    return logits, cache


def generate(cfg, model, params, shd, prompt, max_new_tokens=16,
             cache_len=256, greedy=True):
    b = prompt.shape[0]
    cache = model.init_cache(shd, b, cache_len)
    logits, cache = prefill_into_cache(cfg, model, params, cache, prompt, shd)
    dec = jax.jit(lambda c, t: model.decode_step(params, c, t, shd))
    out = []
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    for _ in range(max_new_tokens):
        out.append(tok)
        logits, cache = dec(cache, tok.astype(jnp.int32))
        tok = jnp.argmax(logits[:, -1 if logits.ndim == 3 else slice(None),
                                :cfg.vocab_size], axis=-1)
        tok = tok.reshape(b, 1)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_mesh_of((len(jax.devices()), 1), ("data", "model")))
    shd = Sharder(cfg, mesh)
    model = model_zoo.build_model(cfg)
    params = model.table.init(jax.random.key(0))
    if cfg.is_encoder_decoder:
        # fill cross-attention cache from stub frames first
        from repro.models import encdec
        frames = jax.random.normal(
            jax.random.key(1), (args.batch, cfg.encoder_seq_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
        cache = model.init_cache(shd, args.batch, 256)
        cache = encdec.prefill_cross_cache(cfg, params, frames, shd, cache)

    prompt = jax.random.randint(jax.random.key(2),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)
    t0 = time.time()
    tokens = generate(cfg, model, params, shd, prompt,
                      max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {tokens.shape} in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", tokens[0, :12].tolist())


if __name__ == "__main__":
    main()
