"""Serving launcher with two modes.

``--mode lm`` (default): batched prefill -> decode loop over the
brick-sharded KV cache.  ``python -m repro.launch.serve --arch <id>
--reduced``.  The serve path is the GEPS query flow applied to generation:
the prompt batch is the "job", the KV bricks hold the per-chip context
shards, each decode step computes locally and merges the per-brick softmax
partials (core/brick_attention.py).

``--mode query``: the multi-tenant GEPS query service —
``python -m repro.launch.serve --mode query --tenants 4 --queries 64``.
Stands up a brick store + QueryService, replays a multi-tenant workload
with repeats, and reports shared-scan amortization and cache hit rates.
Add ``--stream`` for progressive delivery: every ticket gets a
ResultStream fed per-packet prefix merges mid-scan, and the report adds
time-to-first-partial vs time-to-final plus a live coverage trace for one
sample ticket.

``--backend {sim,spmd}`` (query mode) picks the execution backend every
dispatch window runs on: the virtual-time grid simulation (default) or
the SPMD chunked streaming scan over the brick shards (wall-clock
latencies, same streaming/caching/planning behaviour — see
``docs/backends.md``).

``--fleet N`` (query mode) replaces the single QueryService with a
coherence-fabric :class:`~repro.fabric.fleet.Fleet` of N front-ends over
one brick store: submissions round-robin across the fleet, a shared L2
cache tier + persistent fragment registry turn repeats into zero-I/O
hits on ANY front-end, a mid-run dataset bump demonstrates the gossip
invalidation bound, and with ``--stream`` one sample ticket is read
cross-frontend through the bus fan-out.

``--policy`` (query mode) arms the failure-policy engine
(``service/policy.py``): each front-end runs the node state machine over
its health reports, routes around degraded/banned nodes, speculatively
re-executes stragglers, and re-replicates bricks off persistently sick
nodes; fleet mode additionally hardens epoch gossip with ack/repair.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import json

from repro.configs.registry import get_config, list_archs, reduced_config
from repro.launch.mesh import make_mesh_of, make_production_mesh
from repro.models import model_zoo
from repro.parallel.sharding import Sharder
from repro.train import steps as steps_lib


def prefill_into_cache(cfg, model, params, cache, tokens, shd):
    """Feed a prompt through decode steps to fill the ring cache.

    (Chunked prefill via the forward path is the production fast path; the
    token-by-token fill is used for correctness and small prompts.)"""
    dec = lambda c, t: model.decode_step(params, c, t, shd)
    for i in range(tokens.shape[1]):
        logits, cache = dec(cache, tokens[:, i:i + 1])
    return logits, cache


def generate(cfg, model, params, shd, prompt, max_new_tokens=16,
             cache_len=256, greedy=True):
    b = prompt.shape[0]
    cache = model.init_cache(shd, b, cache_len)
    logits, cache = prefill_into_cache(cfg, model, params, cache, prompt, shd)
    dec = jax.jit(lambda c, t: model.decode_step(params, c, t, shd))
    out = []
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    for _ in range(max_new_tokens):
        out.append(tok)
        logits, cache = dec(cache, tok.astype(jnp.int32))
        tok = jnp.argmax(logits[:, -1 if logits.ndim == 3 else slice(None),
                                :cfg.vocab_size], axis=-1)
        tok = tok.reshape(b, 1)
    return jnp.concatenate(out, axis=1)


def _dump_trace(records, path):
    """Write trace records to ``path``: JSONL for ``.jsonl``, Chrome-trace
    JSON (Perfetto-loadable) otherwise."""
    from repro.obs import trace as trace_lib
    if str(path).endswith(".jsonl"):
        trace_lib.save_jsonl(records, path)
    else:
        trace_lib.save_chrome(records, path)
    print(f"  trace: {len(records)} records -> {path}")


def _dump_metrics(snapshot, path):
    """Write a metrics snapshot: Prometheus text exposition format for
    ``.prom`` paths, JSON otherwise."""
    if str(path).endswith(".prom"):
        with open(path, "w") as f:
            f.write(snapshot.to_prom_text())
    else:
        with open(path, "w") as f:
            json.dump(snapshot.to_dict(), f, indent=2, sort_keys=True)
    print(f"  metrics: {len(snapshot.metrics)} series -> {path}")


def _backend_kwargs(args):
    """Collect the SPMD performance knobs from the CLI into the
    ``backend_kwargs`` dict QueryService/Fleet forward to the backend
    constructor.  Returns None for the simulated backend — the knobs are
    scan-path concepts and passing them there should fail loudly, not
    silently no-op."""
    if args.backend != "spmd":
        for flag, name in ((args.use_pallas, "--use-pallas"),
                           (args.chunk_events, "--chunk-events"),
                           (args.adaptive_chunks, "--adaptive-chunks"),
                           (args.mesh_devices, "--mesh-devices"),
                           (args.autotune, "--autotune")):
            if flag:
                raise SystemExit(
                    f"{name} requires --backend spmd (the simulation "
                    "has no kernel scan path)")
        return None
    kw = {}
    if args.use_pallas:
        kw["use_pallas"] = True
    if args.interpret != "auto":
        kw["interpret"] = args.interpret == "interpret"
    if args.chunk_events is not None:
        kw["chunk_events"] = args.chunk_events
    if args.adaptive_chunks:
        kw["adaptive_chunks"] = True
    if args.mesh_devices is not None:
        kw["mesh_devices"] = args.mesh_devices
    if args.autotune:
        kw["autotune"] = True
    return kw or None


def serve_fleet(args):
    """Fleet serving mode: the multi-tenant workload of ``serve_queries``
    replayed round-robin over ``--fleet N`` coherence-fabric front-ends.
    Reports fleet-aggregate hit rates (incl. the shared-L2 contribution),
    the gossip propagation bound, registry pre-warming, and — with
    ``--stream`` — a cross-frontend proxy read of one sample ticket."""
    from repro.configs.geps_events import reduced as geps_reduced
    from repro.core import events as ev
    from repro.core.brick import create_store
    from repro.fabric import Fleet, FragmentRegistry, MessageBus
    from repro.obs import flight as flight_lib

    cfg = geps_reduced()
    schema = ev.EventSchema.from_config(cfg)
    store = create_store(schema, n_events=args.n_events,
                         n_nodes=args.n_nodes,
                         events_per_brick=cfg.events_per_brick,
                         replication=cfg.replication_factor, seed=0)
    want_obs = bool(args.trace_out or args.metrics_dump or args.policy)
    bus = MessageBus(drop_rate=args.drop_rate, seed=args.bus_seed)
    recorder = None
    if args.flight_out:
        # the store_config record makes the log self-contained: replay
        # (python -m repro.obs.replay) rebuilds an equal store from it
        recorder = flight_lib.FlightRecorder()
        recorder.record("store_config", origin="serve",
                        schema_name="geps_reduced", n_events=args.n_events,
                        n_nodes=args.n_nodes,
                        events_per_brick=cfg.events_per_brick,
                        replication=cfg.replication_factor, seed=0)
    fleet = Fleet(store, args.fleet, bus=bus, registry=FragmentRegistry(),
                  backend=args.backend, backend_kwargs=_backend_kwargs(args),
                  obs=want_obs,
                  policy=args.policy, gossip_repair=args.policy,
                  single_flight=args.single_flight,
                  flight=recorder if recorder is not None else False)
    hot = ["e_total > 40 && count(pt > 15) >= 2",
           "e_t_miss > 30", "pt_lead > 60 || n_tracks >= 8"]
    t0 = time.time()
    sample = None
    for i in range(args.queries):
        tenant = f"tenant{i % args.tenants}"
        if i % 3 != 2:
            # hot index advances slower than the submit round-robin, so
            # consecutive submissions of the same hot query land on
            # DIFFERENT front-ends in the same window — the same-window
            # duplicate-scan race single-flight leases exist to close
            expr = hot[(i // 3) % len(hot)]
        else:
            expr = (f"e_total > {20 + (i % 7) * 10} && "
                    f"count(pt > 15) >= {1 + i % 4}")
        gtid = fleet.submit(expr, tenant=tenant, stream=args.stream)
        if sample is None:
            sample = gtid
        if (i + 1) % args.window == 0:
            fleet.step()
        if args.kill_node is not None and i == args.queries // 3:
            # mid-run grid-node death: failover + liveness gossip (and,
            # when recording, the event the replay must reproduce)
            fleet.node_leave(args.kill_node)
        if args.queries > 2 and i == args.queries // 2:
            # mid-run dataset bump on one member: gossip invalidates the
            # whole fleet within the documented bound
            fleet.bump_dataset_version(0)
    fleet.drain()
    dt = time.time() - t0
    s = fleet.fleet_stats()
    print(f"fleet: {args.fleet} front-ends, {s['served']}/{s['submitted']} "
          f"served in {dt:.2f}s ({s['served'] / max(dt, 1e-9):.1f} q/s)")
    print(f"  hit_rate={s['hit_rate']:.3f} (cache_hits={s['cache_hits']}, "
          f"of which l2_hits={s['l2_hits']}), "
          f"events_scanned={s['events_scanned']}")
    if args.single_flight:
        print(f"  single-flight: adopted={s['adopted']} tickets rode a "
              f"remote lease owner's stream "
              f"(fallbacks={s['lease_fallbacks']})")
    print(f"  gossip: bound={fleet.rounds_bound} rounds "
          f"(fanout={fleet.gossip_fanout}), epochs="
          f"{[fe.catalog.dataset_epoch for fe in fleet.frontends]}")
    if fleet.l2 is not None:
        print(f"  shared L2: {len(fleet.l2)} entries, "
              f"{fleet.l2.stats.hits} hits, "
              f"{fleet.l2.stats.fragment_puts} fragment installs")
    if fleet.registry is not None:
        print(f"  registry: {len(fleet.registry)} fragments tracked, "
              f"hot={fleet.registry.hot(4)}")
    if args.policy:
        for fe_id, states in fleet.policy_states().items():
            bad = {n: s for n, s in states.items() if s != "ok"}
            print(f"  policy[{fe_id}]: "
                  f"{bad if bad else 'all nodes ok'} "
                  f"(gossip repair: "
                  f"{fleet.frontends[0].gossip.stats.repairs} repairs)")
            break  # one line is enough; views converge via gossip
    if args.stream and sample is not None:
        owner_idx = fleet.owner_of(sample)
        reader = (owner_idx + 1) % args.fleet
        proxy = fleet.stream(sample, frontend=reader)
        fleet.drain()
        state = proxy.state
        print(f"  cross-frontend stream: ticket {sample} (owner fe"
              f"{owner_idx}) read from fe{reader}: {proxy.published} "
              f"snapshots, state={state}")
    if args.trace_out:
        _dump_trace(fleet.trace_records(), args.trace_out)
    if args.metrics_dump:
        _dump_metrics(fleet.metrics_snapshot(), args.metrics_dump)
    if args.flight_out:
        n = fleet.save_flight(args.flight_out)
        print(f"  flight: {n} records -> {args.flight_out} "
              f"(replay: python -m repro.obs.replay {args.flight_out})")
    fleet.close()


def serve_queries(args):
    """Query-serving mode: multi-tenant traffic over the brick store.

    With ``--adaptive-window`` the service runs a virtual arrival clock at
    ``--arrival-rate`` q/s and lets the EWMA WindowController size each
    dispatch window against measured (virtual) scan latency, instead of
    stepping every fixed ``--window`` submissions.  ``--cost-budget``
    enables per-tenant cost-budgeted admission (planner cost units).
    ``--stream`` turns every submission into a streamed ticket and reports
    progressive-delivery metrics (time-to-first-partial vs final)."""
    from repro.configs.geps_events import reduced as geps_reduced
    from repro.core import events as ev
    from repro.core.brick import create_store
    from repro.service import QueryScheduler, QueryService, WindowController

    cfg = geps_reduced()
    schema = ev.EventSchema.from_config(cfg)
    store = create_store(schema, n_events=args.n_events,
                         n_nodes=args.n_nodes,
                         events_per_brick=cfg.events_per_brick,
                         replication=cfg.replication_factor, seed=0)
    sched = QueryScheduler(
        max_batch=args.window,
        cost_budget_per_tenant=args.cost_budget)
    wc = clock = None
    if args.adaptive_window:
        # virtual clock: arrivals spaced 1/rate apart, same units as the
        # simulator's makespans the controller sees as scan latency
        vnow = [0.0]
        clock = lambda: vnow[0]
        wc = WindowController(initial=args.window)
    obs = None
    if args.trace_out or args.metrics_dump or args.policy:
        from repro.obs import Observability
        obs = Observability(origin="fe0")
    policy = catalog = None
    if args.policy:
        # the policy and the service must judge node liveness from the
        # SAME catalogue, so build it here and hand it to both
        from repro.core.catalog import MetadataCatalog
        from repro.service.policy import FailurePolicy
        catalog = MetadataCatalog(store.n_nodes)
        policy = FailurePolicy(catalog, store, obs=obs)
    svc = QueryService(store, catalog, scheduler=sched, window_controller=wc,
                       backend=args.backend,
                       backend_kwargs=_backend_kwargs(args),
                       obs=obs, policy=policy,
                       **({"clock": clock} if clock else {}))
    # multi-tenant workload: a few hot queries repeated across tenants
    # (the interactive-analysis regime) plus per-tenant near-duplicate
    # long-tail queries sharing aggregate fragments
    hot = ["e_total > 40 && count(pt > 15) >= 2",
           "e_t_miss > 30", "pt_lead > 60 || n_tracks >= 8"]
    t0 = time.time()
    sample_tid = None
    first_partial = {}  # ticket -> t_virtual of its FIRST published snapshot
    for i in range(args.queries):
        tenant = f"tenant{i % args.tenants}"
        if i % 3 != 2:
            expr = hot[i % len(hot)]
        else:
            expr = (f"e_total > {20 + (i % 7) * 10} && "
                    f"count(pt > 15) >= {1 + i % 4}")
        tid = svc.submit(expr, tenant=tenant, stream=args.stream)
        if args.stream:
            # record at publish time: the buffer conflates under
            # backpressure, so reading it later would miss early snapshots
            svc.stream(tid).subscribe(
                lambda s, t=tid: first_partial.setdefault(t, s.t_virtual))
        if sample_tid is None:
            sample_tid = tid
        if args.adaptive_window:
            vnow[0] += 1.0 / args.arrival_rate
            if svc.scheduler.n_pending >= wc.window():
                svc.step()
        elif (i + 1) % args.window == 0:
            svc.step()
    svc.drain()
    dt = time.time() - t0
    s = svc.stats
    scanned_per_query = s.events_scanned / max(1, s.served - s.cache_hits)
    print(f"query-service: {s.served}/{s.submitted} served in {dt:.2f}s "
          f"({s.served / dt:.1f} q/s wall)")
    print(f"  batches={s.batches} jobs_run={s.jobs_run} "
          f"cache_hits={s.cache_hits} rejected={s.rejected}")
    print(f"  events_scanned={s.events_scanned} "
          f"(store={store.n_events} events; "
          f"{scanned_per_query:.0f} scanned/executed-query)")
    if s.fragment_evals:
        print(f"  planner: fragment_evals={s.fragment_evals} "
              f"vs unshared={s.fragment_evals_unshared} "
              f"({s.fragment_evals_unshared / s.fragment_evals:.2f}x "
              f"factored out), "
              f"fragment_cache_puts={svc.cache.stats.fragment_puts}")
    if svc.window_history and args.adaptive_window:
        print(f"  adaptive windows: {svc.window_history}")
    if args.stream:
        ratios = []
        for tid, stream in svc.streams.items():
            if not stream.done or stream.published < 2:
                continue  # cache hits stream a single final snapshot
            ratios.append(first_partial[tid] / stream.latest().t_virtual)
        if ratios:
            print(f"  streaming: {len(svc.streams)} streams, "
                  f"first-partial/final virtual-time ratio "
                  f"{sum(ratios) / len(ratios):.2f} "
                  f"(mean over {len(ratios)} scanned tickets)")
        sample = svc.streams.get(sample_tid)
        if sample is not None and sample.latest() is not None:
            snap = sample.latest()
            cov = snap.coverage
            print(f"  sample ticket {sample_tid}: {sample.published} "
                  f"snapshots ({sample.dropped} conflated), final coverage "
                  f"{cov.events_scanned}/{cov.events_total} events over "
                  f"{len(cov.bricks_seen)}/{cov.bricks_total} bricks")
    if policy is not None:
        states = policy.states()
        bad = {n: st for n, st in states.items() if st != "ok"}
        print(f"  policy: {bad if bad else 'all nodes ok'} "
              f"(speculation {'on' if policy.config.speculate else 'off'})")
    if obs is not None:
        if args.trace_out:
            _dump_trace(obs.tracer.records(), args.trace_out)
        if args.metrics_dump:
            _dump_metrics(obs.metrics.snapshot(), args.metrics_dump)
    svc.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "query"), default="lm")
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    # query mode
    ap.add_argument("--n-events", type=int, default=1024)
    ap.add_argument("--n-nodes", type=int, default=4)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--window", type=int, default=16,
                    help="submissions per dispatch window")
    ap.add_argument("--adaptive-window", action="store_true",
                    help="EWMA-controlled window width (arrival rate vs. "
                         "measured scan latency)")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="virtual arrivals/sec for --adaptive-window")
    ap.add_argument("--cost-budget", type=float, default=None,
                    help="per-tenant pending cost budget (planner units)")
    ap.add_argument("--stream", action="store_true",
                    help="progressive delivery: per-ticket ResultStreams "
                         "fed per-packet prefix merges mid-scan")
    ap.add_argument("--backend", choices=("sim", "spmd"), default="sim",
                    help="execution backend for dispatch windows: the "
                         "virtual-time grid simulation or the SPMD "
                         "chunked streaming shard scan (wall-clock "
                         "latencies; --adaptive-window then observes "
                         "real scan times)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="spmd backend: run in-family plan targets "
                         "through the fused event_filter Pallas kernel "
                         "(mixed windows split per target — see "
                         "docs/backends.md, Performance tuning)")
    ap.add_argument("--interpret", choices=("auto", "interpret",
                                            "compiled"), default="auto",
                    help="spmd backend: Pallas execution mode; auto "
                         "(default) compiles on TPU/GPU and falls back "
                         "to the interpreter on CPU")
    ap.add_argument("--chunk-events", type=int, default=None, metavar="N",
                    help="spmd backend: events per scan chunk "
                         "(= streamed partial granularity)")
    ap.add_argument("--adaptive-chunks", action="store_true",
                    help="spmd backend: size chunks from measured scan "
                         "rate (EWMA ChunkController) instead of a "
                         "fixed --chunk-events")
    ap.add_argument("--mesh-devices", type=int, default=None, metavar="D",
                    help="spmd backend: shard each brick's chunk groups "
                         "over a D-device scan mesh (shard_map when D "
                         "jax devices exist, lockstep emulation "
                         "otherwise)")
    ap.add_argument("--autotune", action="store_true",
                    help="spmd backend: sweep event_filter (block_e, "
                         "block_t) per chunk shape and use the cached "
                         "winner")
    ap.add_argument("--fleet", type=int, default=1,
                    help="query mode: number of coherence-fabric "
                         "front-ends (1 = single QueryService)")
    ap.add_argument("--single-flight", action="store_true",
                    help="query mode with --fleet: scan-intent leases + "
                         "in-flight stream adoption (fabric/leases.py) — "
                         "N duplicate scans become 1 scan + N-1 zero-I/O "
                         "stream subscriptions")
    ap.add_argument("--policy", action="store_true",
                    help="query mode: enable the failure-policy engine "
                         "(node state machine, routing avoidance, "
                         "speculative re-execution, proactive "
                         "re-replication; with --fleet also gossip "
                         "ack/repair) — see docs/policy.md")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="query mode: enable the observability plane and "
                         "write the span trace to PATH (.jsonl = JSONL "
                         "records, anything else = Chrome-trace JSON "
                         "loadable in Perfetto)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="query mode: enable the observability plane and "
                         "write the (fleet-merged) metrics snapshot to "
                         "PATH (.prom = Prometheus text exposition, "
                         "anything else = JSON)")
    ap.add_argument("--flight-out", default=None, metavar="PATH",
                    help="query mode with --fleet: arm the flight "
                         "recorder and write the causal decision log as "
                         "JSONL to PATH; replay with "
                         "'python -m repro.obs.replay PATH'")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="query mode with --fleet: seeded message-loss "
                         "probability on every bus link")
    ap.add_argument("--bus-seed", type=int, default=0,
                    help="query mode with --fleet: RNG seed for the bus "
                         "loss draw (determinism knob for --flight-out)")
    ap.add_argument("--kill-node", type=int, default=None, metavar="N",
                    help="query mode with --fleet: kill grid node N a "
                         "third of the way through the workload "
                         "(failover + liveness gossip)")
    args = ap.parse_args(argv)

    if args.mode == "query":
        if args.fleet > 1:
            serve_fleet(args)
        else:
            serve_queries(args)
        return
    if args.arch is None:
        ap.error("--arch is required for --mode lm")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_mesh_of((len(jax.devices()), 1), ("data", "model")))
    shd = Sharder(cfg, mesh)
    model = model_zoo.build_model(cfg)
    params = model.table.init(jax.random.key(0))
    if cfg.is_encoder_decoder:
        # fill cross-attention cache from stub frames first
        from repro.models import encdec
        frames = jax.random.normal(
            jax.random.key(1), (args.batch, cfg.encoder_seq_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
        cache = model.init_cache(shd, args.batch, 256)
        cache = encdec.prefill_cross_cache(cfg, params, frames, shd, cache)

    prompt = jax.random.randint(jax.random.key(2),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)
    t0 = time.time()
    tokens = generate(cfg, model, params, shd, prompt,
                      max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {tokens.shape} in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", tokens[0, :12].tolist())


if __name__ == "__main__":
    main()
