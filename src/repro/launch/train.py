"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this runs under one process per host with
jax.distributed.initialize(); in this container it runs the same code on
the local device mesh (use --reduced for a smoke-scale config).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_config, list_archs, reduced_config
from repro.launch.mesh import make_mesh_of, make_production_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        mesh = make_mesh_of((len(jax.devices()), 1), ("data", "model"))

    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=args.lr)
    trainer = Trainer(cfg, tcfg, mesh)
    out = trainer.train()
    print(f"done: {out}")


if __name__ == "__main__":
    main()
