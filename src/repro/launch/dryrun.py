import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# NOTE: the two lines above MUST run before any jax-importing module: jax
# locks the device count at first backend init.  Everything else follows.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model_zoo  # noqa: E402
from repro.optim.adamw import AdamW, abstract_opt_state  # noqa: E402
from repro.parallel.sharding import Sharder  # noqa: E402
from repro.train import steps as steps_lib  # noqa: E402

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = [
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "alias_size_in_bytes",
        "temp_size_in_bytes",
    ]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def cell_is_applicable(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 524k-token decode state is "
                       "quadratic-regime; skipped per DESIGN.md section 5")
    return True, ""


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               cfg_override=None):
    """Lower + compile one (arch x shape x mesh) cell. Returns (record, lowered, compiled)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": why}, None, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    shd = Sharder(cfg, mesh)
    model = model_zoo.build_model(cfg)
    table = model.table

    params_abs = table.abstract_sharded(shd)
    batch_abs = model_zoo.input_specs(model, shape, shd)
    t0 = time.time()

    if shape.kind == "train":
        opt = AdamW(moment_dtype=cfg.opt_moment_dtype)
        opt_abs = abstract_opt_state(params_abs, opt, shd)
        step_fn, _ = steps_lib.make_train_step(cfg, model, mesh, opt)
        out_shardings = (
            table.shardings(shd),
            {"m": table.shardings(shd), "v": table.shardings(shd),
             "count": NamedSharding(mesh, P())},
            None,
        )
        jitted = jax.jit(step_fn, out_shardings=out_shardings,
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        step_fn, _ = steps_lib.make_prefill_step(cfg, model, mesh)
        jitted = jax.jit(step_fn)
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        step_fn, _ = steps_lib.make_decode_step(cfg, model, mesh)
        cache_abs = model.init_cache_abstract(shd, shape.global_batch,
                                              shape.seq_len)
        jitted = jax.jit(step_fn, donate_argnums=(1,))
        lowered = jitted.lower(params_abs, cache_abs, batch_abs)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older JAX returns [dict]
        cost = cost[0] if cost else {}
    record = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_params": table.num_params(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "memory": _mem_dict(compiled),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
    }
    return record, lowered, compiled


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save_hlo: bool = True, verbose: bool = True) -> dict:
    record, lowered, compiled = lower_cell(arch, shape_name,
                                           multi_pod=multi_pod)
    if "skipped" in record:
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name}: {record['skipped']}")
        return record

    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape_name}__{record['mesh']}".replace("/", "_")
    if save_hlo:
        hlo_path = ARTIFACT_DIR / f"{stem}.hlo.txt"
        hlo_path.write_text(compiled.as_text())
        record["hlo_path"] = str(hlo_path)
    (ARTIFACT_DIR / f"{stem}.json").write_text(json.dumps(record, indent=2))

    if verbose:
        mem = record["memory"]
        print(f"[dryrun] OK {arch} x {shape_name} mesh={record['mesh']} "
              f"compile={record['compile_s']}s flops={record['flops']:.3e} "
              f"bytes={record['bytes_accessed']:.3e}")
        print(f"  memory_analysis: {mem}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description="GEPS multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, multi_pod=mp,
                             save_hlo=not args.no_hlo)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} x {shape} multi_pod={mp}: {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
