"""Production mesh construction.

NOTE: this module must never touch jax device state at import time — the
mesh is built inside a function so the dry-run can set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips, (data, model).
    Multi-pod: 2 pods x 256 = 512 chips, (pod, data, model); the ``pod``
    axis is the GEPS WAN/site axis (cross-pod traffic = result merge only).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # more devices available than the mesh needs (512-device dry-run process
    # building the single-pod mesh): take a prefix
    devs = np.asarray(devices[:n]).reshape(shape)
    return Mesh(devs, axes)


def make_host_mesh(shape=None, axes=("data", "model")) -> Mesh:
    """Small mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return make_mesh_of(shape, axes)


def make_mesh_of(shape, axes) -> Mesh:
    n = math.prod(shape)
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)
