"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  Terms (seconds per global step, per chip — the SPMD
module IS the per-chip program):

  compute    = HLO_FLOPs_per_chip / 197e12
  memory     = HLO_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9

The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures how
much compiled compute is useful (remat, padded heads, MoE capacity slack,
attention quadratic all land here).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

from repro.analysis import hlo_parse
from repro.analysis.flops import model_flops
from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s/link

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    collective_per_chip: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_total: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)
    collective_by_op: dict
    cost_analysis_flops: float  # raw (loop-body-once) number, for reference

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze_cell(arch: str, shape_name: str, mesh: str = "16x16",
                 hlo_text: Optional[str] = None,
                 record: Optional[dict] = None) -> Roofline:
    stem = f"{arch}__{shape_name}__{mesh}"
    if record is None:
        record = json.loads((ARTIFACT_DIR / f"{stem}.json").read_text())
    if hlo_text is None:
        hlo_text = (ARTIFACT_DIR / f"{stem}.hlo.txt").read_text()

    totals = hlo_parse.analyze(hlo_text)
    chips = 512 if mesh == "2x16x16" else 256
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mf = model_flops(cfg, shape)

    t_c = totals.flops / PEAK_FLOPS
    t_m = totals.bytes / HBM_BW
    t_x = totals.collective_bytes / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh,
        flops_per_chip=totals.flops,
        bytes_per_chip=totals.bytes,
        collective_per_chip=totals.collective_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dom,
        model_flops_total=mf,
        useful_ratio=mf / max(1.0, totals.flops * chips),
        collective_by_op={k: v for k, v in sorted(
            totals.collective_by_op.items())},
        cost_analysis_flops=record.get("flops", -1.0),
    )


def format_row(r: Roofline) -> str:
    return (f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_compute*1e3:.1f} | "
            f"{r.t_memory*1e3:.1f} | {r.t_collective*1e3:.1f} | "
            f"{r.dominant} | {r.useful_ratio:.2f} |")
