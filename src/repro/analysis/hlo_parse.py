"""Optimized-HLO walker: per-device FLOPs, bytes, and collective bytes.

Why not ``compiled.cost_analysis()``: on the CPU backend it counts a
``while`` body ONCE, and our programs are scans-of-scans (microbatch loop x
layer scan x kv-chunk scan), so its numbers are off by the product of trip
counts.  This walker:

1. splits the optimized HLO into computations,
2. reads each while loop's trip count out of its condition computation
   (the ``constant(N)`` the induction variable is compared against),
3. walks the call graph from ENTRY with multiplicities
   (while body x trip count, fusions/calls x 1),
4. accumulates:
   - flops: 2 * prod(out_shape) * contraction_size for every dot (fusion
     internals included), conservative elementwise ignored,
   - bytes: at fusion granularity — operands + outputs of materialized
     ops (fusion internals are free, matching XLA's fusion model),
   - collective bytes: operand sizes of all-reduce / all-gather /
     reduce-scatter / all-to-all / collective-permute.

Everything is per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4, "u64": 8,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
    "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape string like 'f32[2,3]' or
    '(f32[2], bf16[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str       # output shape string
    op: str
    rest: str        # remainder of the line (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    defs: Dict[str, str]  # instr name -> output shape string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = Computation(mc.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        md = _DEF_RE.match(line)
        if md:
            name, shape, op, rest = md.groups()
            cur.instrs.append(Instr(name, shape, op, rest))
            cur.defs[name] = shape
    return comps


def _operand_names(rest: str) -> List[str]:
    """Names of %operands up to the closing paren of the op call."""
    out = []
    depth = 1
    for m in re.finditer(r"%([\w.\-]+)|([()])", rest):
        if m.group(2) == "(":
            depth += 1
        elif m.group(2) == ")":
            depth -= 1
            if depth == 0:
                break
        elif m.group(1) and depth >= 1:
            out.append(m.group(1))
    return out


def dot_flops(instr: Instr, defs: Dict[str, str]) -> int:
    """2 * prod(output) * contraction size (batch dims handled since they
    appear in the output)."""
    ops = _operand_names(instr.rest)
    if not ops:
        return 0
    lhs_shape = defs.get(ops[0], "")
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not m or not lhs_shape:
        return 0
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 0
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contraction = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(lhs_dims):
            contraction *= lhs_dims[i]
    return 2 * shape_elems(instr.shape) * contraction


def while_trip_count(cond: Computation) -> int:
    """Largest s32 constant in the condition — scans compare the induction
    variable against the trip count."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.shape.startswith("s32"):
            m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def instr_bytes(ins: Instr, defs: Dict[str, str]) -> float:
    """HBM traffic model per instruction.

    Slicing ops touch only the slice, not the buffer they index into
    (dynamic-slice of a (L, ...) weight stack inside a scan reads one
    layer's weights, not L layers'); updates are in-place (aliased)."""
    out_b = shape_bytes(ins.shape)
    ops = _operand_names(ins.rest)
    if ins.op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b
    if ins.op == "dynamic-update-slice":
        upd = shape_bytes(defs.get(ops[1], "")) if len(ops) > 1 else out_b
        return 2.0 * upd
    if ins.op == "scatter":
        upd = shape_bytes(defs.get(ops[2], "")) if len(ops) > 2 else out_b
        return 2.0 * upd
    if ins.op in ("reshape", "transpose", "copy", "convert", "broadcast",
                  "reverse", "concatenate", "pad"):
        return 2.0 * out_b
    # dot / reduce / elementwise / select etc: operands + output
    opb = sum(shape_bytes(defs.get(o, "")) for o in ops)
    return opb + out_b


def _internal_bytes(comp: Computation) -> float:
    total = 0.0
    for ins in comp.instrs:
        if ins.op in _SKIP_BYTES_OPS or ins.op == "fusion":
            continue
        total += instr_bytes(ins, comp.defs)
    return total


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    while_loops: List[Tuple[str, int, float]] = dataclasses.field(
        default_factory=list)  # (body name, trip, mult)


def analyze(hlo: str) -> Totals:
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1]

    totals = Totals()
    visited_stack = []

    def walk(comp_name: str, mult: float, count_bytes: bool):
        comp = comps.get(comp_name)
        if comp is None or comp_name in visited_stack:
            return
        visited_stack.append(comp_name)
        for ins in comp.instrs:
            if ins.op == "while":
                m = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                trip = while_trip_count(comps[m.group(1)]) if (
                    m and m.group(1) in comps) else 1
                if mb and mb.group(1) in comps:
                    totals.while_loops.append((mb.group(1), trip, mult))
                    walk(mb.group(1), mult * trip, count_bytes)
                continue
            if ins.op in ("call", "conditional", "custom-call"):
                for mm in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                      ins.rest):
                    walk(mm.group(1), mult, count_bytes)
                continue
            if ins.op == "fusion":
                mm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                callee = mm.group(1) if mm and mm.group(1) in comps else None
                if callee:
                    # fusion internals: flops only (bytes handled below)
                    walk(callee, mult, False)
                if count_bytes:
                    # two estimates, take the smaller:
                    # - boundary: operands + output (right for compute
                    #   fusions, overcounts in-place update fusions whose
                    #   output aliases a whole stacked buffer)
                    # - internals: sum of per-op traffic with slice/DUS
                    #   rules (right for update fusions, overcounts long
                    #   fused elementwise chains)
                    out_b = shape_bytes(ins.shape)
                    boundary = out_b + sum(
                        shape_bytes(comp.defs.get(o, ""))
                        for o in _operand_names(ins.rest))
                    internal = _internal_bytes(comps[callee]) if callee \
                        else boundary
                    totals.bytes += mult * min(boundary, internal)
                continue
            if ins.op == "dot":
                totals.flops += mult * dot_flops(ins, comp.defs)
            if ins.op.startswith("convolution"):
                # rough: 2 * out elems * kernel elems (kernel = operand 1)
                ops = _operand_names(ins.rest)
                kshape = comp.defs.get(ops[1], "") if len(ops) > 1 else ""
                totals.flops += mult * 2 * shape_elems(ins.shape) * max(
                    1, shape_elems(kshape) // max(1, shape_elems(ins.shape)))
            if any(ins.op.startswith(c) for c in COLLECTIVES):
                opb = sum(shape_bytes(comp.defs.get(o, ""))
                          for o in _operand_names(ins.rest))
                totals.collective_bytes += mult * opb
                key = ins.op
                totals.collective_by_op[key] = (
                    totals.collective_by_op.get(key, 0.0) + mult * opb)
            if count_bytes and ins.op not in _SKIP_BYTES_OPS:
                totals.bytes += mult * instr_bytes(ins, comp.defs)
        visited_stack.pop()

    walk(entry, 1.0, True)
    return totals
