"""Analytic MODEL_FLOPS (the 6ND yardstick) per architecture x shape.

MODEL_FLOPS is the *useful* compute: 6 * N * D for dense training
(N = non-embedding params, D = tokens), 6 * N_active * D for MoE, and the
forward third of that (2ND) for prefill; decode counts one token per
sequence.  The ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute,
padded-head waste, MoE capacity slack, and attention's quadratic extra.
"""
from __future__ import annotations

from repro.models import model_zoo
from repro.models.params import np_prod


def param_counts(cfg):
    """(total, embedding-ish, active) parameter counts from the ParamTable."""
    model = model_zoo.build_model(cfg)
    total = 0
    embed = 0
    moe = 0
    for path, d in model.table.defs.items():
        n = np_prod(d.shape)
        total += n
        if "embed" in path or "out/head" in path or "pos/table" in path:
            embed += n
        if "/moe/w_" in path:
            moe += n
    active = total - embed
    if cfg.num_experts and cfg.num_experts_per_tok:
        active -= moe * (1 - cfg.num_experts_per_tok / cfg.num_experts)
    return total, embed, active


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per global step for the cell."""
    total, embed, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * active * tokens
        # embedding/unembed matmul: the unembed dot is real compute
        base += 6.0 * cfg.d_model * cfg.vocab_padded * tokens
        return base
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens + 2.0 * cfg.d_model * cfg.vocab_padded * tokens
    # decode: one token per sequence
    tokens = shape.global_batch
    flops = 2.0 * active * tokens + 2.0 * cfg.d_model * cfg.vocab_padded * tokens
    # attention over the cache: 2 * 2 * H * hd * W per token
    w = min(shape.seq_len, cfg.sliding_window or cfg.attention_window
            or shape.seq_len)
    flops += 4.0 * cfg.num_heads_padded * cfg.head_dim * w * tokens * (
        cfg.num_layers)
    return flops
