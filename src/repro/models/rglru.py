"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel):
    r_t = sigmoid(gate_a(x_t))          # recurrence gate
    i_t = sigmoid(gate_x(x_t))          # input gate
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Gates are block-diagonal linear maps (one block per head), matching the
published architecture and sharding cleanly over the model axis.

Training/prefill uses ``jax.lax.associative_scan`` (the recurrence is a
linear first-order scan -> O(log S) depth); decode is the O(1) step.  The
Pallas TPU kernel in ``repro.kernels.rglru_scan`` implements the chunked
sequential-in-VMEM version; this module is its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

RGLRU_C = 8.0  # the paper's fixed constant


def block_diag_linear(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B,S,W); w: (H, W/H, W/H); b: (H, W/H) -> (B,S,W)."""
    bsz, s, width = x.shape
    h = w.shape[0]
    xh = x.reshape(bsz, s, h, width // h)
    y = jnp.einsum("bshc,hce->bshe", xh, w) + b
    return y.reshape(bsz, s, width)


def rglru_gates(p: dict, x: jax.Array):
    """Returns (log_a, gated_x) for the scan, both (B,S,W) f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(block_diag_linear(xf, p["a_gate_w"].astype(jnp.float32),
                                         p["a_gate_b"].astype(jnp.float32)))
    i = jax.nn.sigmoid(block_diag_linear(xf, p["x_gate_w"].astype(jnp.float32),
                                         p["x_gate_b"].astype(jnp.float32)))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, multiplier * (i * xf)


def rglru_scan(p: dict, x: jax.Array, h0: jax.Array | None = None):
    """Full-sequence RG-LRU via associative scan.

    x: (B,S,W) -> (y (B,S,W), h_last (B,W))."""
    a, bx = rglru_gates(p, x)  # (B,S,W) f32 each
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h0 + b_1
        bx = bx.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(p: dict, x: jax.Array, h_prev: jax.Array):
    """One decode step. x: (B,1,W), h_prev: (B,W) f32 -> (y (B,1,W), h)."""
    a, bx = rglru_gates(p, x)
    h = a[:, 0] * h_prev.astype(jnp.float32) + bx[:, 0]
    return h[:, None, :].astype(x.dtype), h


# --------------------------------------------------------------------------- #
# Full recurrent block: linear -> (conv1d -> RG-LRU) * gelu branch -> linear
# --------------------------------------------------------------------------- #
def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array | None = None):
    """Depthwise causal conv. x:(B,S,W), w:(T,W), b:(W,).
    state: (B,T-1,W) previous inputs for decode. Returns (y, new_state)."""
    t = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], t - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+T-1, W)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(t))
    y = y + b[None, None, :]
    new_state = xp[:, -(t - 1):, :] if t > 1 else jnp.zeros_like(pad)
    return y, new_state


def recurrent_block(cfg, p: dict, x: jax.Array, shd, *,
                    h0=None, conv_state=None, decode=False):
    """Griffin recurrent temporal block. x: (B,S,d).
    Returns (y (B,S,d), (h_last, conv_state))."""
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate"])
    branch = jnp.einsum("bsd,dw->bsw", x, p["w_branch"])
    gate = shd.ws(gate, "batch", None, "tensor")
    branch = shd.ws(branch, "batch", None, "tensor")
    branch, conv_state = causal_conv1d(branch, p["conv_w"], p["conv_b"],
                                       conv_state)
    if decode:
        rec, h_last = rglru_step(p, branch, h0)
    else:
        rec, h_last = rglru_scan(p, branch, h0)
    y = jax.nn.gelu(gate, approximate=True) * rec
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    return shd.act_btd(out), (h_last, conv_state)


def add_recurrent_params(t, cfg, prefix: str, layers: int | None = None):
    d = cfg.d_model
    w = cfg.lru_width or d
    h = cfg.num_heads
    Ls = () if layers is None else (layers,)
    Lr = () if layers is None else ("null",)
    t.add(f"{prefix}/w_gate", Ls + (d, w), Lr + ("fsdp", "tensor"), init="fan_in")
    t.add(f"{prefix}/w_branch", Ls + (d, w), Lr + ("fsdp", "tensor"), init="fan_in")
    t.add(f"{prefix}/conv_w", Ls + (cfg.conv1d_width, w),
          Lr + ("null", "tensor"), init="fan_in")
    t.add(f"{prefix}/conv_b", Ls + (w,), Lr + ("tensor",), init="zeros")
    t.add(f"{prefix}/a_gate_w", Ls + (h, w // h, w // h),
          Lr + ("tensor", "null", "null"), init="fan_in")
    t.add(f"{prefix}/a_gate_b", Ls + (h, w // h), Lr + ("tensor", "null"),
          init="zeros")
    t.add(f"{prefix}/x_gate_w", Ls + (h, w // h, w // h),
          Lr + ("tensor", "null", "null"), init="fan_in")
    t.add(f"{prefix}/x_gate_b", Ls + (h, w // h), Lr + ("tensor", "null"),
          init="zeros")
    t.add(f"{prefix}/lam", Ls + (w,), Lr + ("tensor",), init="lru_a")
    t.add(f"{prefix}/w_out", Ls + (w, d), Lr + ("tensor", "fsdp"), init="fan_in")
