"""xLSTM LM (sLSTM + mLSTM blocks), xLSTM[7:1]-style.

24 layers = 3 super-blocks of (7 mLSTM + 1 sLSTM), scanned over the 3
repeats with stacked params.

mLSTM: matrix-memory cell.  Training/prefill uses the chunkwise-parallel
log-space formulation (same online pattern as flash attention, with gate
decay biases instead of softmax normalization); decode is the O(1)
recurrent update on the (H, hd, hd) matrix state.  The Pallas kernel in
``repro.kernels.mlstm_scan`` implements the chunked VMEM version; this
module is its oracle.

sLSTM: scalar-memory cell with per-head block-diagonal recurrent weights;
inherently sequential -> lax.scan over time.

Both blocks keep O(1) decode state, which is what qualifies xlstm-350m for
the long_500k cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import model_zoo
from repro.models.params import ParamTable
from repro.models.transformer import _remat, embed_tokens, unembed
from repro.models.rglru import block_diag_linear, causal_conv1d

MLSTM_PF = 2.0  # mLSTM up-projection factor
SLSTM_PF = 4.0 / 3.0  # sLSTM post-FFN factor


def _dims(cfg):
    d = cfg.d_model
    inner = int(MLSTM_PF * d)
    h = cfg.num_heads
    return d, inner, h, inner // h, d // h  # d, inner, H, hd_m, hd_s


def _pattern(cfg):
    unit = cfg.xlstm_pattern or ("mlstm",) * 7 + ("slstm",)
    n_super = cfg.num_layers // len(unit)
    assert n_super * len(unit) == cfg.num_layers, (cfg.num_layers, unit)
    return unit, n_super


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def _add_mlstm(t: ParamTable, cfg, prefix, nl):
    d, inner, h, hd, _ = _dims(cfg)
    Ls, Lr = (nl,), ("null",)
    t.add(f"{prefix}/ln/scale", Ls + (d,), Lr + ("null",), init="zeros")
    t.add(f"{prefix}/w_up", Ls + (d, inner), Lr + ("fsdp", "tensor"), init="fan_in")
    t.add(f"{prefix}/w_gate", Ls + (d, inner), Lr + ("fsdp", "tensor"), init="fan_in")
    t.add(f"{prefix}/conv_w", Ls + (cfg.conv1d_width, inner),
          Lr + ("null", "tensor"), init="fan_in")
    t.add(f"{prefix}/conv_b", Ls + (inner,), Lr + ("tensor",), init="zeros")
    t.add(f"{prefix}/wq", Ls + (h, hd, hd), Lr + ("tensor", "null", "null"),
          init="fan_in")
    t.add(f"{prefix}/wk", Ls + (h, hd, hd), Lr + ("tensor", "null", "null"),
          init="fan_in")
    t.add(f"{prefix}/wv", Ls + (h, hd, hd), Lr + ("tensor", "null", "null"),
          init="fan_in")
    t.add(f"{prefix}/w_i", Ls + (inner, h), Lr + ("fsdp", "null"), init="fan_in")
    t.add(f"{prefix}/b_i", Ls + (h,), Lr + ("null",), init="zeros")
    t.add(f"{prefix}/w_f", Ls + (inner, h), Lr + ("fsdp", "null"), init="fan_in")
    t.add(f"{prefix}/b_f", Ls + (h,), Lr + ("null",), init="ones", scale=3.0)
    t.add(f"{prefix}/out_norm/scale", Ls + (inner,), Lr + ("tensor",), init="zeros")
    t.add(f"{prefix}/w_down", Ls + (inner, d), Lr + ("tensor", "fsdp"),
          init="fan_in")


def _add_slstm(t: ParamTable, cfg, prefix, nl):
    d, _, h, _, hd = _dims(cfg)
    Ls, Lr = (nl,), ("null",)
    t.add(f"{prefix}/ln/scale", Ls + (d,), Lr + ("null",), init="zeros")
    for g in ("z", "i", "f", "o"):
        t.add(f"{prefix}/w_{g}", Ls + (d, d), Lr + ("fsdp", "null"), init="fan_in")
        t.add(f"{prefix}/r_{g}", Ls + (h, hd, hd), Lr + ("null", "null", "null"),
              init="fan_in", scale=0.01)
        t.add(f"{prefix}/b_{g}", Ls + (d,), Lr + ("null",),
              init="ones" if g == "f" else "zeros")
    t.add(f"{prefix}/out_norm/scale", Ls + (d,), Lr + ("null",), init="zeros")
    # post-FFN (pf = 4/3 gated)
    f_ff = int(SLSTM_PF * d)
    t.add(f"{prefix}/ln_ff/scale", Ls + (d,), Lr + ("null",), init="zeros")
    t.add(f"{prefix}/ff_gate", Ls + (d, f_ff), Lr + ("fsdp", "tensor"), init="fan_in")
    t.add(f"{prefix}/ff_in", Ls + (d, f_ff), Lr + ("fsdp", "tensor"), init="fan_in")
    t.add(f"{prefix}/ff_out", Ls + (f_ff, d), Lr + ("tensor", "fsdp"), init="fan_in")


def param_table(cfg) -> ParamTable:
    t = ParamTable(cfg)
    d, vp = cfg.d_model, cfg.vocab_padded
    unit, n_super = _pattern(cfg)
    t.add("embed/table", (vp, d), ("tensor", "fsdp"), init="normal")
    if not cfg.tie_embeddings:
        t.add("out/head", (d, vp), ("fsdp", "tensor"), init="fan_in")
    t.add("final_norm/scale", (d,), ("null",), init="zeros")
    for j, kind in enumerate(unit):
        prefix = f"blocks/u{j}"
        (_add_mlstm if kind == "mlstm" else _add_slstm)(t, cfg, prefix, n_super)
    return t


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #
def _mlstm_qkv_gates(cfg, p, x):
    """x: (B,S,d). Returns q,k,v (B,S,H,hd), log_i, log_f (B,S,H) f32."""
    d, inner, h, hd, _ = _dims(cfg)
    b, s, _ = x.shape
    xu = jnp.einsum("bsd,de->bse", x, p["w_up"])  # (B,S,inner)
    xc, _ = causal_conv1d(xu, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    xh = xc.reshape(b, s, h, hd)
    q = jnp.einsum("bshc,hce->bshe", xh, p["wq"])
    k = jnp.einsum("bshc,hce->bshe", xh, p["wk"])
    v = jnp.einsum("bshc,hce->bshe", xu.reshape(b, s, h, hd), p["wv"])
    xuf = xu.astype(jnp.float32)
    log_i = (jnp.einsum("bse,eh->bsh", xuf, p["w_i"].astype(jnp.float32))
             + p["b_i"].astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xuf, p["w_f"].astype(jnp.float32))
        + p["b_f"].astype(jnp.float32))
    return xu, q, k, v, log_i, log_f


def mlstm_parallel(cfg, q, k, v, log_i, log_f, chunk_size=1024):
    """Chunkwise-parallel mLSTM (the flash-attention-like oracle).

    Tiled over BOTH q and kv (flash-style): the online accumulators live
    per q-block, so the backward pass never stores a full-sequence f32
    (B,S,H,hd) carry per kv chunk — at xlstm-350m train_4k that carry was
    30+ GB/chip of scan residuals.

    q,k,v: (B,S,H,hd); log_i/log_f: (B,S,H) f32.
    Returns h: (B,S,H,hd)."""
    b, s, h, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    F = jnp.cumsum(log_f, axis=1)  # (B,S,H): sum of log f up to and incl. t

    c = min(chunk_size, s)
    n_chunks = -(-s // c)
    pad = n_chunks * c - s
    if pad:
        pads = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, pads)
        k = jnp.pad(k, pads)
        v = jnp.pad(v, pads)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        F_q = jnp.pad(F, ((0, 0), (0, pad), (0, 0)), mode="edge")
    else:
        F_q = F
    sp = n_chunks * c

    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qc = qf.reshape(b, n_chunks, c, h, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, n_chunks, c, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, c, h, hd).transpose(1, 0, 2, 3, 4)
    ic = log_i.reshape(b, n_chunks, c, h).transpose(1, 0, 2, 3)
    Fc = F_q.reshape(b, n_chunks, c, h).transpose(1, 0, 2, 3)
    idx = jnp.arange(sp).reshape(n_chunks, c)

    @jax.checkpoint
    def q_block(args):
        q_i, F_i, qidx = args  # (B,c,H,hd), (B,c,H), (c,)

        def kv_step(carry, xs):
            m, num, den = carry  # (B,c,H), (B,c,H,hd), (B,c,H)
            k_j, v_j, li_j, F_j, kidx = xs
            logw = (F_i[:, :, None, :] - F_j[:, None, :, :]
                    + li_j[:, None, :, :])  # (B,c,c,H)
            mask = kidx[None, :] <= qidx[:, None]  # (c,c)
            logw = jnp.where(mask[None, :, :, None], logw, -1e30)
            logw = logw.transpose(0, 1, 3, 2)  # (B,c,H,c)
            m_new = jnp.maximum(m, jnp.max(logw, axis=-1))
            wts = jnp.exp(logw - m_new[..., None])
            corr = jnp.exp(m - m_new)
            sc = jnp.einsum("bqhd,bchd->bqhc", q_i, k_j,
                            preferred_element_type=jnp.float32)
            a = wts * sc  # (B,c,H,c)
            num = num * corr[..., None] + jnp.einsum(
                "bqhc,bchd->bqhd", a.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            den = den * corr + jnp.sum(a, axis=-1)
            return (m_new, num, den), None

        m0 = jnp.full((b, c, h), -1e30, jnp.float32)
        num0 = jnp.zeros((b, c, h, hd), jnp.float32)
        den0 = jnp.zeros((b, c, h), jnp.float32)
        (m, num, den), _ = jax.lax.scan(
            kv_step, (m0, num0, den0), (kc, vc, ic, Fc, idx))
        normalizer = jnp.maximum(jnp.abs(den), jnp.exp(-m))
        return (num / normalizer[..., None]).astype(q.dtype)

    out = jax.lax.map(q_block, (qc, Fc, idx))  # (n_chunks, B, c, H, hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, hd)
    return out[:, :s]


def mlstm_block(cfg, p, x, shd):
    """Full mLSTM residual block. x: (B,S,d)."""
    d, inner, h, hd, _ = _dims(cfg)
    b, s, _ = x.shape
    xin = L.rmsnorm(x, p["ln"]["scale"], cfg.norm_eps)
    xu, q, k, v, log_i, log_f = _mlstm_qkv_gates(cfg, p, xin)
    hh = mlstm_parallel(cfg, q, k, v, log_i, log_f)
    hh = hh.reshape(b, s, inner)
    hh = L.rmsnorm(hh, p["out_norm"]["scale"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", xin, p["w_gate"])
    y = hh * jax.nn.silu(z)
    return x + shd.act_btd(jnp.einsum("bse,ed->bsd", y, p["w_down"]))


def mlstm_decode(cfg, p, x, state, shd):
    """One-token mLSTM step. state: dict(C (B,H,hd,hd), n (B,H,hd), m (B,H),
    conv (B,T-1,inner)) all f32 except conv."""
    d, inner, h, hd, _ = _dims(cfg)
    b = x.shape[0]
    xin = L.rmsnorm(x, p["ln"]["scale"], cfg.norm_eps)
    xu = jnp.einsum("bsd,de->bse", xin, p["w_up"])
    xc, conv = causal_conv1d(xu, p["conv_w"], p["conv_b"], state["conv"])
    xc = jax.nn.silu(xc)
    xh = xc.reshape(b, 1, h, hd)
    q = jnp.einsum("bshc,hce->bshe", xh, p["wq"])[:, 0]  # (B,H,hd)
    kk = jnp.einsum("bshc,hce->bshe", xh, p["wk"])[:, 0]
    vv = jnp.einsum("bshc,hce->bshe", xu.reshape(b, 1, h, hd), p["wv"])[:, 0]
    xuf = xu.astype(jnp.float32)[:, 0]
    log_i = (xuf @ p["w_i"].astype(jnp.float32)) + p["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (xuf @ p["w_f"].astype(jnp.float32)) + p["b_f"].astype(jnp.float32))

    m_new = jnp.maximum(log_f + state["m"], log_i)  # (B,H)
    decay = jnp.exp(log_f + state["m"] - m_new)
    inp = jnp.exp(log_i - m_new)
    kf = kk.astype(jnp.float32)
    vf = vv.astype(jnp.float32)
    C = (state["C"] * decay[..., None, None]
         + inp[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kf, vf))
    n = state["n"] * decay[..., None] + inp[..., None] * kf
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.sum(n * qf, axis=-1)), jnp.exp(-m_new))
    hh = (num / den[..., None]).reshape(b, 1, inner).astype(x.dtype)
    hh = L.rmsnorm(hh, p["out_norm"]["scale"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", xin, p["w_gate"])
    y = hh * jax.nn.silu(z)
    out = x + shd.act_btd(jnp.einsum("bse,ed->bsd", y, p["w_down"]))
    return out, {"C": C, "n": n, "m": m_new, "conv": conv}


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #
def _slstm_cell(cfg, p, zifo, state):
    """One time step. zifo: tuple of (B,d) pre-activations (x-part only).
    state: (c,n,h,m) each (B,d) f32. Returns (h_out (B,d), new state)."""
    d, _, heads, _, hd = _dims(cfg)
    b = zifo[0].shape[0]
    h_prev = state["h"]
    hh = h_prev.reshape(b, heads, hd)

    def rec(w):  # (H, hd, hd) applied per head
        return jnp.einsum("bhc,hce->bhe", hh, w.astype(jnp.float32)).reshape(b, d)

    z = jnp.tanh(zifo[0] + rec(p["r_z"]))
    i_raw = zifo[1] + rec(p["r_i"])
    f_raw = zifo[2] + rec(p["r_f"])
    o = jax.nn.sigmoid(zifo[3] + rec(p["r_o"]))

    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + state["m"], i_raw)
    i_st = jnp.exp(i_raw - m_new)
    f_st = jnp.exp(log_f + state["m"] - m_new)
    c = f_st * state["c"] + i_st * z
    n = f_st * state["n"] + i_st
    h_out = o * c / jnp.maximum(n, 1e-6)
    return h_out, {"c": c, "n": n, "h": h_out, "m": m_new}


def slstm_block(cfg, p, x, shd, state=None, decode=False):
    """sLSTM residual block + post-FFN. x: (B,S,d)."""
    d, _, heads, _, hd = _dims(cfg)
    b, s, _ = x.shape
    xin = L.rmsnorm(x, p["ln"]["scale"], cfg.norm_eps)
    xf = xin.astype(jnp.float32)
    pre = {g: jnp.einsum("bsd,de->bse", xf, p[f"w_{g}"].astype(jnp.float32))
           + p[f"b_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}
    if state is None:
        state = {k: jnp.zeros((b, d), jnp.float32) for k in ("c", "n", "h")}
        state["m"] = jnp.full((b, d), -1e30, jnp.float32)

    if decode:
        h_out, state = _slstm_cell(
            cfg, p, tuple(pre[g][:, 0] for g in ("z", "i", "f", "o")), state)
        hs = h_out[:, None, :]
    else:
        def step(st, zifo):
            h_out, st = _slstm_cell(cfg, p, zifo, st)
            return st, h_out

        xs = tuple(pre[g].transpose(1, 0, 2) for g in ("z", "i", "f", "o"))
        # time-chunked remat: saving all S per-step residuals for backward
        # costs O(S) f32 state tensors (58 GB/chip at train_4k); checkpoint
        # at chunk boundaries and recompute inside — O(S/C) saved states.
        chunk = 256
        if s > chunk and s % chunk == 0:
            xs = tuple(a.reshape(s // chunk, chunk, *a.shape[1:])
                       for a in xs)

            @jax.checkpoint
            def chunk_step(st, zifo_chunk):
                st, hs = jax.lax.scan(step, st, zifo_chunk)
                return st, hs

            state, hs = jax.lax.scan(chunk_step, state, xs)
            hs = hs.reshape(s, *hs.shape[2:])
        else:
            state, hs = jax.lax.scan(step, state, xs)
        hs = hs.transpose(1, 0, 2)  # (B,S,d)

    hs = L.rmsnorm(hs.astype(x.dtype), p["out_norm"]["scale"], cfg.norm_eps)
    x = x + shd.act_btd(hs)
    # post-FFN
    hf = L.rmsnorm(x, p["ln_ff"]["scale"], cfg.norm_eps)
    gate = jnp.einsum("bsd,df->bsf", hf, p["ff_gate"])
    up = jnp.einsum("bsd,df->bsf", hf, p["ff_in"])
    y = jax.nn.silu(gate) * up
    x = x + shd.act_btd(jnp.einsum("bsf,fd->bsd", y, p["ff_out"]))
    return x, state


# --------------------------------------------------------------------------- #
# Model assembly
# --------------------------------------------------------------------------- #
def forward(cfg, params, tokens, shd):
    unit, n_super = _pattern(cfg)
    x = embed_tokens(cfg, params, tokens, shd)

    def super_block(p, x):
        for j, kind in enumerate(unit):
            pj = p[f"u{j}"]
            if kind == "mlstm":
                x = mlstm_block(cfg, pj, x, shd)
            else:
                x, _ = slstm_block(cfg, pj, x, shd)
        return (x,)

    body = _remat(cfg, super_block)
    if cfg.scan_layers:
        (x,), _ = jax.lax.scan(lambda c, p: (body(p, c[0]), None), (x,),
                               params["blocks"])
    else:
        for i in range(n_super):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            (x,) = body(p_i, x)

    x = L.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(cfg, params, x, shd), jnp.float32(0.0)


def init_cache_abstract(cfg, shd, batch: int, seq_len: int):
    d, inner, h, hd, hd_s = _dims(cfg)
    unit, n_super = _pattern(cfg)
    n_m = sum(1 for k in unit if k == "mlstm")
    n_s = len(unit) - n_m
    ct = cfg.conv1d_width - 1
    dt = jnp.dtype(cfg.dtype)

    def sds(shape, roles, dtype=jnp.float32):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=shd.named(roles, shape))

    return {
        "C": sds((n_m, n_super, batch, h, hd, hd),
                 ("null", "null", "batch", "null", "null", "null")),
        "n": sds((n_m, n_super, batch, h, hd),
                 ("null", "null", "batch", "null", "null")),
        "m": sds((n_m, n_super, batch, h),
                 ("null", "null", "batch", "null")),
        "conv": sds((n_m, n_super, batch, ct, inner),
                    ("null", "null", "batch", "null", "tensor"), dt),
        "s_c": sds((n_s, n_super, batch, d), ("null", "null", "batch", "null")),
        "s_n": sds((n_s, n_super, batch, d), ("null", "null", "batch", "null")),
        "s_h": sds((n_s, n_super, batch, d), ("null", "null", "batch", "null")),
        "s_m": sds((n_s, n_super, batch, d), ("null", "null", "batch", "null")),
        "t": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg, shd, batch: int, seq_len: int):
    abs_cache = init_cache_abstract(cfg, shd, batch, seq_len)
    cache = {k: jnp.zeros(s.shape, s.dtype) for k, s in abs_cache.items()}
    cache["m"] = cache["m"] - 1e30
    cache["s_m"] = cache["s_m"] - 1e30
    return cache


def decode_step(cfg, params, cache, tokens, shd):
    unit, n_super = _pattern(cfg)
    x = embed_tokens(cfg, params, tokens, shd)

    def scan_fn(x, xs):
        p, C, n, m, conv, s_c, s_n, s_h, s_m = xs
        mi = si = 0
        newC, newn, newm, newconv = [], [], [], []
        new_s = {"c": [], "n": [], "h": [], "m": []}
        for j, kind in enumerate(unit):
            pj = p[f"u{j}"]
            if kind == "mlstm":
                st = {"C": C[mi], "n": n[mi], "m": m[mi], "conv": conv[mi]}
                x, st = mlstm_decode(cfg, pj, x, st, shd)
                newC.append(st["C"])
                newn.append(st["n"])
                newm.append(st["m"])
                newconv.append(st["conv"])
                mi += 1
            else:
                st = {"c": s_c[si], "n": s_n[si], "h": s_h[si], "m": s_m[si]}
                x, st = slstm_block(cfg, pj, x, shd, state=st, decode=True)
                for key in new_s:
                    new_s[key].append(st[key])
                si += 1
        ys = (jnp.stack(newC), jnp.stack(newn), jnp.stack(newm),
              jnp.stack(newconv), jnp.stack(new_s["c"]), jnp.stack(new_s["n"]),
              jnp.stack(new_s["h"]), jnp.stack(new_s["m"]))
        return x, ys

    tr = lambda a: jnp.swapaxes(a, 0, 1)  # (n_kind, n_super, ...) -> scan axis
    xs = (params["blocks"], tr(cache["C"]), tr(cache["n"]), tr(cache["m"]),
          tr(cache["conv"]), tr(cache["s_c"]), tr(cache["s_n"]),
          tr(cache["s_h"]), tr(cache["s_m"]))
    x, ys = jax.lax.scan(scan_fn, x, xs)
    C, n, m, conv, s_c, s_n, s_h, s_m = (tr(y) for y in ys)

    x = L.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(cfg, params, x, shd)
    new_cache = {"C": C, "n": n, "m": m, "conv": conv, "s_c": s_c,
                 "s_n": s_n, "s_h": s_h, "s_m": s_m, "t": cache["t"] + 1}
    return logits, new_cache


# --------------------------------------------------------------------------- #
def build(cfg) -> "model_zoo.Model":
    table = param_table(cfg)

    def fwd(params, batch, shd):
        return forward(cfg, params, batch["tokens"], shd)

    return model_zoo.Model(
        cfg=cfg,
        table=table,
        forward=fwd,
        decode_step=lambda params, cache, tokens, shd: decode_step(
            cfg, params, cache, tokens, shd),
        init_cache_abstract=lambda shd, b, s: init_cache_abstract(cfg, shd, b, s),
        init_cache=lambda shd, b, s: init_cache(cfg, shd, b, s),
        extra_inputs=lambda shape, shd: {},
    )
