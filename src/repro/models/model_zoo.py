"""Family dispatch: one ``Model`` facade per architecture family.

Every family exposes the same functional surface so the launcher, dry-run,
trainer and JSE treat all 10 assigned architectures uniformly:

  param_table()                       -> ParamTable
  forward(params, batch, shd)         -> (logits, aux_loss)     train/prefill
  init_cache_abstract(shd, B, S)      -> cache SDS pytree        decode
  decode_step(params, cache, tok, shd)-> (logits, cache)
  input_specs(shape, shd)             -> abstract batch pytree
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.models import transformer
from repro.models.params import ParamTable


@dataclasses.dataclass
class Model:
    cfg: object
    table: ParamTable
    forward: Callable  # (params, batch, shd) -> (logits, aux)
    decode_step: Callable  # (params, cache, tokens, shd) -> (logits, cache)
    init_cache_abstract: Callable  # (shd, batch, seq_len) -> pytree
    init_cache: Callable
    extra_inputs: Callable  # (shape, shd) -> dict of extra abstract inputs


def _token_sds(shd, batch, seq):
    return jax.ShapeDtypeStruct(
        (batch, seq), jnp.int32,
        sharding=shd.named(("batch", None), (batch, seq)))


def input_specs(model: Model, shape, shd) -> dict:
    """Abstract (ShapeDtypeStruct) inputs for one shape cell."""
    cfg = model.cfg
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": _token_sds(shd, shape.global_batch, shape.seq_len),
            "labels": _token_sds(shd, shape.global_batch, shape.seq_len),
        }
    else:  # decode: one new token, cache of seq_len
        specs = {"tokens": _token_sds(shd, shape.global_batch, 1)}
    specs.update(model.extra_inputs(shape, shd))
    return specs


# --------------------------------------------------------------------------- #
def build_model(cfg) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _decoder_lm(cfg)
    if cfg.family == "audio":
        from repro.models import encdec
        return encdec.build(cfg)
    if cfg.family == "hybrid":
        from repro.models import hybrid
        return hybrid.build(cfg)
    if cfg.family == "ssm":
        from repro.models import xlstm
        return xlstm.build(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def _decoder_lm(cfg) -> Model:
    table = transformer.param_table(cfg)

    def fwd(params, batch, shd):
        return transformer.forward(cfg, params, batch["tokens"], shd,
                                   patch_embeds=batch.get("patch_embeds"))

    def dec(params, cache, tokens, shd):
        return transformer.decode_step(cfg, params, cache, tokens, shd)

    def extra(shape, shd):
        if cfg.num_patches and shape.kind in ("train", "prefill"):
            sh = (shape.global_batch, cfg.num_patches, cfg.d_model)
            return {"patch_embeds": jax.ShapeDtypeStruct(
                sh, jnp.dtype(cfg.dtype),
                sharding=shd.named(("batch", None, None), sh))}
        return {}

    return Model(
        cfg=cfg,
        table=table,
        forward=fwd,
        decode_step=dec,
        init_cache_abstract=lambda shd, b, s: transformer.init_cache_abstract(
            cfg, shd, b, s),
        init_cache=lambda shd, b, s: transformer.init_cache(cfg, shd, b, s),
        extra_inputs=extra,
    )
