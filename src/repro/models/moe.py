"""Mixture-of-Experts block: grouped top-k routing with capacity dropping.

Implementation is the MaxText/Switch "grouped one-hot dispatch" formulation:
tokens are split into routing groups of ``cfg.moe_group_size`` so the dispatch
tensor is (G, Sg, E, C) with C = Sg * topk / E * capacity_factor — memory
scales linearly in group size instead of quadratically in tokens.

Sharding strategies (cfg.moe_sharding):
  "tp": experts replicated across the model axis, d_ff sharded (grok-1:
        8 experts do not divide 16-way TP; expert compute stays local and
        only activation collectives occur — the GEPS-faithful choice).
  "ep": expert dim sharded over the model axis (phi3.5-moe: 16 experts ==
        16-way axis; dispatch becomes an all-to-all, the classic EP layout).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def moe_capacity(cfg, group_size: int) -> int:
    c = int(
        math.ceil(group_size * cfg.num_experts_per_tok / cfg.num_experts
                  * cfg.moe_capacity_factor)
    )
    return max(8, ((c + 7) // 8) * 8)  # round to 8 for lane alignment


def moe_block(cfg, p: dict, x: jax.Array, shd):
    """x: (B, S, d) -> ((B, S, d), aux_loss scalar f32)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    sg = min(cfg.moe_group_size, s)
    assert s % sg == 0, (s, sg)
    g = b * (s // sg)
    cap = moe_capacity(cfg, sg)

    xg = x.reshape(g, sg, d)
    xg = shd.ws(xg, "batch", None, None)

    # --- router (f32) ---
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G,Sg,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G,Sg,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balancing auxiliary loss (Switch) ---
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )  # fraction of tokens routed to each expert
    aux_loss = e * jnp.sum(me * ce)

    # --- capacity assignment: position of each token in its expert queue ---
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (G,Sg,k,E)
    # priority: earlier tokens first; cumulative count per expert
    pos_in_expert = jnp.cumsum(onehot.reshape(g, sg * k, e), axis=1) - 1.0
    pos_in_expert = pos_in_expert.reshape(g, sg, k, e)
    within_cap = (pos_in_expert < cap) & (onehot > 0)
    cap_idx = jnp.sum(pos_in_expert * onehot, axis=-1).astype(jnp.int32)  # (G,Sg,k)

    # dispatch (G,Sg,E,C) / combine (G,Sg,E,C) tensors
    cap_onehot = jax.nn.one_hot(cap_idx, cap, dtype=jnp.float32)  # (G,Sg,k,C)
    mask = jnp.where(within_cap, onehot, 0.0)  # (G,Sg,k,E)
    dispatch = jnp.einsum("gske,gskc->gsec", mask, cap_onehot)
    combine = jnp.einsum("gske,gskc,gsk->gsec", mask, cap_onehot,
                         gate_vals.astype(jnp.float32))

    dispatch = shd.ws(dispatch.astype(x.dtype), "batch", None, "expert", None)

    # --- expert computation ---
    xe = jnp.einsum("gsd,gsec->egcd", xg, dispatch)  # (E,G,C,d)
    xe = shd.ws(xe, "expert", "batch", None, None)
    gate = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"])
    up = jnp.einsum("egcd,edf->egcf", xe, p["w_in"])
    gate = shd.ws(gate, "expert", "batch", None, "moe_ff")
    up = shd.ws(up, "expert", "batch", None, "moe_ff")
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_out"])  # (E,G,C,d)
    ye = shd.ws(ye, "expert", "batch", None, None)

    # --- combine back to token order ---
    # combine in the compute dtype: an f32 combine tensor would make every
    # backward cotangent f32, doubling all expert weight-grad stacks (on
    # TPU the MXU accumulates bf16 dots in f32 anyway)
    out = jnp.einsum("egcd,gsec->gsd", ye,
                     combine.astype(ye.dtype)).astype(x.dtype)
    out = out.reshape(b, s, d)
    return shd.act_btd(out), aux_loss


def add_moe_params(table, cfg, prefix: str, layers: int | None = None):
    L = () if layers is None else (layers,)
    Lr = () if layers is None else ("null",)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    table.add(f"{prefix}/router", L + (d, e), Lr + ("fsdp", "null"),
              init="fan_in", dtype="float32")
    table.add(f"{prefix}/w_gate", L + (e, d, f), Lr + ("expert", "moe_d", "moe_ff"),
              init="fan_in")
    table.add(f"{prefix}/w_in", L + (e, d, f), Lr + ("expert", "moe_d", "moe_ff"),
              init="fan_in")
    table.add(f"{prefix}/w_out", L + (e, f, d), Lr + ("expert", "moe_ff", "moe_d"),
              init="fan_in")
