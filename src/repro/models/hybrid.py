"""Hybrid recurrent/attention LM (RecurrentGemma-9B / Griffin).

Block pattern: (recurrent, recurrent, local-attention) repeated.  38 layers
= 12 super-blocks of 3 + a tail of 2 recurrent blocks; the 12 super-blocks
run under one lax.scan (stacked params), the tail is unrolled — keeping the
compiled HLO at ~one super-block regardless of depth.

Each block unit is a Griffin residual pair: x += temporal(norm(x));
x += geglu_mlp(norm(x)).  Temporal is either the RG-LRU recurrent block
(models/rglru.py) or local sliding-window MQA attention.

Decode state: per recurrent layer an RG-LRU hidden (B, W_lru) f32 + conv
state (B, 3, W_lru); per attention layer a ring KV cache bounded by the
attention window (2048) — this is why long_500k decode is O(window), the
sub-quadratic property the cell requires.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import mlp as mlp_lib
from repro.models import model_zoo
from repro.models import rglru
from repro.models.params import ParamTable
from repro.models.transformer import (
    _remat,
    add_attn_layer_params,
    attn_out_proj,
    attn_qkv,
    embed_tokens,
    head_mask,
    unembed,
)


def _pattern(cfg):
    """Returns (n_super, tail): 38 -> (12, ('rec','rec'))."""
    unit = cfg.block_pattern or ("rec", "rec", "attn")
    n_super = cfg.num_layers // len(unit)
    n_tail = cfg.num_layers - n_super * len(unit)
    return unit, n_super, unit[:n_tail]


def param_table(cfg) -> ParamTable:
    t = ParamTable(cfg)
    d, vp = cfg.d_model, cfg.vocab_padded
    unit, n_super, tail = _pattern(cfg)

    t.add("embed/table", (vp, d), ("tensor", "fsdp"), init="normal")
    t.add("final_norm/scale", (d,), ("null",), init="zeros")

    for j, kind in enumerate(unit):
        prefix = f"blocks/u{j}"
        if kind == "rec":
            t.add(f"{prefix}/ln1/scale", (n_super, d), ("null", "null"), init="zeros")
            t.add(f"{prefix}/ln2/scale", (n_super, d), ("null", "null"), init="zeros")
            rglru.add_recurrent_params(t, cfg, f"{prefix}/rec", n_super)
            mlp_lib.add_mlp_params(t, cfg, f"{prefix}/mlp", n_super)
        else:
            add_attn_layer_params(t, cfg, prefix, n_super)
            mlp_lib.add_mlp_params(t, cfg, f"{prefix}/mlp", n_super)
    for j, kind in enumerate(tail):
        prefix = f"tail/u{j}"
        t.add(f"{prefix}/ln1/scale", (d,), ("null",), init="zeros")
        t.add(f"{prefix}/ln2/scale", (d,), ("null",), init="zeros")
        rglru.add_recurrent_params(t, cfg, f"{prefix}/rec", None)
        mlp_lib.add_mlp_params(t, cfg, f"{prefix}/mlp", None)
    return t


# --------------------------------------------------------------------------- #
def _rec_unit(cfg, p, x, shd, *, h0=None, conv0=None, decode=False):
    h = L.norm(cfg, x, p["ln1"]["scale"])
    y, (h_last, conv_state) = rglru.recurrent_block(
        cfg, p["rec"], h, shd, h0=h0, conv_state=conv0, decode=decode)
    x = x + y
    h = L.norm(cfg, x, p["ln2"]["scale"])
    x = x + mlp_lib.mlp(cfg, p["mlp"], h, shd)
    return x, (h_last, conv_state)


def _attn_unit(cfg, p, x, shd, positions):
    h = L.norm(cfg, x, p["ln1"]["scale"])
    q, k, v = attn_qkv(cfg, p["attn"], h, shd, positions)
    out = attn_lib.attention(
        q, k, v, q_positions=positions, k_positions=positions, causal=True,
        window=cfg.attention_window, scale=cfg.attn_scale_override,
        logit_cap=cfg.attn_logit_softcap)
    x = x + attn_out_proj(cfg, p["attn"], shd.act_bthd(out), shd)
    h = L.norm(cfg, x, p["ln2"]["scale"])
    return x + mlp_lib.mlp(cfg, p["mlp"], h, shd)


def forward(cfg, params, tokens, shd):
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = embed_tokens(cfg, params, tokens, shd)
    unit, n_super, tail = _pattern(cfg)

    def super_block(p, x):
        for j, kind in enumerate(unit):
            pj = p[f"u{j}"]
            if kind == "rec":
                x, _ = _rec_unit(cfg, pj, x, shd)
            else:
                x = _attn_unit(cfg, pj, x, shd, positions)
        return (x,)

    body = _remat(cfg, super_block)
    if cfg.scan_layers:
        (x,), _ = jax.lax.scan(lambda c, p: (body(p, c[0]), None), (x,),
                               params["blocks"])
    else:
        for i in range(n_super):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            (x,) = body(p_i, x)

    for j, kind in enumerate(tail):
        x, _ = _rec_unit(cfg, params["tail"][f"u{j}"], x, shd)

    x = L.norm(cfg, x, params["final_norm"]["scale"])
    return unembed(cfg, params, x, shd), jnp.float32(0.0)


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #
def init_cache_abstract(cfg, shd, batch: int, seq_len: int):
    unit, n_super, tail = _pattern(cfg)
    n_rec_scan = sum(1 for k in unit if k == "rec")
    w_attn = min(seq_len, cfg.attention_window or seq_len)
    w_lru = cfg.lru_width or cfg.d_model
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    ct = cfg.conv1d_width - 1
    dt = jnp.dtype(cfg.dtype)

    def sds(shape, roles, dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=shd.named(roles, shape))

    cache = {
        # recurrent states for the scanned super-blocks, one slot per rec
        # unit position: (n_rec_in_unit, n_super, B, ...)
        "lru_h": sds((n_rec_scan, n_super, batch, w_lru),
                     ("null", "null", "batch", "tensor"), jnp.float32),
        "conv": sds((n_rec_scan, n_super, batch, ct, w_lru),
                    ("null", "null", "batch", "null", "tensor")),
        "k": sds((n_super, batch, w_attn, kh, hd),
                 ("null", "batch", "null", "tensor", "null")),
        "v": sds((n_super, batch, w_attn, kh, hd),
                 ("null", "batch", "null", "tensor", "null")),
        "kpos": sds((w_attn,), ("null",), jnp.int32),
        "t": jax.ShapeDtypeStruct((), jnp.int32),
    }
    for j in range(len(tail)):
        cache[f"tail{j}_h"] = sds((batch, w_lru), ("batch", "tensor"),
                                  jnp.float32)
        cache[f"tail{j}_conv"] = sds((batch, ct, w_lru),
                                     ("batch", "null", "tensor"))
    return cache


def init_cache(cfg, shd, batch: int, seq_len: int):
    abs_cache = init_cache_abstract(cfg, shd, batch, seq_len)
    cache = {k: jnp.zeros(s.shape, s.dtype) for k, s in abs_cache.items()}
    cache["kpos"] = cache["kpos"] - 1
    return cache


def decode_step(cfg, params, cache, tokens, shd):
    t = cache["t"]
    w = cache["k"].shape[2]
    slot = jnp.mod(t, w)
    positions = t[None].astype(jnp.int32)
    kpos = cache["kpos"].at[slot].set(t)
    unit, n_super, tail = _pattern(cfg)

    x = embed_tokens(cfg, params, tokens, shd)

    def scan_fn(x, xs):
        p, lru_h, conv, k_i, v_i = xs
        ri = 0
        new_h, new_conv = [], []
        for j, kind in enumerate(unit):
            pj = p[f"u{j}"]
            if kind == "rec":
                x, (h_last, cstate) = _rec_unit(
                    cfg, pj, x, shd, h0=lru_h[ri], conv0=conv[ri], decode=True)
                new_h.append(h_last)
                new_conv.append(cstate)
                ri += 1
            else:
                h = L.norm(cfg, x, pj["ln1"]["scale"])
                q, k_new, v_new = attn_qkv(cfg, pj["attn"], h, shd, positions)
                k_i = jax.lax.dynamic_update_slice_in_dim(
                    k_i, k_new.astype(k_i.dtype), slot, 1)
                v_i = jax.lax.dynamic_update_slice_in_dim(
                    v_i, v_new.astype(v_i.dtype), slot, 1)
                out = attn_lib.attention(
                    q, k_i, v_i, q_positions=positions, k_positions=kpos,
                    causal=True, window=cfg.attention_window,
                    scale=cfg.attn_scale_override,
                    logit_cap=cfg.attn_logit_softcap)
                x = x + attn_out_proj(cfg, pj["attn"], out, shd)
                h = L.norm(cfg, x, pj["ln2"]["scale"])
                x = x + mlp_lib.mlp(cfg, pj["mlp"], h, shd)
        return x, (jnp.stack(new_h), jnp.stack(new_conv), k_i, v_i)

    x, (lru_h, conv, k, v) = jax.lax.scan(
        scan_fn, x,
        (params["blocks"], cache["lru_h"].transpose(1, 0, 2, 3),
         cache["conv"].transpose(1, 0, 2, 3, 4), cache["k"], cache["v"]))

    new_cache = dict(cache)
    new_cache["lru_h"] = lru_h.transpose(1, 0, 2, 3)
    new_cache["conv"] = conv.transpose(1, 0, 2, 3, 4)
    new_cache["k"] = k
    new_cache["v"] = v

    for j, kind in enumerate(tail):
        x, (h_last, cstate) = _rec_unit(
            cfg, params["tail"][f"u{j}"], x, shd,
            h0=cache[f"tail{j}_h"], conv0=cache[f"tail{j}_conv"], decode=True)
        new_cache[f"tail{j}_h"] = h_last
        new_cache[f"tail{j}_conv"] = cstate

    x = L.norm(cfg, x, params["final_norm"]["scale"])
    logits = unembed(cfg, params, x, shd)
    new_cache["kpos"] = kpos
    new_cache["t"] = t + 1
    return logits, new_cache


# --------------------------------------------------------------------------- #
def build(cfg) -> "model_zoo.Model":
    table = param_table(cfg)

    def fwd(params, batch, shd):
        return forward(cfg, params, batch["tokens"], shd)

    return model_zoo.Model(
        cfg=cfg,
        table=table,
        forward=fwd,
        decode_step=lambda params, cache, tokens, shd: decode_step(
            cfg, params, cache, tokens, shd),
        init_cache_abstract=lambda shd, b, s: init_cache_abstract(cfg, shd, b, s),
        init_cache=lambda shd, b, s: init_cache(cfg, shd, b, s),
        extra_inputs=lambda shape, shd: {},
    )
