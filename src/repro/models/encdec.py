"""Encoder-decoder backbone (whisper-medium).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, d_model).  Everything
behind the frontend is real: sinusoidal encoder positions, learned decoder
positions, pre-LN layernorm blocks with q/v/o biases, GELU MLPs, cross
attention, tied decoder embedding/unembedding, ring-buffer decode cache.

Param layout:
  enc/layers/*          (L_enc-stacked: ln1, attn, ln2, mlp)
  enc/final_norm/*
  dec/embed/table       (Vp, d)  (tied unembed)
  dec/pos/table         (max_positions, d)
  dec/layers/*          (L_dec-stacked: ln1, attn, lnx, xattn, ln2, mlp)
  dec/final_norm/*
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import mlp as mlp_lib
from repro.models import model_zoo
from repro.models.params import ParamTable
from repro.models.transformer import (
    _remat,
    attn_out_proj,
    cache_len,
    head_mask,
)


def _add_attn(t: ParamTable, cfg, prefix: str, nl: int, *, cross=False):
    d, kh, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    hp = cfg.num_heads_padded
    Ls, Lr = (nl,), ("null",)
    pad_q = None if hp == cfg.num_heads else (2, cfg.num_heads)
    pad_o = None if hp == cfg.num_heads else (1, cfg.num_heads)
    t.add(f"{prefix}/wq", Ls + (d, hp, hd), Lr + ("fsdp", "tensor", "null"),
          init="fan_in", zero_pad=pad_q)
    t.add(f"{prefix}/wk", Ls + (d, kh, hd), Lr + ("fsdp", "tensor", "null"),
          init="fan_in")
    t.add(f"{prefix}/wv", Ls + (d, kh, hd), Lr + ("fsdp", "tensor", "null"),
          init="fan_in")
    t.add(f"{prefix}/wo", Ls + (hp, hd, d), Lr + ("tensor", "null", "fsdp"),
          init="fan_in", zero_pad=pad_o)
    if cfg.attn_bias:
        t.add(f"{prefix}/bq", Ls + (hp, hd), Lr + ("tensor", "null"), init="zeros")
        t.add(f"{prefix}/bv", Ls + (kh, hd), Lr + ("tensor", "null"), init="zeros")
        t.add(f"{prefix}/bo", Ls + (d,), Lr + ("null",), init="zeros")


def _add_norm(t, cfg, path, nl=None):
    Ls = () if nl is None else (nl,)
    Lr = () if nl is None else ("null",)
    t.add(f"{path}/scale", Ls + (cfg.d_model,), Lr + ("null",), init="ones")
    t.add(f"{path}/bias", Ls + (cfg.d_model,), Lr + ("null",), init="zeros")


def param_table(cfg) -> ParamTable:
    t = ParamTable(cfg)
    d = cfg.d_model
    vp = cfg.vocab_padded
    le, ld = cfg.num_encoder_layers, cfg.num_layers

    # encoder
    _add_norm(t, cfg, "enc/layers/ln1", le)
    _add_attn(t, cfg, "enc/layers/attn", le)
    _add_norm(t, cfg, "enc/layers/ln2", le)
    mlp_lib.add_mlp_params(t, cfg, "enc/layers/mlp", le)
    _add_norm(t, cfg, "enc/final_norm")

    # decoder
    t.add("dec/embed/table", (vp, d), ("tensor", "fsdp"), init="normal")
    t.add("dec/pos/table", (cfg.max_positions, d), ("null", "fsdp"),
          init="normal")
    _add_norm(t, cfg, "dec/layers/ln1", ld)
    _add_attn(t, cfg, "dec/layers/attn", ld)
    _add_norm(t, cfg, "dec/layers/lnx", ld)
    _add_attn(t, cfg, "dec/layers/xattn", ld, cross=True)
    _add_norm(t, cfg, "dec/layers/ln2", ld)
    mlp_lib.add_mlp_params(t, cfg, "dec/layers/mlp", ld)
    _add_norm(t, cfg, "dec/final_norm")
    return t


# --------------------------------------------------------------------------- #
def _ln(cfg, x, p):
    return L.layernorm(x, p["scale"], p["bias"], cfg.norm_eps)


def _proj_qkv(cfg, p, xq, xkv, shd):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if cfg.attn_bias:
        q = q + p["bq"]
        v = v + p["bv"]
    return shd.act_bthd(q), shd.ws(k, "batch", None, "tensor", None), v


def _attn_out(cfg, p, out, shd):
    y = attn_out_proj(cfg, {"wo": p["wo"]}, out, shd)
    if cfg.attn_bias:
        y = y + p["bo"]
    return y


def _attend(cfg, q, k, v, q_pos, k_pos, causal, window=None):
    return attn_lib.attention(
        q, k, v, q_positions=q_pos, k_positions=k_pos, causal=causal,
        window=window, scale=cfg.attn_scale_override,
        logit_cap=cfg.attn_logit_softcap)


def sinusoid_positions(s: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1).astype(dtype)


def encode(cfg, params, frames, shd):
    """frames: (B, S_enc, d) stub embeddings -> encoder output (B,S_enc,d)."""
    b, s, d = frames.shape
    x = frames.astype(cfg.dtype) + sinusoid_positions(s, d, cfg.dtype)[None]
    x = shd.act_btd(x)
    pos = jnp.arange(s, dtype=jnp.int32)

    def layer(p, x):
        h = _ln(cfg, x, p["ln1"])
        q, k, v = _proj_qkv(cfg, p["attn"], h, h, shd)
        out = _attend(cfg, q, k, v, pos, pos, causal=False)
        x = x + _attn_out(cfg, p["attn"], shd.act_bthd(out), shd)
        h = _ln(cfg, x, p["ln2"])
        return (x + mlp_lib.mlp(cfg, p["mlp"], h, shd),)

    body = _remat(cfg, layer)
    if cfg.scan_layers:
        (x,), _ = jax.lax.scan(lambda c, p: (body(p, c[0]), None), (x,),
                               params["enc"]["layers"])
    else:
        for i in range(cfg.num_encoder_layers):
            p_i = jax.tree.map(lambda a: a[i], params["enc"]["layers"])
            (x,) = body(p_i, x)
    return _ln(cfg, x, params["enc"]["final_norm"])


def _dec_layer(cfg, p, x, shd, q_pos, enc_kv, enc_pos):
    h = _ln(cfg, x, p["ln1"])
    q, k, v = _proj_qkv(cfg, p["attn"], h, h, shd)
    out = _attend(cfg, q, k, v, q_pos, q_pos, causal=True)
    x = x + _attn_out(cfg, p["attn"], shd.act_bthd(out), shd)

    h = _ln(cfg, x, p["lnx"])
    qx = jnp.einsum("bsd,dhk->bshk", h, p["xattn"]["wq"])
    if cfg.attn_bias:
        qx = qx + p["xattn"]["bq"]
    ek, ev = enc_kv
    out = _attend(cfg, shd.act_bthd(qx), ek, ev, q_pos, enc_pos, causal=False)
    x = x + _attn_out(cfg, p["xattn"], shd.act_bthd(out), shd)

    h = _ln(cfg, x, p["ln2"])
    return x + mlp_lib.mlp(cfg, p["mlp"], h, shd), None


def forward(cfg, params, tokens, frames, shd):
    """Teacher-forced enc-dec forward -> (logits (B,S,Vp), aux=0)."""
    enc_out = encode(cfg, params, frames, shd)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    b, s = tokens.shape
    q_pos = jnp.arange(s, dtype=jnp.int32)
    x = L.embed_lookup(params["dec"]["embed"]["table"], tokens).astype(cfg.dtype)
    x = x + params["dec"]["pos"]["table"][:s][None].astype(cfg.dtype)
    x = shd.act_btd(x)

    def layer(p, x):
        # cross-attention K/V projected per layer from the encoder output
        ek = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
        ev = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
        if cfg.attn_bias:
            ev = ev + p["xattn"]["bv"]
        y, _ = _dec_layer(cfg, p, x, shd, q_pos, (ek, ev), enc_pos)
        return (y,)

    body = _remat(cfg, layer)
    if cfg.scan_layers:
        (x,), _ = jax.lax.scan(lambda c, p: (body(p, c[0]), None), (x,),
                               params["dec"]["layers"])
    else:
        for i in range(cfg.num_layers):
            p_i = jax.tree.map(lambda a: a[i], params["dec"]["layers"])
            (x,) = body(p_i, x)

    x = _ln(cfg, x, params["dec"]["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["dec"]["embed"]["table"])
    return shd.act_btv(logits), jnp.float32(0.0)


# --------------------------------------------------------------------------- #
# Decode: ring-buffer self cache + precomputed cross K/V
# --------------------------------------------------------------------------- #
def init_cache_abstract(cfg, shd, batch: int, seq_len: int):
    from repro.core import brick_attention as brick

    w = cache_len(cfg, seq_len)
    kh, hd, nl = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    se = cfg.encoder_seq_len
    dt = jnp.dtype(cfg.dtype)
    seq_role = "tensor" if brick.brick_active(cfg, shd, w) else "null"

    def sds(shape, roles, dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=shd.named(roles, shape))

    kv_roles = ("null", "batch", seq_role,
                "tensor" if seq_role == "null" else "null", "null")
    return {
        "k": sds((nl, batch, w, kh, hd), kv_roles),
        "v": sds((nl, batch, w, kh, hd), kv_roles),
        "xk": sds((nl, batch, se, kh, hd), ("null", "batch", "null", "tensor", "null")),
        "xv": sds((nl, batch, se, kh, hd), ("null", "batch", "null", "tensor", "null")),
        "kpos": sds((w,), ("null",), jnp.int32),
        "t": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg, shd, batch: int, seq_len: int):
    abs_cache = init_cache_abstract(cfg, shd, batch, seq_len)
    cache = {k: jnp.zeros(s.shape, s.dtype) for k, s in abs_cache.items()}
    cache["kpos"] = cache["kpos"] - 1
    return cache


def prefill_cross_cache(cfg, params, frames, shd, cache):
    """Run the encoder once and fill the cross-attention K/V."""
    enc_out = encode(cfg, params, frames, shd)

    def proj(p):
        ek = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
        ev = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
        if cfg.attn_bias:
            ev = ev + p["xattn"]["bv"]
        return ek.astype(cfg.dtype), ev.astype(cfg.dtype)

    ks, vs = [], []
    for i in range(cfg.num_layers):
        p_i = jax.tree.map(lambda a: a[i], params["dec"]["layers"])
        ek, ev = proj(p_i)
        ks.append(ek)
        vs.append(ev)
    cache = dict(cache)
    cache["xk"] = jnp.stack(ks)
    cache["xv"] = jnp.stack(vs)
    return cache


def decode_step(cfg, params, cache, tokens, shd):
    from repro.core import brick_attention as brick

    t = cache["t"]
    w = cache["k"].shape[2]
    use_brick = brick.brick_active(cfg, shd, w)
    slot = jnp.mod(t, w)
    kpos = cache["kpos"].at[slot].set(t)
    q_pos = t[None].astype(jnp.int32)
    enc_pos = jnp.arange(cfg.encoder_seq_len, dtype=jnp.int32)

    x = L.embed_lookup(params["dec"]["embed"]["table"], tokens).astype(cfg.dtype)
    pos_embed = jax.lax.dynamic_slice_in_dim(
        params["dec"]["pos"]["table"], jnp.clip(t, 0, cfg.max_positions - 1), 1, 0)
    x = x + pos_embed[None].astype(cfg.dtype)
    x = shd.act_btd(x)

    def scan_fn(x, xs):
        p, k_i, v_i, xk_i, xv_i = xs
        h = _ln(cfg, x, p["ln1"])
        q, k_new, v_new = _proj_qkv(cfg, p["attn"], h, h, shd)
        if use_brick:
            out, k_i, v_i = brick.decode_attention(
                cfg, shd, q, k_i, v_i, kpos, k_new, v_new, slot, t)
        else:
            k_i = jax.lax.dynamic_update_slice_in_dim(
                k_i, k_new.astype(k_i.dtype), slot, 1)
            v_i = jax.lax.dynamic_update_slice_in_dim(
                v_i, v_new.astype(v_i.dtype), slot, 1)
            out = _attend(cfg, q, k_i, v_i, q_pos, kpos, causal=True)
        x = x + _attn_out(cfg, p["attn"], out, shd)

        h = _ln(cfg, x, p["lnx"])
        qx = jnp.einsum("bsd,dhk->bshk", h, p["xattn"]["wq"])
        if cfg.attn_bias:
            qx = qx + p["xattn"]["bq"]
        out = _attend(cfg, qx, xk_i, xv_i, q_pos, enc_pos, causal=False)
        x = x + _attn_out(cfg, p["xattn"], out, shd)

        h = _ln(cfg, x, p["ln2"])
        x = x + mlp_lib.mlp(cfg, p["mlp"], h, shd)
        return x, (k_i, v_i)

    x, (k, v) = jax.lax.scan(
        scan_fn, x,
        (params["dec"]["layers"], cache["k"], cache["v"], cache["xk"],
         cache["xv"]))

    x = _ln(cfg, x, params["dec"]["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["dec"]["embed"]["table"])
    new_cache = dict(cache, k=k, v=v, kpos=kpos, t=t + 1)
    return shd.act_btv(logits), new_cache


# --------------------------------------------------------------------------- #
def build(cfg) -> "model_zoo.Model":
    table = param_table(cfg)

    def fwd(params, batch, shd):
        return forward(cfg, params, batch["tokens"], batch["frames"], shd)

    def dec(params, cache, tokens, shd):
        return decode_step(cfg, params, cache, tokens, shd)

    def extra(shape, shd):
        if shape.kind in ("train", "prefill"):
            sh = (shape.global_batch, cfg.encoder_seq_len, cfg.d_model)
            return {"frames": jax.ShapeDtypeStruct(
                sh, jnp.dtype(cfg.dtype),
                sharding=shd.named(("batch", None, None), sh))}
        return {}

    return model_zoo.Model(
        cfg=cfg,
        table=table,
        forward=fwd,
        decode_step=dec,
        init_cache_abstract=lambda shd, b, s: init_cache_abstract(cfg, shd, b, s),
        init_cache=lambda shd, b, s: init_cache(cfg, shd, b, s),
        extra_inputs=extra,
    )
