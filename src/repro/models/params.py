"""Parameter tables: a single source of truth for shapes, sharding roles and
initialization of every model parameter.

Each architecture family builds a ``ParamTable`` (path -> ParamDef).  From the
table we derive, guaranteed-consistent:

- ``init(key)``          -> real parameter pytree (smoke tests, examples)
- ``abstract()``         -> ShapeDtypeStruct pytree (dry-run lowering)
- ``specs(sharder)``     -> PartitionSpec pytree (pjit in/out shardings)

Paths are "/"-separated; the pytree is a nested dict split on "/".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    roles: Tuple[Optional[str], ...]  # sharding roles, one per dim
    init: str = "normal"  # normal | zeros | ones | fan_in | lru_a
    scale: float = 0.02
    dtype: Optional[str] = None  # override cfg.param_dtype
    zero_pad: Optional[Tuple[int, int]] = None  # (axis, real_size): slots
    #   beyond real_size on axis are zero-initialized (exact head padding)

    def __post_init__(self):
        assert len(self.shape) == len(self.roles), (self.shape, self.roles)


class ParamTable:
    def __init__(self, cfg):
        self.cfg = cfg
        self.defs: Dict[str, ParamDef] = {}

    def add(self, path: str, shape, roles, init="normal", scale=0.02,
            dtype=None, zero_pad=None):
        assert path not in self.defs, f"duplicate param {path}"
        self.defs[path] = ParamDef(tuple(shape), tuple(roles), init, scale,
                                   dtype, zero_pad)

    # ------------------------------------------------------------------ #
    def _nested(self, leaf_fn: Callable[[str, ParamDef], object]) -> dict:
        tree: dict = {}
        for path, d in self.defs.items():
            node = tree
            parts = path.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = leaf_fn(path, d)
        return tree

    def _dtype(self, d: ParamDef):
        return jnp.dtype(d.dtype or self.cfg.param_dtype)

    def init(self, key: jax.Array) -> dict:
        paths = sorted(self.defs)
        keys = dict(zip(paths, jax.random.split(key, max(2, len(paths)))))

        def leaf(path, d: ParamDef):
            dt = self._dtype(d)
            if d.init == "zeros":
                return jnp.zeros(d.shape, dt)
            if d.init == "ones":
                return jnp.ones(d.shape, dt)
            if d.init == "lru_a":
                # RG-LRU recurrence gate param: softplus^-1 spacing so that
                # a = sigmoid(param)^(c*gate) starts in a stable regime.
                u = jax.random.uniform(keys[path], d.shape, jnp.float32, 0.9, 0.999)
                val = jnp.log(jnp.exp(-jnp.log(u) * 8.0) - 1.0)  # softplus inverse
                return val.astype(dt)
            scale = d.scale
            if d.init == "fan_in":
                scale = 1.0 / math.sqrt(max(1, d.shape[-2] if len(d.shape) > 1 else d.shape[0]))
            val = jax.random.normal(keys[path], d.shape, jnp.float32) * scale
            if d.zero_pad is not None:
                axis, real = d.zero_pad
                idx = jax.lax.broadcasted_iota(jnp.int32, d.shape, axis)
                val = jnp.where(idx < real, val, 0.0)
            return val.astype(dt)

        return self._nested(leaf)

    def abstract(self) -> dict:
        return self._nested(
            lambda path, d: jax.ShapeDtypeStruct(d.shape, self._dtype(d))
        )

    def specs(self, sharder) -> dict:
        return self._nested(lambda path, d: sharder.spec(d.roles, d.shape))

    def shardings(self, sharder) -> dict:
        return self._nested(
            lambda path, d: NamedSharding(sharder.mesh, sharder.spec(d.roles, d.shape))
        )

    def abstract_sharded(self, sharder) -> dict:
        """ShapeDtypeStructs carrying shardings — dry-run lowering inputs."""
        return self._nested(
            lambda path, d: jax.ShapeDtypeStruct(
                d.shape,
                self._dtype(d),
                sharding=NamedSharding(sharder.mesh, sharder.spec(d.roles, d.shape)),
            )
        )

    def num_params(self) -> int:
        return sum(int(np_prod(d.shape)) for d in self.defs.values())

    def bytes(self) -> int:
        return sum(
            int(np_prod(d.shape)) * self._dtype(d).itemsize for d in self.defs.values()
        )


def np_prod(shape: Sequence[int]) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out
