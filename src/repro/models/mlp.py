"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain GELU MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp(cfg, p: dict, x: jax.Array, shd) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  p holds w_in/(w_gate)/w_out."""
    if cfg.mlp_style in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, p["w_in"])
        gate = shd.act_btf(gate)
        up = shd.act_btf(up)
        act = jax.nn.silu if cfg.mlp_style == "swiglu" else _gelu
        h = act(gate) * up
    elif cfg.mlp_style == "gelu":
        h = _gelu(jnp.einsum("bsd,df->bsf", x, p["w_in"]) + p["b_in"])
        h = shd.act_btf(h)
    else:
        raise ValueError(cfg.mlp_style)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    if "b_out" in p:
        out = out + p["b_out"]
    return shd.act_btd(out)


def _gelu(x):
    return jax.nn.gelu(x, approximate=True)


def add_mlp_params(table, cfg, prefix: str, layers: int | None = None):
    """Register MLP params; ``layers`` adds a leading scan-stack dim."""
    L = () if layers is None else (layers,)
    Lr = () if layers is None else ("null",)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_style in ("swiglu", "geglu"):
        table.add(f"{prefix}/w_gate", L + (d, f), Lr + ("fsdp", "tensor"), init="fan_in")
        table.add(f"{prefix}/w_in", L + (d, f), Lr + ("fsdp", "tensor"), init="fan_in")
        table.add(f"{prefix}/w_out", L + (f, d), Lr + ("tensor", "fsdp"), init="fan_in")
    elif cfg.mlp_style == "gelu":
        table.add(f"{prefix}/w_in", L + (d, f), Lr + ("fsdp", "tensor"), init="fan_in")
        table.add(f"{prefix}/b_in", L + (f,), Lr + ("tensor",), init="zeros")
        table.add(f"{prefix}/w_out", L + (f, d), Lr + ("tensor", "fsdp"), init="fan_in")
        table.add(f"{prefix}/b_out", L + (d,), Lr + ("null",), init="zeros")
    else:
        raise ValueError(cfg.mlp_style)
