"""Shared neural-net layers (pure JAX, no framework deps).

Numerics: parameters/activations in cfg.dtype (bf16 target), all norm and
softmax statistics accumulated in f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(
    x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], eps: float
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(cfg, x: jax.Array, scale: jax.Array, bias: Optional[jax.Array] = None):
    if cfg.norm_style == "layernorm":
        return layernorm(x, scale, bias, cfg.norm_eps)
    return rmsnorm(x, scale, cfg.norm_eps)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """(V, d) table, integer tokens -> (..., d). one_hot-free gather."""
    return jnp.take(table, tokens, axis=0)


# --------------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float, rotary_dim: Optional[int] = None):
    rd = rotary_dim or head_dim
    exponent = jnp.arange(0, rd, 2, dtype=jnp.float32) / rd
    return 1.0 / (theta ** exponent)  # (rd/2,)


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    *,
    theta: float,
    style: str = "neox",
) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,) int32.

    style:
      neox  – rotate-half over the full head dim (llama/qwen/starcoder2)
      half  – rotary applied to the first half of the head dim only,
              interleaved pairs (chatglm "2d"/partial rotary)
      none  – identity
    """
    if style == "none":
        return x
    b, s, h, d = x.shape
    if positions.ndim == 1:
        positions = positions[None, :]
    pos = positions.astype(jnp.float32)[:, :, None, None]  # (B,S,1,1)

    if style == "neox":
        freqs = rope_frequencies(d, theta)  # (d/2,)
        angles = pos * freqs  # (B,S,1,d/2)
        sin, cos = jnp.sin(angles), jnp.cos(angles)
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
        return out.astype(x.dtype)

    if style == "half":
        rd = d // 2
        freqs = rope_frequencies(d, theta, rotary_dim=rd)  # (rd/2,)
        angles = pos * freqs  # (B,S,1,rd/2)
        sin, cos = jnp.sin(angles), jnp.cos(angles)
        xr = x[..., :rd].astype(jnp.float32)
        xp = x[..., rd:]
        x_even = xr[..., 0::2]
        x_odd = xr[..., 1::2]
        rot_even = x_even * cos - x_odd * sin
        rot_odd = x_odd * cos + x_even * sin
        xr_out = jnp.stack([rot_even, rot_odd], axis=-1).reshape(xr.shape)
        return jnp.concatenate([xr_out.astype(x.dtype), xp], axis=-1)

    raise ValueError(f"unknown rope style {style!r}")


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
