"""Decoder-only transformer LM (dense / MoE / VLM backbone).

Layers are stacked on a leading axis and iterated with ``lax.scan`` so the
compiled HLO holds ONE layer body regardless of depth — this keeps the
40-cell x 512-device dry-run compile tractable and is also the deployment
configuration (scan + remat).  ``cfg.scan_layers=False`` unrolls instead
(a perf-pass knob).

Param paths (all stacked with leading L when scanned):
  embed/table (Vp, d)            out/head (d, Vp)          final_norm/scale
  layers/ln1/scale               layers/ln2/scale
  layers/attn/{wq,wk,wv,wo}      layers/attn/{q_norm,k_norm}  (qk_norm)
  layers/mlp/...  or  layers/moe/...
  vlm/patch_proj (d_patch_in, d) (pixtral stub frontend)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models.params import ParamTable


# --------------------------------------------------------------------------- #
# Parameter table
# --------------------------------------------------------------------------- #
def param_table(cfg) -> ParamTable:
    t = ParamTable(cfg)
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    vp = cfg.vocab_padded
    nl = cfg.num_layers

    t.add("embed/table", (vp, d), ("tensor", "fsdp"), init="normal")
    if not cfg.tie_embeddings:
        t.add("out/head", (d, vp), ("fsdp", "tensor"), init="fan_in")
    ln_init = "ones" if cfg.norm_style == "layernorm" else "zeros"
    t.add("final_norm/scale", (d,), ("null",), init=ln_init)
    if cfg.norm_style == "layernorm":
        t.add("final_norm/bias", (d,), ("null",), init="zeros")

    add_attn_layer_params(t, cfg, "layers", nl)
    if cfg.num_experts:
        moe_lib.add_moe_params(t, cfg, "layers/moe", nl)
    else:
        mlp_lib.add_mlp_params(t, cfg, "layers/mlp", nl)

    if cfg.num_patches:
        # pixtral stub frontend: project precomputed patch embeddings
        t.add("vlm/patch_proj", (d, d), ("fsdp", "null"), init="fan_in")
    return t


def add_attn_layer_params(t: ParamTable, cfg, prefix: str, nl: Optional[int]):
    d, kh, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    hp = cfg.num_heads_padded  # zero-masked padding for even 16-way TP
    Ls = () if nl is None else (nl,)
    Lr = () if nl is None else ("null",)
    nL = len(Ls)
    ln_init = "ones" if cfg.norm_style == "layernorm" else "zeros"
    t.add(f"{prefix}/ln1/scale", Ls + (d,), Lr + ("null",), init=ln_init)
    t.add(f"{prefix}/ln2/scale", Ls + (d,), Lr + ("null",), init=ln_init)
    if cfg.norm_style == "layernorm":
        t.add(f"{prefix}/ln1/bias", Ls + (d,), Lr + ("null",), init="zeros")
        t.add(f"{prefix}/ln2/bias", Ls + (d,), Lr + ("null",), init="zeros")
    if cfg.post_attn_norm:
        t.add(f"{prefix}/ln1_post/scale", Ls + (d,), Lr + ("null",), init="zeros")
        t.add(f"{prefix}/ln2_post/scale", Ls + (d,), Lr + ("null",), init="zeros")
    pad = (None if hp == cfg.num_heads else (nL + 1, cfg.num_heads))
    t.add(f"{prefix}/attn/wq", Ls + (d, hp, hd), Lr + ("fsdp", "tensor", "null"),
          init="fan_in", zero_pad=pad)
    t.add(f"{prefix}/attn/wk", Ls + (d, kh, hd), Lr + ("fsdp", "tensor", "null"),
          init="fan_in")
    t.add(f"{prefix}/attn/wv", Ls + (d, kh, hd), Lr + ("fsdp", "tensor", "null"),
          init="fan_in")
    pad_o = (None if hp == cfg.num_heads else (nL, cfg.num_heads))
    t.add(f"{prefix}/attn/wo", Ls + (hp, hd, d), Lr + ("tensor", "null", "fsdp"),
          init="fan_in", zero_pad=pad_o)
    if cfg.attn_bias:
        t.add(f"{prefix}/attn/bq", Ls + (hp, hd), Lr + ("tensor", "null"),
              init="zeros")
        t.add(f"{prefix}/attn/bk", Ls + (kh, hd), Lr + ("tensor", "null"),
              init="zeros")
        t.add(f"{prefix}/attn/bv", Ls + (kh, hd), Lr + ("tensor", "null"),
              init="zeros")
        t.add(f"{prefix}/attn/bo", Ls + (d,), Lr + ("null",), init="zeros")
    if cfg.qk_norm:
        t.add(f"{prefix}/attn/q_norm", Ls + (hd,), Lr + ("null",), init="zeros")
        t.add(f"{prefix}/attn/k_norm", Ls + (hd,), Lr + ("null",), init="zeros")


# --------------------------------------------------------------------------- #
# Attention sub-block (shared with encdec/hybrid)
# --------------------------------------------------------------------------- #
def head_mask(cfg, dtype):
    """(Hp,) mask zeroing padded heads so padding is mathematically exact
    (keeps dwo for padded rows at zero — see DESIGN.md)."""
    hp = cfg.num_heads_padded
    if hp == cfg.num_heads:
        return None
    return (jnp.arange(hp) < cfg.num_heads).astype(dtype)


def attn_qkv(cfg, p, x, shd, positions):
    """Project + rope. x:(B,S,d) -> q:(B,S,Hp,hd), k/v:(B,S,K,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias and "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q, k = shd.act_bthd(q), shd.ws(k, "batch", None, "tensor", None)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, theta=cfg.rope_theta, style=cfg.rope_style)
    k = L.apply_rope(k, positions, theta=cfg.rope_theta, style=cfg.rope_style)
    return q, k, v


def attn_out_proj(cfg, p, out, shd):
    """Mask padded heads, project back to d_model."""
    hm = head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm[None, None, :, None]
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.attn_bias and "bo" in p:
        y = y + p["bo"]
    return shd.act_btd(y)


def self_attention(cfg, p, x, shd, positions, *, causal=True,
                   window=None, kv_override=None, k_positions=None):
    """Full self-attention sub-block (no residual). Returns (B,S,d)."""
    q, k, v = attn_qkv(cfg, p, x, shd, positions)
    if kv_override is not None:
        k, v = kv_override
    kp = k_positions if k_positions is not None else positions
    out = attn_lib.attention(
        q, k, v,
        q_positions=positions, k_positions=kp,
        causal=causal, window=window,
        scale=cfg.attn_scale_override, logit_cap=cfg.attn_logit_softcap,
    )
    out = shd.act_bthd(out)
    return attn_out_proj(cfg, p, out, shd)


# --------------------------------------------------------------------------- #
# Layer body + forward
# --------------------------------------------------------------------------- #
def _layer(cfg, p, x, shd, positions):
    """One pre-norm transformer layer. Returns (x, aux_loss)."""
    h = L.norm(cfg, x, p["ln1"]["scale"], p["ln1"].get("bias"))
    a = self_attention(cfg, p["attn"], h, shd, positions,
                       window=cfg.sliding_window)
    if cfg.post_attn_norm:
        a = L.norm(cfg, a, p["ln1_post"]["scale"])
    x = x + a
    h = L.norm(cfg, x, p["ln2"]["scale"], p["ln2"].get("bias"))
    if cfg.num_experts:
        m, aux = moe_lib.moe_block(cfg, p["moe"], h, shd)
    else:
        m, aux = mlp_lib.mlp(cfg, p["mlp"], h, shd), jnp.float32(0.0)
    if cfg.post_attn_norm:
        m = L.norm(cfg, m, p["ln2_post"]["scale"])
    return x + m, aux


def _remat(cfg, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full


def run_layers(cfg, layer_params, x, shd, positions, layer_fn=None):
    """Scan (or unroll) the stacked layer parameters over x.

    With cfg.remat_segments = G > 0 the scan is two-level (sqrt remat):
    an outer scan over G checkpointed segments of K = L/G layers each.
    The backward pass then saves G segment inputs instead of L layer
    inputs — for grok-1 this is the difference between a 6.4 GB and a
    0.8 GB residual stack per device (see EXPERIMENTS.md section Perf)."""
    fn = layer_fn or _layer
    body = _remat(cfg, functools.partial(fn, cfg, shd=shd, positions=positions))

    def scan_fn(carry, p_i):
        x, aux = carry
        y, aux_i = body(p_i, x)
        return (y, aux + aux_i), None

    if cfg.scan_layers and cfg.remat_segments > 1:
        g = cfg.remat_segments
        n = jax.tree.leaves(layer_params)[0].shape[0]
        assert n % g == 0, (n, g)
        k = n // g
        seg_params = jax.tree.map(
            lambda a: a.reshape((g, k) + a.shape[1:]), layer_params)

        @jax.checkpoint
        def segment(carry, p_seg):
            return jax.lax.scan(scan_fn, carry, p_seg)[0], None

        (x, aux), _ = jax.lax.scan(segment, (x, jnp.float32(0.0)), seg_params)
        return x, aux

    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.float32(0.0)), layer_params)
        return x, aux

    aux = jnp.float32(0.0)
    for i in range(cfg.num_layers):
        p_i = jax.tree.map(lambda a: a[i], layer_params)
        x, aux_i = body(p_i, x)
        aux = aux + aux_i
    return x, aux


def embed_tokens(cfg, params, tokens, shd, patch_embeds=None):
    x = L.embed_lookup(params["embed"]["table"], tokens)
    x = x.astype(jnp.dtype(cfg.dtype)) * jnp.asarray(cfg.embed_scale, cfg.dtype)
    if cfg.num_patches and patch_embeds is not None:
        # pixtral stub: precomputed patch embeddings projected and prepended
        pe = jnp.einsum("bpd,de->bpe", patch_embeds.astype(cfg.dtype),
                        params["vlm"]["patch_proj"])
        x = jnp.concatenate([pe, x[:, cfg.num_patches:, :]], axis=1)
    return shd.act_btd(x)


def unembed(cfg, params, x, shd):
    table = (params["embed"]["table"].T if cfg.tie_embeddings
             else params["out"]["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, table)
    return shd.act_btv(logits)


def forward(cfg, params, tokens, shd, patch_embeds=None):
    """tokens: (B, S) -> logits (B, S, Vp) [+ aux moe loss]."""
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = embed_tokens(cfg, params, tokens, shd, patch_embeds)
    x, aux = run_layers(cfg, params["layers"], x, shd, positions)
    x = L.norm(cfg, x, params["final_norm"]["scale"],
               params["final_norm"].get("bias"))
    return unembed(cfg, params, x, shd), aux


# --------------------------------------------------------------------------- #
# Decode (one token, KV cache)
# --------------------------------------------------------------------------- #
def cache_len(cfg, seq_len: int) -> int:
    w = cfg.sliding_window or cfg.attention_window
    return min(seq_len, w) if w else seq_len


def init_cache_abstract(cfg, shd, batch: int, seq_len: int):
    """ShapeDtypeStruct cache for dry-run lowering (with shardings).

    Large unwindowed caches use the grid-brick layout: sequence dim sharded
    over the model axis (see core/brick_attention.py)."""
    from repro.core import brick_attention as brick

    w = cache_len(cfg, seq_len)
    kh, hd, nl = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    seq_role = "tensor" if brick.brick_active(cfg, shd, w) else "null"

    def sds(shape, roles, dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=shd.named(roles, shape))

    kv_roles = ("null", "batch", seq_role, "tensor" if seq_role == "null" else "null", "null")
    return {
        "k": sds((nl, batch, w, kh, hd), kv_roles),
        "v": sds((nl, batch, w, kh, hd), kv_roles),
        "kpos": sds((w,), ("null",), jnp.int32),
        "t": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg, shd, batch: int, seq_len: int):
    abs_cache = init_cache_abstract(cfg, shd, batch, seq_len)
    cache = {
        k: jnp.zeros(s.shape, s.dtype) for k, s in abs_cache.items()
    }
    cache["kpos"] = cache["kpos"] - 1  # -1 marks empty slots
    return cache


def _decode_layer(cfg, p, x, shd, positions, k_i, v_i, kpos, slot, t,
                  use_brick):
    """Decode step for one layer: update cache slice, attend. x:(B,1,d)."""
    from repro.core import brick_attention as brick

    h = L.norm(cfg, x, p["ln1"]["scale"], p["ln1"].get("bias"))
    q, k_new, v_new = attn_qkv(cfg, p["attn"], h, shd, positions)

    if use_brick:
        out, k_i, v_i = brick.decode_attention(
            cfg, shd, q, k_i, v_i, kpos, k_new, v_new, slot, t)
    else:
        k_i = jax.lax.dynamic_update_slice_in_dim(
            k_i, k_new.astype(k_i.dtype), slot, 1)
        v_i = jax.lax.dynamic_update_slice_in_dim(
            v_i, v_new.astype(v_i.dtype), slot, 1)
        window = cfg.sliding_window or cfg.attention_window
        out = attn_lib.attention(
            q, k_i, v_i,
            q_positions=positions, k_positions=kpos,
            causal=True, window=window,
            scale=cfg.attn_scale_override, logit_cap=cfg.attn_logit_softcap,
        )
    a = attn_out_proj(cfg, p["attn"], out, shd)
    if cfg.post_attn_norm:
        a = L.norm(cfg, a, p["ln1_post"]["scale"])
    x = x + a
    h = L.norm(cfg, x, p["ln2"]["scale"], p["ln2"].get("bias"))
    if cfg.num_experts:
        m, _ = moe_lib.moe_block(cfg, p["moe"], h, shd)
    else:
        m = mlp_lib.mlp(cfg, p["mlp"], h, shd)
    if cfg.post_attn_norm:
        m = L.norm(cfg, m, p["ln2_post"]["scale"])
    return x + m, k_i, v_i


def decode_step(cfg, params, cache, tokens, shd):
    """tokens: (B, 1) -> (logits (B,1,Vp), new cache)."""
    from repro.core import brick_attention as brick

    t = cache["t"]
    w = cache["k"].shape[2]
    use_brick = brick.brick_active(cfg, shd, w)
    slot = jnp.mod(t, w)
    positions = t[None].astype(jnp.int32)  # (1,)
    kpos = cache["kpos"].at[slot].set(t)

    x = embed_tokens(cfg, params, tokens, shd)

    def scan_fn(x, xs):
        p_i, k_i, v_i = xs
        x, k_i, v_i = _decode_layer(cfg, p_i, x, shd, positions, k_i, v_i,
                                    kpos, slot, t, use_brick)
        return x, (k_i, v_i)

    if cfg.scan_layers:
        x, (k, v) = jax.lax.scan(
            scan_fn, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.num_layers):
            p_i = jax.tree.map(lambda a: a[i], params["layers"])
            x, (k_i, v_i) = scan_fn(x, (p_i, cache["k"][i], cache["v"][i]))
            ks.append(k_i)
            vs.append(v_i)
        k, v = jnp.stack(ks), jnp.stack(vs)

    x = L.norm(cfg, x, params["final_norm"]["scale"],
               params["final_norm"].get("bias"))
    logits = unembed(cfg, params, x, shd)
    new_cache = {"k": k, "v": v, "kpos": kpos, "t": t + 1}
    return logits, new_cache
