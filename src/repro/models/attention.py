"""Grouped-query attention with chunked online-softmax (flash-style) in pure
JAX.  The Pallas TPU kernel in ``repro.kernels.flash_attention`` implements
the same contraction with explicit VMEM tiling; this module is the lowering
path used by the dry-run (CPU container) and the oracle the kernel is tested
against.

Formulation: **repeat-KV**.  KV heads are broadcast up to the (padded) query
head count before the contraction, so the head axis shards cleanly over
16-way TP for every assigned GQA ratio (64/8, 40/8, 24/2, 48/8, ...) — the
grouped 5-D formulation cannot be partitioned when kv_heads < TP degree.

Memory note: naive (S x S) scores at prefill_32k would need ~17 GB/device;
the kv-chunked online softmax keeps the transient at (S_q x C) per head,
which is what lets ``compiled.memory_analysis()`` fit in 16 GB v5e HBM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import softcap as apply_softcap

NEG_INF = -1e30


def repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, K, D) -> (B, S, H, D) by repeating each kv head H//K times."""
    b, s, kh, d = k.shape
    if kh == num_heads:
        return k
    reps = num_heads // kh
    return jnp.repeat(k, reps, axis=2)


def _mask_bias(
    q_pos: jax.Array,  # (Sq,) absolute positions of queries
    k_pos: jax.Array,  # (C,) absolute positions of keys (-1 = empty slot)
    *,
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    """(Sq, C) additive bias: 0 where attending is allowed, NEG_INF elsewhere."""
    valid = (k_pos >= 0)[None, :]
    if causal:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def attention(
    q: jax.Array,  # (B, Sq, H, D)  (H = padded head count)
    k: jax.Array,  # (B, Sk, K, D)
    v: jax.Array,  # (B, Sk, K, D)
    *,
    q_positions: jax.Array,  # (Sq,) int32 absolute positions
    k_positions: jax.Array,  # (Sk,) int32 absolute positions, -1 for empty
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    logit_cap: Optional[float] = None,
    chunk_size: int = 1024,
) -> jax.Array:
    """GQA with online softmax over KV chunks. Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    scale = scale if scale is not None else d ** -0.5

    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    # scale in f32 for range, then back to the compute dtype: dots run in
    # the input dtype with f32 accumulation (preferred_element_type) — on
    # TPU this is the native MXU mode; an explicit f32 cast of K/V would
    # materialize 2x-sized copies of the whole cache/sequence in HBM.
    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)

    if sk <= chunk_size:
        return _attn_block(qf, k, v, q_positions, k_positions, causal, window,
                           logit_cap).astype(q.dtype)

    # pad KV to a multiple of the chunk (padded slots get k_pos = -1)
    n_chunks = -(-sk // chunk_size)
    pad = n_chunks * chunk_size - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad), constant_values=-1)

    kc = k.reshape(b, n_chunks, chunk_size, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk_size, h, d).transpose(1, 0, 2, 3, 4)
    pc = k_positions.reshape(n_chunks, chunk_size)

    def step(carry, xs):
        m, l, acc = carry  # (B,Sq,H), (B,Sq,H), (B,Sq,H,D)
        k_i, v_i, pos_i = xs
        s = jnp.einsum("bqhd,bchd->bqhc", qf, k_i,
                       preferred_element_type=jnp.float32)
        s = apply_softcap(s, logit_cap)
        bias = _mask_bias(q_positions, pos_i, causal=causal, window=window)
        s = s + bias[:, None, :][None]  # (B,Sq,H,C)
        # clamp the running max so fully-masked chunks give exp(-huge) ~ 0,
        # not exp(0) = 1 (the classic online-softmax masking bug)
        m_new = jnp.maximum(jnp.maximum(m, jnp.max(s, axis=-1)), 0.1 * NEG_INF)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    a0 = jnp.zeros((b, sq, h, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def _attn_block(qf, k, v, q_positions, k_positions, causal, window, logit_cap):
    """Single-block attention (Sk small): one stable softmax, f32 accum."""
    s = jnp.einsum("bqhd,bchd->bqhc", qf, k,
                   preferred_element_type=jnp.float32)
    s = apply_softcap(s, logit_cap)
    bias = _mask_bias(q_positions, k_positions, causal=causal, window=window)
    s = s + bias[:, None, :][None]
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), 0.1 * NEG_INF)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = (p / jnp.maximum(l, 1e-30)).astype(v.dtype)
    return jnp.einsum("bqhc,bchd->bqhd", p, v,
                      preferred_element_type=jnp.float32)
