"""Result merging — "the final result is merged from the various results
coming from the different Grid nodes" (paper section 4.2).

A query result is a small, associative-mergeable summary: selected-event
count, sum/histogram of a physics variable, and a bounded set of selected
event ids.  Associativity is what lets the merge run as a tree: per-brick
-> per-node -> per-pod -> JSE, and as plain psums in the SPMD realization.

Two merge schedules share the same ``merge2`` kernel:

- :func:`tree_merge` — the batch JSE schedule: all partials collected,
  pairwise reduction at job end (what the paper's "retrieves the results,
  merging them together" does).
- :class:`MergeAccumulator` — the *streaming* schedule: partials are folded
  in as they arrive, and :meth:`~MergeAccumulator.snapshot` at any moment
  is **bit-identical** to ``tree_merge`` of the partials seen so far.  The
  accumulator is what lets the service ship progressive histograms while
  the grid job is still running without giving up the batch path's exact
  result (see ``docs/streaming.md`` for the equivalence argument).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

HIST_BINS = 64
HIST_RANGE = (0.0, 512.0)
MAX_IDS = 128


@dataclasses.dataclass
class QueryResult:
    """One (partial or merged) query summary.

    The paper's per-node "result file": selected/processed event counts, the
    sum and a fixed-range histogram of the summary variable (``e_total``),
    and a bounded sample of selected event ids.  Every field merges
    associatively (``merge2``), which is what makes the JSE merge schedule
    — tree, streaming prefix, or SPMD psum — a free choice."""
    n_selected: int = 0
    n_processed: int = 0
    sum_var: float = 0.0
    hist: Optional[np.ndarray] = None          # (HIST_BINS,) counts
    selected_ids: Optional[np.ndarray] = None  # bounded id sample

    def __post_init__(self):
        if self.hist is None:
            self.hist = np.zeros(HIST_BINS, np.int64)
        if self.selected_ids is None:
            self.selected_ids = np.zeros(0, np.int64)

    def to_dict(self) -> dict:
        """JSON-serializable form of the result (``hist`` and
        ``selected_ids`` as plain int lists) — what the fleet's L2 cache
        tier persists across restarts.  Round-trips exactly through
        :meth:`from_dict` (``results_identical`` holds): counts and
        histogram bins are integers, ids are integers, and the float
        ``sum_var`` survives JSON bit-for-bit (repr round-trip)."""
        return {
            "n_selected": int(self.n_selected),
            "n_processed": int(self.n_processed),
            "sum_var": float(self.sum_var),
            "hist": [int(x) for x in self.hist],
            "selected_ids": [int(x) for x in self.selected_ids],
        }

    @classmethod
    def from_dict(cls, data: dict) -> "QueryResult":
        """Rebuild a result from :meth:`to_dict` output."""
        return cls(
            n_selected=int(data["n_selected"]),
            n_processed=int(data["n_processed"]),
            sum_var=float(data["sum_var"]),
            hist=np.asarray(data["hist"], np.int64),
            selected_ids=np.asarray(data["selected_ids"], np.int64),
        )


def from_mask(mask: np.ndarray, var: np.ndarray,
              event_id: np.ndarray) -> QueryResult:
    """Summarize one evaluated packet: selection mask -> QueryResult.

    This is the leaf of every merge tree — a grid node calls it on each
    packet's predicate output before shipping the partial to the JSE."""
    sel = mask != 0
    vals = var[sel]
    hist, _ = np.histogram(vals, bins=HIST_BINS, range=HIST_RANGE)
    ids = event_id[sel][:MAX_IDS]
    return QueryResult(
        n_selected=int(sel.sum()), n_processed=int(mask.shape[0]),
        sum_var=float(vals.sum()), hist=hist.astype(np.int64),
        selected_ids=ids.astype(np.int64))


def merge2(a: QueryResult, b: QueryResult) -> QueryResult:
    """Merge two partials (``a`` earlier than ``b`` in packet order).

    Counts and histograms add exactly; ``selected_ids`` concatenates in
    order and keeps the first ``MAX_IDS`` — a prefix-stable truncation, so
    any merge schedule that preserves packet order keeps the same sample."""
    return QueryResult(
        n_selected=a.n_selected + b.n_selected,
        n_processed=a.n_processed + b.n_processed,
        sum_var=a.sum_var + b.sum_var,
        hist=a.hist + b.hist,
        selected_ids=np.concatenate([a.selected_ids, b.selected_ids])[:MAX_IDS],
    )


def results_identical(a: QueryResult, b: QueryResult) -> bool:
    """Field-by-field *bit* equality of two results — the predicate behind
    every merge-schedule-equivalence guarantee (shared scans, fragment
    plans, streamed prefixes).  Float ``sum_var`` is compared exactly, not
    approximately: equivalent schedules reproduce the same merge DAG, so
    they must agree to the last bit."""
    return (a.n_selected == b.n_selected
            and a.n_processed == b.n_processed
            and a.sum_var == b.sum_var
            and np.array_equal(a.hist, b.hist)
            and np.array_equal(a.selected_ids, b.selected_ids))


def merge_batch(parts: Sequence[Sequence[QueryResult]]) -> List[QueryResult]:
    """Batched JSE merge for a shared scan: ``parts[i][k]`` is packet *i*'s
    partial for query *k*.  Each query's partials arrive in the same packet
    order, so merging column *k* with ``tree_merge`` is bit-identical to
    the merge an independent single-query job would have produced."""
    if not parts:
        return []
    k = len(parts[0])
    if any(len(p) != k for p in parts):
        raise ValueError("ragged batch partials")
    return [tree_merge([p[q] for p in parts]) for q in range(k)]


def tree_merge(results: Sequence[QueryResult],
               merge_fn: Callable = merge2) -> QueryResult:
    """Pairwise tree reduction (the JSE merge schedule).

    Level-by-level: adjacent pairs merge, an odd leftover is carried to the
    next level at the end.  The resulting reduction tree groups the leaves
    by the greedy binary decomposition of ``len(results)`` — the same tree
    :class:`MergeAccumulator` maintains incrementally, which is why a
    streamed prefix snapshot finalizes to this function's output bit for
    bit (``tests/test_streaming.py`` pins the property).

    ``merge_fn`` generalizes the reduction to any associative pairwise
    combiner over any element type (the observability plane reduces
    fleet metrics snapshots through this exact schedule); it defaults to
    :func:`merge2` over :class:`QueryResult`.  An empty input returns an
    empty ``QueryResult`` — only meaningful under the default combiner,
    so callers with a custom ``merge_fn`` must pass a non-empty
    sequence."""
    if not results:
        return QueryResult()
    level: List[QueryResult] = list(results)
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            nxt.append(merge_fn(level[i], level[i + 1]))
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


# --------------------------------------------------------------------------- #
# Streaming prefix merge
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Coverage:
    """How much of the job a streamed snapshot has seen — the confidence
    metadata shipped next to every progressive result.

    A streamed snapshot is not an *estimate* of the final answer: it is the
    **exact** answer over the ``events_scanned`` events merged so far.
    Coverage tells the tenant how far along the scan is and whether the
    prefix is currently running behind due to failures:

    - ``events_scanned`` / ``events_total``: events merged so far vs. the
      job's full store (``events_total`` is ``None`` when unknown).
    - ``bricks_seen`` / ``bricks_total``: bricks that have contributed at
      least one packet.  A brick in ``bricks_seen`` is not necessarily
      finished — packets from one brick interleave across nodes.
    - ``packets``: partials merged (the prefix length).
    - ``failures``: node deaths observed so far.  A death re-queues the
      dead node's outstanding packets on surviving replicas, so a non-zero
      count means parts of the store are *holes* in the current prefix
      that a later snapshot will back-fill; the holes close by job end
      unless the whole scan aborts (in which case no final snapshot is
      ever published — see ``service/streaming.py``)."""
    events_scanned: int = 0
    events_total: Optional[int] = None
    bricks_seen: Tuple[int, ...] = ()
    bricks_total: Optional[int] = None
    packets: int = 0
    failures: int = 0

    @property
    def fraction(self) -> Optional[float]:
        """Scanned fraction in [0, 1], or None when the total is unknown."""
        if not self.events_total:
            return None
        return min(1.0, self.events_scanned / self.events_total)

    @property
    def complete(self) -> bool:
        """True when every event of a known-size store has been merged."""
        return (self.events_total is not None
                and self.events_scanned >= self.events_total)


class MergeAccumulator:
    """Incremental prefix merge with ``tree_merge``-exact snapshots.

    The streaming counterpart of :func:`tree_merge`: feed partials with
    :meth:`add` in packet-completion order and read :meth:`snapshot` at any
    time.  After ``k`` partials the snapshot is **bit-identical** to
    ``tree_merge(partials[:k])`` — including the float ``sum_var`` and the
    truncated id sample — so the service can publish progressive results
    mid-job and still guarantee the final one matches the batch JSE merge.

    How: a binary-counter forest (one pending subtree per set bit of the
    prefix length, like the classic streaming merge).  Adding partial
    ``k`` performs exactly the carry merges ``tree_merge`` would, and the
    forest's subtrees are the greedy binary decomposition of ``k`` — the
    same grouping ``tree_merge`` produces level by level.  A snapshot folds
    the forest right-associatively (smallest subtree innermost), which is
    the order the leftover-carry rule imposes, so the whole merge2 DAG
    matches and float sums see the same operand order.  Snapshots cost
    O(log k) merges and never mutate the forest.

    The accumulator also tracks :class:`Coverage`: pass the job's totals at
    construction and a ``brick_id`` per partial to get scanned-fraction /
    bricks-seen / failure-hole metadata alongside each snapshot."""

    def __init__(self, *, events_total: Optional[int] = None,
                 bricks_total: Optional[int] = None):
        # forest of (level, subtree), highest level (earliest leaves) first
        self._forest: List[Tuple[int, QueryResult]] = []
        self._n = 0
        self._events = 0
        self._bricks: set = set()
        self._failures = 0
        self.events_total = events_total
        self.bricks_total = bricks_total

    @property
    def n_partials(self) -> int:
        """Partials merged so far (the current prefix length)."""
        return self._n

    def add(self, partial: QueryResult, *,
            brick_id: Optional[int] = None) -> None:
        """Fold in the next partial (must be fed in packet-merge order).

        Performs the binary-counter carries: while the two newest subtrees
        cover equally many partials they merge (earlier operand on the
        left), exactly the pairings ``tree_merge`` makes."""
        self._n += 1
        self._events += partial.n_processed
        if brick_id is not None:
            self._bricks.add(int(brick_id))
        lvl, node = 0, partial
        while self._forest and self._forest[-1][0] == lvl:
            _, left = self._forest.pop()
            node = merge2(left, node)
            lvl += 1
        self._forest.append((lvl, node))

    def note_failure(self, n: int = 1) -> None:
        """Record ``n`` node deaths so coverage can flag re-queue holes."""
        self._failures += n

    def snapshot(self) -> QueryResult:
        """Exact merged result of the prefix seen so far.

        Bit-identical to ``tree_merge`` of the partials added so far; an
        empty accumulator snapshots to the empty :class:`QueryResult`."""
        if not self._forest:
            return QueryResult()
        acc = self._forest[-1][1]
        for _, tree in reversed(self._forest[:-1]):
            acc = merge2(tree, acc)
        return acc

    def coverage(self) -> Coverage:
        """Current :class:`Coverage` metadata (see its docstring)."""
        return Coverage(
            events_scanned=self._events,
            events_total=self.events_total,
            bricks_seen=tuple(sorted(self._bricks)),
            bricks_total=self.bricks_total,
            packets=self._n,
            failures=self._failures,
        )
