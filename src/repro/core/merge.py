"""Result merging — "the final result is merged from the various results
coming from the different Grid nodes" (paper section 4.2).

A query result is a small, associative-mergeable summary: selected-event
count, sum/histogram of a physics variable, and a bounded set of selected
event ids.  Associativity is what lets the merge run as a tree: per-brick
-> per-node -> per-pod -> JSE, and as plain psums in the SPMD realization.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

HIST_BINS = 64
HIST_RANGE = (0.0, 512.0)
MAX_IDS = 128


@dataclasses.dataclass
class QueryResult:
    n_selected: int = 0
    n_processed: int = 0
    sum_var: float = 0.0
    hist: Optional[np.ndarray] = None          # (HIST_BINS,) counts
    selected_ids: Optional[np.ndarray] = None  # bounded id sample

    def __post_init__(self):
        if self.hist is None:
            self.hist = np.zeros(HIST_BINS, np.int64)
        if self.selected_ids is None:
            self.selected_ids = np.zeros(0, np.int64)


def from_mask(mask: np.ndarray, var: np.ndarray,
              event_id: np.ndarray) -> QueryResult:
    sel = mask != 0
    vals = var[sel]
    hist, _ = np.histogram(vals, bins=HIST_BINS, range=HIST_RANGE)
    ids = event_id[sel][:MAX_IDS]
    return QueryResult(
        n_selected=int(sel.sum()), n_processed=int(mask.shape[0]),
        sum_var=float(vals.sum()), hist=hist.astype(np.int64),
        selected_ids=ids.astype(np.int64))


def merge2(a: QueryResult, b: QueryResult) -> QueryResult:
    return QueryResult(
        n_selected=a.n_selected + b.n_selected,
        n_processed=a.n_processed + b.n_processed,
        sum_var=a.sum_var + b.sum_var,
        hist=a.hist + b.hist,
        selected_ids=np.concatenate([a.selected_ids, b.selected_ids])[:MAX_IDS],
    )


def merge_batch(parts: Sequence[Sequence[QueryResult]]) -> List[QueryResult]:
    """Batched JSE merge for a shared scan: ``parts[i][k]`` is packet *i*'s
    partial for query *k*.  Each query's partials arrive in the same packet
    order, so merging column *k* with ``tree_merge`` is bit-identical to
    the merge an independent single-query job would have produced."""
    if not parts:
        return []
    k = len(parts[0])
    if any(len(p) != k for p in parts):
        raise ValueError("ragged batch partials")
    return [tree_merge([p[q] for p in parts]) for q in range(k)]


def tree_merge(results: Sequence[QueryResult]) -> QueryResult:
    """Pairwise tree reduction (the JSE merge schedule)."""
    if not results:
        return QueryResult()
    level: List[QueryResult] = list(results)
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            nxt.append(merge2(level[i], level[i + 1]))
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]
