"""Event records: the columnar stand-in for the paper's ROOT trees.

An *event* (paper section 1.1: one LHC collision, ~1 MB) is stored columnar:
per-event scalar variables plus a variable-length tracks matrix (padded to
``max_tracks`` with a validity count).  A batch of events is an ``EventBatch``
pytree of arrays whose leading dim is the event index — this is the unit the
grid bricks shard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

# canonical scalar variable names (index into the scalars column)
SCALAR_VARS = (
    "e_total", "e_t_miss", "pt_lead", "eta_lead", "phi_lead", "m_inv",
    "n_jets", "n_leptons",
)
TRACK_VARS = ("pt", "eta", "phi", "d0", "z0", "charge", "chi2")


@dataclasses.dataclass
class EventSchema:
    """Shape contract of an EventBatch: scalar-column count, track
    padding width, and per-track variable count (the query compiler
    resolves variable names against this)."""
    n_scalars: int
    max_tracks: int
    track_vars: int

    @classmethod
    def from_config(cls, cfg) -> "EventSchema":
        """Build from a geps_events config object."""
        return cls(cfg.n_scalars, cfg.max_tracks, cfg.track_vars)

    def scalar_index(self, name: str) -> int:
        """Column of scalar variable ``name`` (ValueError on unknown)."""
        return SCALAR_VARS.index(name)  # raises ValueError on unknown

    def track_index(self, name: str) -> int:
        """Column of track variable ``name`` (ValueError on unknown)."""
        return TRACK_VARS.index(name)

    def event_bytes(self) -> int:
        """Approximate serialized bytes per event (f32 columns + ids)."""
        return 4 * (self.n_scalars + self.max_tracks * self.track_vars + 2)


def make_batch(scalars, tracks, n_tracks, event_id) -> Dict[str, jax.Array]:
    """Assemble the canonical EventBatch pytree from its four columns."""
    return {
        "scalars": scalars,      # (N, n_scalars) f32
        "tracks": tracks,        # (N, max_tracks, track_vars) f32
        "n_tracks": n_tracks,    # (N,) i32 valid track count
        "event_id": event_id,    # (N,) i32 global id
    }


def synthetic_events(key, schema: EventSchema, n: int,
                     id_offset: int = 0) -> Dict[str, jax.Array]:
    """Generate physically-flavoured synthetic events (heavy-tailed pt etc.)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scalars = jnp.abs(jax.random.normal(k1, (n, schema.n_scalars)) * 50.0)
    tracks = jax.random.normal(k2, (n, schema.max_tracks, schema.track_vars))
    # pt column: exponential tail, always positive
    if schema.track_vars > 0:
        pt = jax.random.exponential(k3, (n, schema.max_tracks)) * 10.0
        tracks = tracks.at[:, :, 0].set(pt)
    n_tracks = jax.random.randint(k4, (n,), 1, schema.max_tracks + 1,
                                  jnp.int32)
    event_id = jnp.arange(id_offset, id_offset + n, dtype=jnp.int32)
    return make_batch(scalars.astype(jnp.float32),
                      tracks.astype(jnp.float32), n_tracks, event_id)


def abstract_events(schema: EventSchema, n: int):
    """ShapeDtypeStructs for dry-run lowering of query jobs."""
    return make_batch(
        jax.ShapeDtypeStruct((n, schema.n_scalars), jnp.float32),
        jax.ShapeDtypeStruct((n, schema.max_tracks, schema.track_vars),
                             jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )


def concat_batches(batches):
    """Concatenate EventBatches along the event axis."""
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *batches)


def host_events(rng: np.random.Generator, schema: EventSchema, n: int,
                id_offset: int = 0):
    """NumPy twin of synthetic_events for host-side brick stores."""
    scalars = np.abs(rng.normal(size=(n, schema.n_scalars)) * 50.0)
    tracks = rng.normal(size=(n, schema.max_tracks, schema.track_vars))
    if schema.track_vars > 0:
        tracks[:, :, 0] = rng.exponential(size=(n, schema.max_tracks)) * 10.0
    n_tracks = rng.integers(1, schema.max_tracks + 1, size=(n,))
    return make_batch(
        scalars.astype(np.float32), tracks.astype(np.float32),
        n_tracks.astype(np.int32),
        np.arange(id_offset, id_offset + n, dtype=np.int32))
