"""Replica placement and failover — the paper's section-7 future work
("create a redundancy mechanism to recover from a malfunction in the
nodes"), built as a first-class feature.

Placement is ring-offset: replicas of a brick owned by node n go to
n + N//r, n + 2N//r, ... (mod N) — spreading load so a single node failure
scatters its recovery reads across the ring instead of hammering one peer.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Set, Tuple


def place_replicas(brick_id: int, node: int, n_nodes: int,
                   replication: int) -> Tuple[int, ...]:
    """Replica owners for a brick (excluding the primary)."""
    r = max(0, min(replication - 1, n_nodes - 1))
    if r == 0:
        return ()
    stride = max(1, n_nodes // (r + 1))
    return tuple((node + (i + 1) * stride) % n_nodes for i in range(r))


def failover_owner(owners: List[int], dead: Set[int]) -> int:
    """First alive owner, or -1 if the brick is lost (paper's acknowledged
    worst case when running without replication)."""
    for n in owners:
        if n not in dead:
            return n
    return -1


def rereplication_plan(specs: Dict[int, "object"], dead: Set[int],
                       n_nodes: int) -> List[Tuple[int, int, int]]:
    """(brick_id, src_node, dst_node) copies needed to restore the
    replication factor after failures."""
    plan = []
    alive = [n for n in range(n_nodes) if n not in dead]
    if not alive:
        return plan
    rr = 0
    for bid, spec in sorted(specs.items()):
        owners = [spec.node, *spec.replicas]
        alive_owners = [n for n in owners if n not in dead]
        lost = len(owners) - len(alive_owners)
        if lost == 0 or not alive_owners:
            continue
        src = alive_owners[0]
        for _ in range(lost):
            while alive[rr % len(alive)] in owners:
                rr += 1
            dst = alive[rr % len(alive)]
            rr += 1
            plan.append((bid, src, dst))
    return plan
