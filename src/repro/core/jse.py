"""Job Submission Engine (JSE) — the paper's section 4.2 dataflow:

  user submits job -> meta-data catalogue -> JSE broker picks it up ->
  per-brick tasks dispatched to the nodes owning the data -> per-node
  results -> merged at the JSE -> catalogue updated -> user retrieves.

Two execution realizations share this module's primitives:

- ``run_job_simulated``: an event-driven virtual-time grid simulation over
  the host-level BrickStore.  Compute on each packet is REAL (numpy query
  evaluation on the actual brick slice), time is virtual (node speeds,
  staging overhead, result transfer) — this is what reproduces the paper's
  Fig 7 crossover and exercises straggler mitigation / failover.

- ``spmd_query_step``: the TPU-native realization — one lockstep jit over
  the mesh-sharded event store (bricks = batch shards that never move),
  with the merge expressed as cross-shard reductions.

The service layer does not call either directly anymore: it programs
against the :class:`~repro.core.backend.ExecutionBackend` contract
(``core/backend.py``), whose ``SimulatedBackend`` wraps the simulation
below and whose ``SpmdBackend`` runs the fragment plan as a chunked
streaming scan over the brick shards.  :func:`eval_plan_slice` is the
one compute primitive both backends share, which is what keeps their
per-packet partials bit-identical.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.brick import BrickStore, batch_sharding
from repro.core.catalog import DONE, FAILED, RUNNING, MetadataCatalog
from repro.core.packets import AdaptivePacketScheduler
from repro.core.replication import failover_owner


@dataclasses.dataclass
class TimeModel:
    """Virtual-time constants (calibrated to the paper's fast-Ethernet grid:
    the Fig-7 crossover sits near 2000 events)."""
    t_event_s: float = 2.0e-3          # per-event processing on a 1x node
    stage_overhead_s: float = 1.15     # executable staging (GRAM) per node
    dispatch_latency_s: float = 0.05   # per-packet control round trip
    result_bytes: float = 2.0e5        # per-node result file (per query)
    bandwidth_Bps: float = 12.5e6      # 100 Mbit/s fast Ethernet
    merge_per_node_s: float = 0.02     # JSE merge cost per partial result
    brick_bytes_per_event: float = 2.0e3  # on-disk brick payload per event
    # (re-replication ships whole bricks: n_events x brick_bytes_per_event
    # over the same fast-Ethernet links, charged on BOTH endpoints)

    # A shared scan is read-dominated: evaluating K stacked predicates on a
    # resident slice costs the same sweep as one (the extra FLOPs hide under
    # the HBM/disk read), so per-packet compute is charged once per batch.
    # Only the result files and the JSE merge scale with K.


@dataclasses.dataclass(frozen=True)
class PacketPartial:
    """One packet's partial results, announced the moment the virtual node
    finishes computing them — the unit of streaming result delivery.

    ``partials`` holds one :class:`~repro.core.merge.QueryResult` per plan
    target (per-query roots first, then materialized shared fragments),
    exactly the row the batch path appends to its merge input.  ``seq`` is
    the packet's position in merge order: feeding partials to a
    :class:`~repro.core.merge.MergeAccumulator` in ``seq`` order makes
    every prefix snapshot bit-identical to the final ``tree_merge``.
    ``t_virtual`` is the packet's compute-completion time on the simulated
    grid clock (the same clock as ``JobStats.makespan_s``), and
    ``failures`` the cumulative node deaths observed so far (coverage
    holes; see ``docs/streaming.md``)."""
    seq: int
    brick_id: int
    start: int
    size: int
    node: int
    t_virtual: float
    failures: int
    partials: List[merge_lib.QueryResult]


@dataclasses.dataclass(frozen=True)
class PacketTelemetry:
    """Measured compute for one evaluated packet: events in the slice,
    calibration iterations applied, distinct track aggregates the
    fragment-factored pass swept, the number of plan targets the packet
    evaluated (the whole window rides one measurement — the fitter
    normalizes per target so window width is not an omitted variable),
    and the REAL (wall-clock) evaluation time.  This is the per-packet
    observable the planner's cost-model calibration
    (``planner.fit_cost_weights``) regresses on — virtual time charges a
    flat per-event rate, but the actual numpy/JAX compute scales with
    calibration and aggregate depth.

    ``node`` attributes the measurement to the grid node that scanned the
    packet (-1 when unknown) — the observability plane's health monitor
    (``repro.obs.health``) folds these into per-node latency EWMAs."""
    size: int
    calib_iters: int
    n_aggregates: int
    wall_s: float
    n_targets: int = 1
    node: int = -1


@dataclasses.dataclass
class JobStats:
    """Execution telemetry for one (batched) simulated grid job: virtual
    makespan, per-node busy time, packet/failure counts, events swept, and
    the planner's fragment accounting."""
    makespan_s: float = 0.0
    per_node_busy: Dict[int, float] = dataclasses.field(default_factory=dict)
    packets: int = 0
    failures: int = 0
    reassigned: int = 0
    # failure-policy accounting: speculative duplicate executions of
    # straggling packets attempted / won (first-result-wins), and packets
    # the routing policy kept away from banned nodes
    speculated: int = 0
    spec_wins: int = 0
    # virtual seconds of brick-copy traffic charged for proactive
    # re-replication applied to this window (both endpoints busy while the
    # copy streams — data movement is never free)
    rereplication_transfer_s: float = 0.0
    events_scanned: int = 0   # brick events swept (shared across a batch)
    # events whose chunk ran (at least partly) through the fused Pallas
    # kernel sub-batch — 0 on the simulation and on pure-jnp SPMD windows
    kernel_events: int = 0
    n_queries: int = 1        # queries amortized over that sweep
    # fragment accounting (common-subexpression factoring across the batch)
    fragment_evals: int = 0           # unique-fragment evaluations performed
    fragment_evals_unshared: int = 0  # what K independent compiles would do
    # merged results for materialized shared fragments, keyed by fragment
    # canonical (query_lib.node_key) — fed to the fragment-level cache
    fragment_results: Dict[str, merge_lib.QueryResult] = \
        dataclasses.field(default_factory=dict)
    # per-packet compute observations for cost-model calibration
    packet_telemetry: List[PacketTelemetry] = \
        dataclasses.field(default_factory=list)


def prepare_window(catalog: MetadataCatalog, job_ids: List[int],
                   plan: Optional[query_lib.FragmentPlan] = None):
    """Validate one shared-scan window and mark its jobs RUNNING — the
    common preamble of every backend's ``run_batch``.

    Checks shared-scan compatibility (every job must cover the same
    bricks with the same ``calib_iters``), builds the fragment plan when
    none was passed, and verifies a passed plan's roots align one-to-one
    with the jobs.  Returns ``(rec, plan)`` where ``rec`` is the window's
    representative job record.  Keeping this in ONE place is what keeps
    the backends' preconditions from diverging."""
    recs = [catalog.jobs[j] for j in job_ids]
    if not recs:
        raise ValueError("empty job batch")
    rec = recs[0]
    for r in recs[1:]:
        if r.bricks != rec.bricks or r.calib_iters != rec.calib_iters:
            raise ValueError(
                f"job {r.job_id} incompatible with shared scan "
                f"(bricks/calib_iters differ from job {rec.job_id})")
    for jid in job_ids:
        catalog.update(jid, status=RUNNING, start_time=time.time())
    if plan is None:
        plan = query_lib.build_fragment_plan([r.expr for r in recs])
    elif len(plan.roots) != len(recs):
        raise ValueError(
            f"plan has {len(plan.roots)} roots for {len(recs)} jobs")
    return rec, plan


def eval_plan_slice(store: BrickStore, plan: query_lib.FragmentPlan,
                    brick_id: int, start: int, size: int,
                    calib_iters: int) -> List[merge_lib.QueryResult]:
    """One slice read + one calibration + one fragment-factored pass —
    the shared-scan inner loop every execution backend runs (the slice is
    resident while every in-flight query consumes it).  Returns one
    partial per plan target (per-query roots first, then materialized
    shared fragments).

    This is deliberately the ONLY place a brick slice is turned into
    partials: the simulated and SPMD backends (``core/backend.py``) both
    call it, so a packet covering the same ``[start, start+size)`` range
    of the same brick yields bit-identical partials on either backend."""
    batch = store.bricks[brick_id]
    sl = {k: v[start:start + size] for k, v in batch.items()}
    slj = {k: jnp.asarray(v) for k, v in sl.items()}
    if calib_iters:
        slj = dict(slj, tracks=query_lib.calibrate(slj, calib_iters))
    var = np.asarray(slj["scalars"][:, 0])  # e_total summary variable
    ids = np.asarray(sl["event_id"])
    masks = plan.evaluate(slj, store.schema)
    return [merge_lib.from_mask(np.asarray(m), var, ids) for m in masks]


class JobSubmissionEngine:
    """The paper's JSE broker: submits jobs to the catalogue, fans each one
    out as per-brick packets to the owning nodes, merges the partials, and
    writes the result back.  ``run_job_batch_simulated`` is the shared-scan
    execution engine the service drives; pass ``on_partial`` to stream
    per-packet partial merges out while the job runs."""

    def __init__(self, catalog: MetadataCatalog, store: BrickStore,
                 time_model: Optional[TimeModel] = None,
                 node_speed: Optional[Dict[int, float]] = None,
                 adaptive_packets: bool = True,
                 packet_ramp: Optional[int] = None,
                 ramp_factor: float = 2.0):
        self.catalog = catalog
        self.store = store
        self.tm = time_model or TimeModel()
        self.node_speed = node_speed or {}
        self.adaptive_packets = adaptive_packets
        # stream-aware sizing: cap early packets at `packet_ramp` events,
        # growing by `ramp_factor` per completed packet (None disables)
        self.packet_ramp = packet_ramp
        self.ramp_factor = ramp_factor
        # observability plane (repro.obs.Observability); None = disabled,
        # and every instrumentation site below is a single `is not None`
        # test on the disabled path
        self.obs = None

    # ------------------------------------------------------------------ #
    def submit(self, expr: str, calib_iters: int = 0) -> int:
        """Register a job over every brick in the store; returns a job id."""
        bricks = tuple(sorted(self.store.bricks))
        return self.catalog.submit(expr, calib_iters, bricks)

    def broker_poll(self, failure_script=None) -> Optional[int]:
        """Pick up the next pending job (the paper's polling broker)."""
        rec = self.catalog.next_pending()
        if rec is None:
            return None
        self.run_job_simulated(rec.job_id, failure_script=failure_script)
        return rec.job_id

    # ------------------------------------------------------------------ #
    def _eval_packet_batch(self, plan: query_lib.FragmentPlan, brick_id: int,
                           start: int, size: int, calib_iters: int
                           ) -> List[merge_lib.QueryResult]:
        """Delegates to :func:`eval_plan_slice` (kept as a method for the
        simulation loop and any external caller)."""
        return eval_plan_slice(self.store, plan, brick_id, start, size,
                               calib_iters)

    def run_job_simulated(self, job_id: int, *,
                          failure_script: Optional[Dict[float, int]] = None,
                          on_partial: Optional[
                              Callable[[PacketPartial], None]] = None
                          ) -> Tuple[merge_lib.QueryResult, JobStats]:
        """Event-driven simulation: nodes pull packets, compute (really),
        and finish after a virtual duration; failures re-queue work on the
        surviving replicas (PROOF-style)."""
        merged, stats = self.run_job_batch_simulated(
            [job_id], failure_script=failure_script, on_partial=on_partial)
        return merged[0], stats

    def run_job_batch_simulated(self, job_ids: List[int], *,
                                failure_script: Optional[Dict[float, int]]
                                = None,
                                plan: Optional[query_lib.FragmentPlan] = None,
                                on_partial: Optional[
                                    Callable[[PacketPartial], None]] = None,
                                packet_ramp: Optional[int] = None,
                                route_avoid: Optional[set] = None,
                                probe_quota: Optional[Dict[int, int]] = None,
                                speculate: bool = False,
                                spec_lead_factor: float = 1.5,
                                rereplicated: Optional[
                                    List[Tuple[int, int, int]]] = None
                                ) -> Tuple[List[merge_lib.QueryResult],
                                           JobStats]:
        """Shared-scan execution of K coalesced jobs: ONE sweep over the
        bricks evaluates every job's predicate on each resident packet, so
        the event-store read is amortized K ways.  The batch is compiled
        through a :class:`~repro.core.query.FragmentPlan` (pass ``plan`` to
        reuse one the service planner already built, e.g. with materialized
        shared fragments), so common subexpressions across the K queries are
        evaluated once per packet.  Scheduling, failure handling and the
        per-query merges are identical to K independent
        ``run_job_simulated`` runs — per-query results are bit-identical.

        Returns ``(merged, stats)`` where ``merged[k]`` is job *k*'s result;
        merged results for any materialized shared fragments are in
        ``stats.fragment_results``.

        ``on_partial``, when given, is invoked once per evaluated packet
        with a :class:`PacketPartial`, in the exact order the batch merge
        consumes partials — the streaming delivery hook.  The callback runs
        synchronously inside the scan loop and must not raise; a truncated
        (FAILED) scan still emits the partials computed before the abort,
        but no DONE result ever follows them.

        ``packet_ramp`` overrides the engine-level stream-aware ramp for
        THIS run only (the service enables it per window when someone is
        streaming); None inherits the engine setting.

        ``route_avoid`` / ``probe_quota`` carry the failure policy's
        routing decision (``service/policy.py``): avoided nodes never
        lease a packet this window unless they hold probe quota, in which
        case they lease at most that many packets.  Replica failover
        prefers non-avoided owners; if avoidance would starve the scan,
        availability wins and the policy is ignored.

        ``rereplicated`` charges the data movement of brick copies the
        failure policy applied before this window (``(brick, src, dst)``
        triples): each copy occupies BOTH endpoints for the brick's
        transfer time on the virtual clock before either node leases its
        first packet, and the total lands in
        ``JobStats.rereplication_transfer_s`` — re-replication buys
        resilience with real bandwidth, not for free.

        ``speculate`` enables straggler mitigation: when a node goes idle
        with the queue drained, it re-executes the slowest unresolved
        in-flight packet (first-result-wins).  Because
        :func:`eval_plan_slice` is pure, the duplicate partials are
        bit-identical to the originals and are structurally discarded —
        speculation can only lower a packet's ``t_virtual`` completion,
        never change the merged result.  In this mode partial emission is
        deferred to virtual completion order (stamps stay honest), and
        ``makespan_s`` covers the straggler tail."""
        rec, plan = prepare_window(self.catalog, job_ids, plan)
        failure_script = dict(failure_script or {})

        ramp = packet_ramp if packet_ramp is not None else self.packet_ramp
        sched = AdaptivePacketScheduler(self.catalog, ramp_start=ramp,
                                        ramp_factor=self.ramp_factor)
        if not self.adaptive_packets:
            sched.min = sched.max = sched.base
        dead = self.catalog.dead_nodes()
        # routing policy: banned nodes never lease; probing nodes lease at
        # most their probe quota.  Availability beats policy — if avoidance
        # would leave no usable node, it is ignored wholesale.
        avoid = set(route_avoid or ()) - set(dead)
        quota = dict(probe_quota or {})
        alive_all = self.catalog.alive_nodes()
        usable = [n for n in alive_all
                  if n not in avoid or quota.get(n, 0) > 0]
        if not usable:
            avoid, quota = set(), {}
            usable = list(alive_all)
        banned = {n for n in avoid if quota.get(n, 0) <= 0}
        n_alive = max(1, len(usable))
        total_events = sum(self.store.specs[b].n_events for b in rec.bricks)
        if self.adaptive_packets:
            # PROOF base sizing: ~8 packets per node over the job, adapted
            # per node by throughput and shrunk as the queue drains
            sched.base = max(sched.min, total_events // (4 * n_alive))
        brick_node: Dict[int, int] = {}
        lost = []
        unavailable = set(dead) | banned
        for bid in rec.bricks:
            # replica-aware re-targeting: prefer an owner that is neither
            # dead nor banned; fall back to any live owner rather than
            # declare the brick lost (availability over policy)
            owner = failover_owner(self.store.owners(bid), unavailable)
            if owner < 0:
                owner = failover_owner(self.store.owners(bid), dead)
            if owner < 0:
                lost.append(bid)
                continue
            brick_node[bid] = owner
            sched.add_work(bid, self.store.specs[bid].n_events)

        if lost:
            for jid in job_ids:
                self.catalog.update(jid, status=FAILED,
                                    note=f"bricks lost (no replica): {lost}")
            return ([merge_lib.QueryResult() for _ in job_ids],
                    JobStats(n_queries=len(job_ids)))

        obs = self.obs
        stats = JobStats(n_queries=len(job_ids))
        plan_aggs = query_lib.unique_aggregates(plan.targets())
        results: List[List[merge_lib.QueryResult]] = []
        # re-replication transfer charge: each applied copy streams one
        # whole brick src -> dst, occupying both endpoints before they can
        # lease packets (the window pays for the policy's data movement)
        busy0: Dict[int, float] = {}
        for bid, src, dst in (rereplicated or ()):
            spec = self.store.specs.get(bid)
            if spec is None:
                continue
            xfer = (spec.n_events * self.tm.brick_bytes_per_event
                    / self.tm.bandwidth_Bps)
            busy0[src] = busy0.get(src, 0.0) + xfer
            busy0[dst] = busy0.get(dst, 0.0) + xfer
            stats.rereplication_transfer_s += xfer
        # virtual clock: heap of (t_free, node); staging charged on first use
        now = 0.0
        free_at: Dict[int, float] = {n: busy0.get(n, 0.0) for n in usable}
        heap = [(free_at[n], n) for n in usable]
        heapq.heapify(heap)
        staged: set = set()
        deadlines = sorted(failure_script)  # virtual times at which nodes die

        def push(t: float, n: int) -> None:
            # `free_at` names each node's live heap entry, so a speculation
            # win can cancel the loser by re-pushing it earlier (the stale
            # entry is skipped at pop time)
            free_at[n] = t
            heapq.heappush(heap, (t, n))

        def speed(n):
            return self.node_speed.get(n, 1.0)

        # speculation state: per-seq virtual completion of in-flight
        # packets; spec mode defers partial emission to completion order
        spec_open: Dict[int, dict] = {}
        emit_buf: Dict[int, PacketPartial] = {}
        emit_next = 0

        def flush_partials(t_now: Optional[float]) -> None:
            # emit buffered partials in seq order once the packet's virtual
            # completion has passed (t_now=None flushes everything)
            nonlocal emit_next
            while emit_next in emit_buf:
                info = spec_open.get(emit_next)
                if t_now is not None and info is not None \
                        and info["t_done"] > t_now:
                    break
                pp = emit_buf.pop(emit_next)
                if info is not None:
                    pp = dataclasses.replace(pp, t_virtual=info["t_done"],
                                             node=info["node"])
                    spec_open.pop(emit_next)
                if on_partial is not None:
                    on_partial(pp)
                emit_next += 1

        def spec_pending() -> bool:
            # unresolved, not-yet-duplicated in-flight completions: what
            # keeps the loop alive after the queue drains in spec mode so
            # idle nodes get their chance to re-execute the stragglers
            return any(i["t_done"] > now and not i["spec"]
                       for i in spec_open.values())

        while not sched.exhausted or (speculate and heap and spec_pending()):
            if not heap:
                live = self.catalog.alive_nodes()
                if avoid and live:
                    # the routing policy starved the scan (every routable
                    # node out of budget): availability wins, re-admit all
                    avoid, quota = set(), {}
                    for n in live:
                        push(now, n)
                    continue
                break
            t_free, node = heapq.heappop(heap)
            if free_at.get(node, t_free) != t_free:
                continue  # superseded by a speculation cancel/re-push
            now = max(now, t_free)
            if speculate:
                flush_partials(now)
            # failure injection
            while deadlines and deadlines[0] <= now:
                t_kill = deadlines.pop(0)
                victim = failure_script[t_kill]
                if self.catalog.node(victim).alive:
                    self.catalog.mark_dead(victim)
                    sched.requeue_node(victim)
                    stats.failures += 1
                    stats.reassigned += 1
                    if obs is not None:
                        obs.tracer.event(
                            "node_death",
                            t_virtual=obs.tracer.virtual_base + now,
                            node=victim)
                        obs.metrics.counter("grid.node_deaths").inc()
                        obs.health.observe_failure(victim)
            if not self.catalog.node(node).alive:
                continue
            if node in avoid and quota.get(node, 0) <= 0:
                continue  # probe budget exhausted: out of this window
            pkt = sched.next_packet(node)
            if pkt is None:
                if speculate:
                    cand = [(info["t_done"], -seq, seq, info)
                            for seq, info in spec_open.items()
                            if info["t_done"] > now and not info["spec"]
                            and info["node"] != node]
                    if cand:
                        _, _, seq, info = max(cand)
                        dur2 = (self.tm.dispatch_latency_s
                                + info["size"] * self.tm.t_event_s
                                / speed(node))
                        if node not in staged:
                            dur2 += self.tm.stage_overhead_s
                        if info["t_done"] - now > spec_lead_factor * dur2:
                            # duplicate execution of the straggling slice:
                            # eval_plan_slice is pure, so the duplicate is
                            # bit-identical to the row already appended at
                            # lease time and is discarded — structural
                            # first-result-wins, no double merge possible
                            dup = self._eval_packet_batch(
                                plan, info["brick"], info["start"],
                                info["size"], rec.calib_iters)
                            identical = all(
                                merge_lib.results_identical(a, b)
                                for a, b in zip(results[seq], dup))
                            staged.add(node)
                            info["spec"] = True
                            stats.speculated += 1
                            t_spec = now + dur2
                            win = t_spec < info["t_done"]
                            if obs is not None:
                                obs.tracer.event(
                                    "speculate",
                                    t_virtual=obs.tracer.virtual_base + now,
                                    seq=seq, node=node,
                                    origin_node=info["node"], win=win,
                                    identical=identical)
                                obs.metrics.counter(
                                    "policy.speculations").inc()
                            if win:
                                stats.spec_wins += 1
                                if obs is not None:
                                    obs.metrics.counter(
                                        "policy.spec_wins").inc()
                                loser = info["node"]
                                info["node"] = node
                                info["t_done"] = t_spec
                                # first result wins: the loser is cancelled
                                # and frees when the winner completes
                                push(t_spec, loser)
                                stats.per_node_busy[node] = \
                                    stats.per_node_busy.get(node, 0) + dur2
                                push(t_spec, node)
                            else:
                                # the original finishes first; the
                                # speculating node abandons at that moment
                                stats.per_node_busy[node] = \
                                    stats.per_node_busy.get(node, 0) \
                                    + (info["t_done"] - now)
                                push(info["t_done"], node)
                            continue
                if sched.inflight:
                    push(now + 0.01, node)
                continue
            pkt_span = None
            if obs is not None:
                pkt_span = obs.tracer.begin(
                    "packet", t_virtual=obs.tracer.virtual_base + now,
                    seq=len(results), brick=pkt.brick_id, start=pkt.start,
                    size=pkt.size, node=node)
            t_wall = time.perf_counter()
            res = self._eval_packet_batch(plan, pkt.brick_id,
                                          pkt.start, pkt.size,
                                          rec.calib_iters)
            wall_s = time.perf_counter() - t_wall
            stats.packet_telemetry.append(PacketTelemetry(
                size=pkt.size, calib_iters=rec.calib_iters,
                n_aggregates=plan_aggs, wall_s=wall_s,
                n_targets=len(plan.targets()), node=node))
            results.append(res)
            stats.events_scanned += pkt.size
            stats.fragment_evals += plan.evals_per_batch
            stats.fragment_evals_unshared += plan.unshared_evals
            compute = pkt.size * self.tm.t_event_s / speed(node)
            dur = self.tm.dispatch_latency_s + compute
            if node not in staged:
                dur += self.tm.stage_overhead_s
                staged.add(node)
            if obs is not None:
                obs.tracer.end(
                    pkt_span,
                    t_virtual=obs.tracer.virtual_base + now + dur)
                obs.metrics.counter("packet.count").inc()
                obs.metrics.histogram("packet.latency_s").observe(wall_s)
                obs.metrics.histogram("packet.events").observe(pkt.size)
                obs.health.observe_packet(node, pkt.size, wall_s)
            seq = len(results) - 1
            if speculate:
                spec_open[seq] = {"node": node, "t_done": now + dur,
                                  "brick": pkt.brick_id, "start": pkt.start,
                                  "size": pkt.size, "spec": False}
                if on_partial is not None:
                    emit_buf[seq] = PacketPartial(
                        seq=seq, brick_id=pkt.brick_id, start=pkt.start,
                        size=pkt.size, node=node, t_virtual=now + dur,
                        failures=stats.failures, partials=res)
            elif on_partial is not None:
                on_partial(PacketPartial(
                    seq=seq, brick_id=pkt.brick_id,
                    start=pkt.start, size=pkt.size, node=node,
                    t_virtual=now + dur, failures=stats.failures,
                    partials=res))
            # throughput telemetry sees compute only — staging/dispatch in
            # the EMA would shrink every node's packets (GRIS reports CPU
            # rate, not control-plane latency)
            sched.complete(pkt.packet_id, pkt.size, compute)
            stats.per_node_busy[node] = stats.per_node_busy.get(node, 0) + dur
            stats.packets += 1
            if node in avoid:
                quota[node] = quota.get(node, 0) - 1
            push(now + dur, node)

        if speculate:
            # the virtual clock stops at the last LEASE; the straggler tail
            # (unresolved completions) is exactly what speculation shortens,
            # so spec-mode makespan accounts for it before flushing
            now = max([i["t_done"] for i in spec_open.values()] + [now])
            flush_partials(None)

        if not sched.exhausted:
            # every node died with work outstanding: the scan is truncated,
            # never a DONE result (a cached partial would poison repeats)
            for jid in job_ids:
                self.catalog.update(jid, status=FAILED,
                                    note="scan aborted: all nodes dead "
                                         "with packets outstanding")
            return ([merge_lib.QueryResult() for _ in job_ids], stats)

        # result transfer + JSE merge (both scale with the batch width)
        k = len(job_ids)
        n_active = len(stats.per_node_busy)
        transfer = k * self.tm.result_bytes / self.tm.bandwidth_Bps
        merged = (merge_lib.merge_batch(results) if results
                  else [merge_lib.QueryResult()
                        for _ in range(len(plan.targets()))])
        # plan targets are roots first, then materialized shared fragments
        stats.fragment_results = dict(
            zip(plan.materialize_keys(), merged[k:]))
        merged = merged[:k]
        makespan = now + transfer + k * n_active * self.tm.merge_per_node_s
        stats.makespan_s = makespan

        end = time.time()
        for jid, m in zip(job_ids, merged):
            self.catalog.update(
                jid, status=DONE, end_time=end,
                events_processed=m.n_processed, failures=stats.failures,
                result={
                    "n_selected": m.n_selected,
                    "n_processed": m.n_processed,
                    "sum_var": m.sum_var,
                    "makespan_s": makespan,
                })
        return merged, stats

    def single_node_time(self, n_events: int, calib_iters: int = 0,
                         node_speed: float = 1.0) -> float:
        """The paper's 'running only on hobbit' baseline (tightly coupled:
        no staging to remote nodes, no result transfer)."""
        return n_events * self.tm.t_event_s / node_speed


# --------------------------------------------------------------------------- #
# SPMD realization: the whole grid job as ONE lockstep step over the mesh
# --------------------------------------------------------------------------- #
def spmd_query_step(expr: str, schema: ev.EventSchema, calib_iters: int = 0,
                    use_pallas: bool = False) -> Callable:
    """Returns fn(batch)->dict of merged results; jit/pjit it over the mesh.

    The per-brick compute (predicate + calibration) happens where each
    event shard lives; the cross-shard sums ARE the JSE merge."""
    predicate = None  # compiled lazily to keep errors at call site

    def step(batch):
        if use_pallas:
            # the kernel fuses calibration with the reduction: raw batch in
            from repro.kernels.event_filter import ops as ef_ops
            mask, var = ef_ops.filter_and_summarize(
                expr, schema, batch, calib_iters=calib_iters)
        else:
            pred = query_lib.compile_query(expr, schema)
            b = batch
            if calib_iters:
                b = dict(b, tracks=query_lib.calibrate(b, calib_iters))
            mask = pred(b)
            var = b["scalars"][:, 0]
        maskf = (mask != 0).astype(jnp.float32)
        lo, hi = merge_lib.HIST_RANGE
        width = (hi - lo) / merge_lib.HIST_BINS
        idx = jnp.clip(((var - lo) / width).astype(jnp.int32), 0,
                       merge_lib.HIST_BINS - 1)
        hist = jnp.sum(
            jax.nn.one_hot(idx, merge_lib.HIST_BINS, dtype=jnp.float32)
            * maskf[:, None], axis=0)
        return {
            "n_selected": jnp.sum(maskf),
            "n_processed": jnp.float32(maskf.shape[0]),
            "sum_var": jnp.sum(var * maskf),
            "hist": hist,
        }

    return step


def spmd_query_batch_step(exprs: List[str], schema: ev.EventSchema,
                          calib_iters: int = 0,
                          use_pallas: bool = False) -> Callable:
    """Batched twin of ``spmd_query_step``: ONE lockstep pass over the
    sharded event store evaluates K queries, returning a dict whose leaves
    carry a leading K axis.  The event shards (and the calibration pass)
    are read/computed once and amortized over every query — the SPMD
    realization of the service's shared scan."""
    def step(batch):
        if use_pallas:
            from repro.kernels.event_filter import ops as ef_ops
            masks, var = ef_ops.filter_and_summarize_batch(
                exprs, schema, batch, calib_iters=calib_iters)
        else:
            bpred = query_lib.compile_query_batch(exprs, schema)
            b = batch
            if calib_iters:
                b = dict(b, tracks=query_lib.calibrate(b, calib_iters))
            masks = bpred(b)                      # (K, N)
            var = b["scalars"][:, 0]
        maskf = (masks != 0).astype(jnp.float32)  # (K, N)
        lo, hi = merge_lib.HIST_RANGE
        width = (hi - lo) / merge_lib.HIST_BINS
        idx = jnp.clip(((var - lo) / width).astype(jnp.int32), 0,
                       merge_lib.HIST_BINS - 1)
        onehot = jax.nn.one_hot(idx, merge_lib.HIST_BINS, dtype=jnp.float32)
        return {
            "n_selected": jnp.sum(maskf, axis=-1),
            "n_processed": jnp.full((maskf.shape[0],), maskf.shape[1],
                                    jnp.float32),
            "sum_var": maskf @ var,
            "hist": maskf @ onehot,               # (K, HIST_BINS)
        }

    return step
