"""Grid bricks: the paper's core storage organization.

"The data storage is split among all grid nodes having each one a piece of
the whole information" (abstract).  A *brick* is a fixed-size slice of the
event store pinned to one node's local disk; jobs ship to bricks, results
ship back — bricks never move at job time.

Two realizations:
- host level (``BrickStore``): numpy arrays per brick with an explicit
  node placement + replica map — used by the JSE simulation, the failure /
  straggler benchmarks, and the data pipeline;
- SPMD level (``shard_to_mesh``): the same batch laid out over the
  ``("pod","data")`` mesh axes with a NamedSharding, so one lockstep jit is
  the "dispatch to all bricks" of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import events as ev
from repro.core.replication import place_replicas


@dataclasses.dataclass
class BrickSpec:
    """Catalogue entry for one brick: primary owner, replica owners, and
    the global event-id range the brick covers."""
    brick_id: int
    node: int                       # primary owner
    replicas: Tuple[int, ...]       # replica owners (paper section 7)
    n_events: int
    id_range: Tuple[int, int]       # [start, end) global event ids


@dataclasses.dataclass
class BrickStore:
    """Host-level realization of the brick-sharded event store: per-brick
    numpy EventBatches plus the placement/replication map the JSE
    simulation schedules against."""
    schema: ev.EventSchema
    bricks: Dict[int, dict]                 # brick_id -> EventBatch (numpy)
    specs: Dict[int, BrickSpec]
    n_nodes: int

    @property
    def n_events(self) -> int:
        """Total events across every brick in the store."""
        return sum(s.n_events for s in self.specs.values())

    def bricks_on_node(self, node: int, include_replicas=False) -> List[int]:
        """Brick ids whose primary (optionally: any replica) is ``node``."""
        out = []
        for bid, spec in self.specs.items():
            if spec.node == node or (include_replicas and node in spec.replicas):
                out.append(bid)
        return sorted(out)

    def owners(self, brick_id: int) -> List[int]:
        """Every node holding the brick, primary first (failover order)."""
        spec = self.specs[brick_id]
        return [spec.node, *spec.replicas]


def create_store(schema: ev.EventSchema, *, n_events: int, n_nodes: int,
                 events_per_brick: int, replication: int = 2,
                 seed: int = 0) -> BrickStore:
    """Distribute a synthetic event dataset over n_nodes as bricks."""
    rng = np.random.default_rng(seed)
    bricks, specs = {}, {}
    brick_id, offset = 0, 0
    while offset < n_events:
        n = min(events_per_brick, n_events - offset)
        batch = ev.host_events(rng, schema, n, id_offset=offset)
        node = brick_id % n_nodes
        replicas = place_replicas(brick_id, node, n_nodes, replication)
        specs[brick_id] = BrickSpec(brick_id, node, replicas, n,
                                    (offset, offset + n))
        bricks[brick_id] = batch
        offset += n
        brick_id += 1
    return BrickStore(schema, bricks, specs, n_nodes)


# --------------------------------------------------------------------------- #
# SPMD realization
# --------------------------------------------------------------------------- #
def batch_sharding(mesh) -> NamedSharding:
    """Sharding that splits the event axis over the mesh's brick axes
    (``pod``/``data``) — the SPMD twin of brick placement."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return NamedSharding(mesh, P(axes))


def shard_to_mesh(batch: dict, mesh) -> dict:
    """Place an EventBatch onto the mesh brick axes (event dim sharded)."""
    sh = batch_sharding(mesh)

    def put(x):
        spec = P(sh.spec[0], *([None] * (x.ndim - 1)))
        return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))

    return jax.tree.map(put, batch)


def gather_store(store: BrickStore, brick_ids: Optional[List[int]] = None):
    """Concatenate bricks (host memory) in id order — for oracles/tests."""
    ids = sorted(brick_ids if brick_ids is not None else store.bricks)
    parts = [store.bricks[i] for i in ids]
    return {k: np.concatenate([p[k] for p in parts], axis=0)
            for k in parts[0]}
