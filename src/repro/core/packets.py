"""PROOF-style adaptive packet scheduler (straggler mitigation).

From the paper's related work: "The master server distributes the event
data packets to every slave server, carefully adjusting the packet size
such that the slower slave servers get smaller data packets than faster
slave servers ... in case a slave failed then remaining slaves can
reprocess its packets."  GEPS lists load balancing toward the best nodes
as future work; we build both mechanisms here:

- packet size proportional to each node's throughput EMA (catalog/GRIS),
- a central work queue: packets leased to nodes, re-queued on failure or
  timeout (work stealing covers stragglers *and* dead nodes).

The same scheduler feeds per-host microbatch sizing in the training data
pipeline (data/pipeline.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.catalog import MetadataCatalog

#: bounded exponent for the geometric stream ramp, so
#: ``ramp_factor ** n`` stays finite on arbitrarily long scans
RAMP_EXP_CAP = 64


def ramp_cap(n_done: int, ramp_start: int, ramp_factor: float) -> float:
    """Stream-aware packet-size cap after ``n_done`` completed packets:
    ``ramp_start * ramp_factor ** n_done`` with the exponent bounded by
    :data:`RAMP_EXP_CAP`.  The ONE place the ramp rule lives — both the
    simulated scheduler (:class:`AdaptivePacketScheduler`) and the SPMD
    backend's chunked scan (``core/backend.py``) size their early
    packets from it, which is what keeps their matched-packetization
    equivalence intact when the ramp is tuned."""
    return ramp_start * ramp_factor ** min(n_done, RAMP_EXP_CAP)


@dataclasses.dataclass
class Packet:
    """A leased unit of work: a contiguous event range of one brick,
    currently assigned to (at most) one node."""
    packet_id: int
    brick_id: int
    start: int         # offset within the brick
    size: int
    lease: Optional[int] = None  # node currently processing it
    attempts: int = 0


class AdaptivePacketScheduler:
    """Central work queue with PROOF-rule packet sizing: slower nodes get
    smaller packets, packets shrink as the queue drains, and failed or
    dead-node packets re-queue at the front for recovery-first service.

    ``ramp_start`` enables the *stream-aware* sizing mode: the first
    packets are capped at ``ramp_start`` events and the cap grows by
    ``ramp_factor`` per completed packet until the PROOF size takes over.
    Streaming delivery wants the first exact prefix on the wire as early
    as possible, which is exactly what PROOF's up-front ~queue/(4·nodes)
    packets pessimize; the ramp keeps time-to-first-partial small while
    converging to adaptive sizing for the bulk of the scan (so the
    makespan cost of streaming stays negligible)."""

    def __init__(self, catalog: MetadataCatalog, *, base_packet: int = 64,
                 min_packet: int = 8, max_packet: int = 1024,
                 max_attempts: int = 5, ramp_start: Optional[int] = None,
                 ramp_factor: float = 2.0):
        if ramp_start is not None and ramp_start <= 0:
            raise ValueError("ramp_start must be positive")
        if ramp_factor <= 1.0:
            raise ValueError("ramp_factor must be > 1")
        self.catalog = catalog
        self.base = base_packet
        self.min = min_packet
        self.max = max_packet
        self.max_attempts = max_attempts
        self.ramp_start = ramp_start
        self.ramp_factor = ramp_factor
        self.queue: deque = deque()   # (brick_id, start, remaining)
        self.inflight: Dict[int, Packet] = {}
        self.done: List[Packet] = []
        self._next_pid = 0

    # ------------------------------------------------------------------ #
    def add_work(self, brick_id: int, n_events: int):
        """Enqueue one brick's events as packetizable work."""
        self.queue.append([brick_id, 0, n_events])

    def packet_size_for(self, node: int) -> int:
        """Slower nodes get smaller packets, and packets shrink as the
        queue drains so no node holds a large tail packet (PROOF rule)."""
        alive = self.catalog.alive_nodes()
        infos = [self.catalog.node(n) for n in alive]
        if not infos:
            return self.base
        mean = sum(i.throughput_ema for i in infos) / len(infos)
        mine = self.catalog.node(node).throughput_ema
        size = int(self.base * (mine / mean if mean > 0 else 1.0))
        remaining = sum(w[2] for w in self.queue)
        drain_cap = max(self.min, remaining // max(1, len(alive)))
        size = max(self.min, min(self.max, size, drain_cap))
        if self.ramp_start is not None:
            # stream-aware ramp: small early packets, growing geometrically
            # with scan progress until PROOF sizing dominates (int() runs
            # only on a value known to be < size)
            cap = ramp_cap(len(self.done), self.ramp_start,
                           self.ramp_factor)
            if cap < size:
                size = max(1, int(cap))
        return size

    def next_packet(self, node: int) -> Optional[Packet]:
        """Lease the next packet to ``node`` (None when queue drained)."""
        if not self.catalog.node(node).alive:
            return None
        if not self.queue:
            return None
        size = self.packet_size_for(node)
        brick_id, start, remaining = self.queue[0]
        take = min(size, remaining)
        pkt = Packet(self._next_pid, brick_id, start, take, lease=node)
        self._next_pid += 1
        if take == remaining:
            self.queue.popleft()
        else:
            self.queue[0][1] += take
            self.queue[0][2] -= take
        self.inflight[pkt.packet_id] = pkt
        return pkt

    def complete(self, packet_id: int, events: int, seconds: float):
        """Acknowledge a finished packet and feed the node's rate EMA."""
        pkt = self.inflight.pop(packet_id)
        self.catalog.node(pkt.lease).observe(events, seconds)
        self.done.append(pkt)

    def fail(self, packet_id: int, *, node_dead: bool = False):
        """Re-queue a failed packet (PROOF reassignment)."""
        pkt = self.inflight.pop(packet_id)
        pkt.attempts += 1
        if node_dead:
            self.catalog.mark_dead(pkt.lease)
        pkt.lease = None
        if pkt.attempts >= self.max_attempts:
            raise RuntimeError(
                f"packet {pkt.packet_id} failed {pkt.attempts} times")
        # re-queue at the FRONT so recovery work finishes first
        self.queue.appendleft([pkt.brick_id, pkt.start, pkt.size])

    def requeue_node(self, node: int):
        """Return all packets leased to a (dead) node to the queue."""
        for pid in [p for p, pkt in self.inflight.items()
                    if pkt.lease == node]:
            self.fail(pid, node_dead=True)

    @property
    def exhausted(self) -> bool:
        """True when no work is queued or in flight (the job swept)."""
        return not self.queue and not self.inflight
