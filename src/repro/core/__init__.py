"""GEPS Grid-Brick core: the paper's primary contribution.

- events / query: the event-processing workload (ROOT-tree role + the
  user-facing filter-expression language)
- brick / catalog / replication: grid-brick storage, metadata catalogue
  (PgSQL role), GRIS/LDAP node info, replica placement
- jse / merge / packets: job submission engine, hierarchical result merge,
  PROOF-style adaptive packets (straggler mitigation)
- backend: the ExecutionBackend contract — SimulatedBackend (virtual-time
  grid) and SpmdBackend (chunked streaming scan over brick shards) behind
  one ``run_batch`` surface
- elastic: node join/leave, re-mesh, migration plans
- brick_attention: the grid-brick principle applied to decode KV caches
"""
from repro.core.backend import (ExecutionBackend,  # noqa: F401
                                SimulatedBackend, SpmdBackend,
                                make_backend)
from repro.core.brick import BrickSpec, BrickStore, create_store  # noqa: F401
from repro.core.catalog import MetadataCatalog  # noqa: F401
from repro.core.jse import (JobSubmissionEngine, TimeModel,  # noqa: F401
                            spmd_query_batch_step, spmd_query_step)
