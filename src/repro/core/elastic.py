"""Elastic scaling: "the scalability of GEPS can be easily obtained through
freely adding into or removing any grid computing and storage node"
(paper section 4).

Host level: node join/leave updates the catalogue, fails bricks over to
replicas, and produces migration / re-replication plans.

SPMD level: ``elastic_mesh_shape`` picks the largest runnable mesh for the
surviving host count; training resumes from the latest checkpoint with
parameters resharded onto the new mesh (checkpoint/ckpt.py restores by
logical path, so any mesh-to-mesh transition works).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.brick import BrickStore
from repro.core.catalog import MetadataCatalog
from repro.core.replication import failover_owner, rereplication_plan


@dataclasses.dataclass
class MigrationPlan:
    """Outcome of a node join/leave: primary reassignments, replica
    copies to schedule, and bricks with no surviving copy."""
    reassign_primary: List[Tuple[int, int, int]]  # (brick, old, new)
    copies: List[Tuple[int, int, int]]            # (brick, src, dst)
    lost_bricks: List[int]


class ElasticManager:
    """Applies node join/leave to the catalogue + brick store and emits
    the :class:`MigrationPlan` a control plane would execute."""

    def __init__(self, catalog: MetadataCatalog, store: BrickStore):
        self.catalog = catalog
        self.store = store

    def node_leave(self, node: int) -> MigrationPlan:
        """Fail ``node``'s bricks over to replicas; plan re-replication."""
        self.catalog.mark_dead(node)
        dead = self.catalog.dead_nodes()
        reassign, lost = [], []
        for bid, spec in sorted(self.store.specs.items()):
            if spec.node in dead:
                new_owner = failover_owner(self.store.owners(bid), dead)
                if new_owner < 0:
                    lost.append(bid)
                else:
                    reassign.append((bid, spec.node, new_owner))
                    spec.node = new_owner
                    spec.replicas = tuple(
                        r for r in spec.replicas if r != new_owner)
        copies = rereplication_plan(self.store.specs, dead,
                                    self.store.n_nodes)
        return MigrationPlan(reassign, copies, lost)

    def node_join(self, node: int) -> MigrationPlan:
        """Re-balance: move bricks from the most-loaded nodes to the joiner."""
        self.catalog.mark_alive(node)
        loads: Dict[int, List[int]] = {}
        for bid, spec in self.store.specs.items():
            loads.setdefault(spec.node, []).append(bid)
        total = len(self.store.specs)
        alive = self.catalog.alive_nodes()
        target = max(1, total // max(1, len(alive)))
        moves = []
        have = len(loads.get(node, []))
        donors = sorted(loads.items(), key=lambda kv: -len(kv[1]))
        for donor, bricks in donors:
            if donor == node:
                continue
            while have < target and len(bricks) > target:
                bid = bricks.pop()
                moves.append((bid, donor, node))
                self.store.specs[bid].node = node
                have += 1
        return MigrationPlan(moves, [], [])

    def apply_copies(self, plan: MigrationPlan):
        """Execute re-replication copies in the host store (restores the
        replication factor after failures)."""
        for bid, src, dst in plan.copies:
            spec = self.store.specs[bid]
            if dst not in spec.replicas and dst != spec.node:
                spec.replicas = spec.replicas + (dst,)


# --------------------------------------------------------------------------- #
def elastic_mesh_shape(n_hosts_alive: int, *, model_parallel: int = 16,
                       pods: int = 1) -> Optional[Tuple[int, ...]]:
    """Largest (data, model) mesh runnable on the surviving chips: keep TP
    fixed (model weights layout unchanged), shrink the data/brick axis to
    the largest power of two that fits.  Returns None if nothing fits."""
    chips = n_hosts_alive
    data = chips // (model_parallel * pods)
    if data < 1:
        return None
    # largest power of two <= data keeps batch divisibility simple
    p = 1
    while p * 2 <= data:
        p *= 2
    return (pods, p, model_parallel) if pods > 1 else (p, model_parallel)
