"""Execution backends: ONE contract for "execute a dispatch window".

The paper's JSE is a single contract — distribute a query over
brick-resident data, merge partials at the submit server — but the repo
grew two divergent realizations of it: the virtual-time simulation
(fragment plans, streaming ``on_partial``, failure scripts, per-packet
telemetry) and the SPMD lockstep step (none of these, merge only at step
end).  This module collapses the divergence behind one interface so every
service/fabric feature (streaming, cache write-through, cost-model
calibration, window planning) works identically on both paths:

- :class:`ExecutionBackend` — the protocol:
  ``run_batch(job_ids, *, plan, on_partial, failure_script, packet_ramp)
  -> (results, JobStats)``.  Exactly the surface
  ``JobSubmissionEngine.run_job_batch_simulated`` already exposes, now
  named and substitutable.
- :class:`SimulatedBackend` — thin wrapper over the event-driven
  virtual-time grid simulation (``core/jse.py``).  Time is virtual, the
  per-packet compute is real.
- :class:`SpmdBackend` — the mesh-shard realization as a **chunked
  streaming scan**: each brick (= shard that never moves) is swept in
  chunks, every chunk evaluated through the same
  :func:`~repro.core.jse.eval_plan_slice` primitive as the simulation,
  and a :class:`~repro.core.jse.PacketPartial` emitted per chunk in
  deterministic merge order (brick id ascending, offset ascending) — so
  prefix snapshots fed to a :class:`~repro.core.merge.MergeAccumulator`
  are bit-identical to ``tree_merge`` of the same prefix, and a window
  executed with the same chunk boundaries on either backend produces
  bit-identical partial streams and final results.
- :class:`ChunkController` — EWMA sizing for ``chunk_events`` from
  measured per-chunk wall times (the PROOF-rule shape
  ``WindowController`` uses for window widths, applied to chunks).
- :class:`PlanSplit` — the mixed-window kernel/jnp split: plan targets
  inside the fused ``event_filter`` kernel's conjunctive family run as
  one kernel sub-batch per chunk, the rest through the jnp fragment
  walk, reassembled in slot order so prefixes stay bit-identical.
- :func:`make_backend` — string-keyed factory (``"sim"`` / ``"spmd"``)
  the service layer and ``launch/serve.py --backend`` use.

See ``docs/backends.md`` for the full contract (merge-order determinism,
clock semantics, failure semantics, Pallas fragment fusion, and the
performance-tuning knobs: block-shape autotune, adaptive chunk sizing,
mesh sharding, interpret auto-detect, double buffering).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Protocol, Tuple, \
    runtime_checkable

import numpy as np

from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.brick import BrickStore
from repro.core.catalog import DONE, MetadataCatalog
from repro.core.jse import (JobStats, JobSubmissionEngine, PacketPartial,
                            PacketTelemetry, TimeModel, eval_plan_slice,
                            prepare_window)
from repro.core.packets import ramp_cap


@runtime_checkable
class ExecutionBackend(Protocol):
    """The one contract the service layer executes dispatch windows
    against.  Implementations own a catalogue + brick store pair and
    TWO mutable attributes the service relies on: ``cost_weights`` (the
    service installs fitted :class:`~repro.service.planner.CostWeights`
    there so the scheduler can bound windows by calibrated cost) and
    ``supports_failure_injection`` (checked BEFORE a window is dequeued;
    a backend that omits it is treated as not supporting failure
    scripts — the safe direction, since an error raised mid-dispatch
    would strand the window's tickets and streams)."""

    catalog: MetadataCatalog
    store: BrickStore
    cost_weights: Optional[object]
    supports_failure_injection: bool

    def run_batch(self, job_ids: List[int], *,
                  plan: Optional[query_lib.FragmentPlan] = None,
                  on_partial: Optional[
                      Callable[[PacketPartial], None]] = None,
                  failure_script: Optional[Dict[float, int]] = None,
                  packet_ramp: Optional[int] = None
                  ) -> Tuple[List[merge_lib.QueryResult], JobStats]:
        """Execute one shared-scan window of catalogued jobs.

        Contract (both backends): jobs must share bricks/calib_iters;
        ``plan`` (a fragment plan whose roots align with ``job_ids``) is
        built when absent; ``on_partial`` is invoked once per evaluated
        packet/chunk, in the exact merge order, with partials whose
        prefix merges are bit-identical to ``tree_merge`` of that
        prefix; ``packet_ramp`` caps early packet sizes for streaming;
        job statuses move RUNNING -> DONE (or FAILED) in the catalogue;
        returns ``(merged, stats)`` with materialized-fragment results
        in ``stats.fragment_results`` and per-packet compute telemetry
        in ``stats.packet_telemetry``."""
        ...


class SimulatedBackend:
    """The event-driven virtual-time grid simulation behind the
    :class:`ExecutionBackend` contract.

    A thin wrapper over :class:`~repro.core.jse.JobSubmissionEngine`
    (exposed as :attr:`engine` for callers tuning simulation knobs such
    as ``adaptive_packets`` or node speeds): scheduling, straggler
    mitigation, failure injection and virtual makespans are all the
    engine's — this class only pins the contract surface."""

    def __init__(self, catalog: MetadataCatalog, store: BrickStore, *,
                 time_model: Optional[TimeModel] = None,
                 node_speed: Optional[Dict[int, float]] = None,
                 adaptive_packets: bool = True,
                 packet_ramp: Optional[int] = None,
                 ramp_factor: float = 2.0):
        self.engine = JobSubmissionEngine(
            catalog, store, time_model=time_model, node_speed=node_speed,
            adaptive_packets=adaptive_packets, packet_ramp=packet_ramp,
            ramp_factor=ramp_factor)
        self.catalog = catalog
        self.store = store
        # fitted cost weights the service installs after telemetry refits
        # (consumed by QueryScheduler window-cost bounding)
        self.cost_weights = None
        #: the virtual grid can kill nodes mid-scan; the service checks
        #: this BEFORE dequeuing a window so an unsupported failure
        #: script fails fast with no state mutated
        self.supports_failure_injection = True
        #: the virtual grid routes packets per node, so the failure
        #: policy's avoid/probe/speculate decision applies here; the
        #: service checks this before passing routing kwargs
        self.supports_routing_policy = True

    @property
    def obs(self):
        """Observability plane handle — stored on the wrapped engine (the
        simulation loop is where packets are scanned), surfaced here so
        the service can install/inspect it backend-agnostically."""
        return self.engine.obs

    @obs.setter
    def obs(self, value):
        """Install the plane on the wrapped engine."""
        self.engine.obs = value

    def submit(self, expr: str, calib_iters: int = 0) -> int:
        """Register a job over every brick in the store (engine passthrough)."""
        return self.engine.submit(expr, calib_iters)

    def run_batch(self, job_ids: List[int], *,
                  plan: Optional[query_lib.FragmentPlan] = None,
                  on_partial: Optional[
                      Callable[[PacketPartial], None]] = None,
                  failure_script: Optional[Dict[float, int]] = None,
                  packet_ramp: Optional[int] = None,
                  route_avoid: Optional[set] = None,
                  probe_quota: Optional[Dict[int, int]] = None,
                  speculate: bool = False,
                  spec_lead_factor: float = 1.5,
                  rereplicated: Optional[List[Tuple[int, int, int]]] = None
                  ) -> Tuple[List[merge_lib.QueryResult], JobStats]:
        """Execute the window on the simulated grid (see
        :meth:`ExecutionBackend.run_batch` for the contract; the routing
        kwargs carry a :class:`~repro.service.policy.PolicyDecision` —
        see ``run_job_batch_simulated`` for their semantics, including
        the ``rereplicated`` brick-copy transfer charge)."""
        return self.engine.run_job_batch_simulated(
            job_ids, plan=plan, on_partial=on_partial,
            failure_script=failure_script, packet_ramp=packet_ramp,
            route_avoid=route_avoid, probe_quota=probe_quota,
            speculate=speculate, spec_lead_factor=spec_lead_factor,
            rereplicated=rereplicated)


class ChunkController:
    """EWMA controller for the SPMD scan's ``chunk_events``.

    The streaming sweet spot for chunk sizing mirrors the PROOF packet
    rule the :class:`~repro.service.frontend.WindowController` applies to
    window widths: a chunk should take about ``target_s`` seconds of
    scan, so the proposal is ``clamp(round(rate * target_s), min_chunk,
    max_chunk)`` where ``rate`` is an EWMA of measured events/second
    over completed chunks.  Chunks too small drown the scan in per-chunk
    dispatch/merge overhead; chunks too large starve the partial stream
    (time-to-first-partial grows linearly in chunk size).

    ``hysteresis`` is the same relative dead-band as the window
    controller's: the held size only moves when the proposal differs
    from it by more than ``hysteresis x current``, so a noisy rate
    estimate doesn't re-chunk every packet (chunk-size churn also churns
    kernel compilation caches, which are keyed on chunk shape).

    Determinism: the controller is a pure function of the observation
    sequence — drive it from an injectable clock
    (``SpmdBackend(clock=...)``) and a fixed seed reproduces the exact
    chunk boundaries, which is what keeps flight logs byte-identical
    under adaptive sizing (see ``tests/test_backend.py``)."""

    def __init__(self, *, initial: int = 64, min_chunk: int = 8,
                 max_chunk: int = 4096, target_s: float = 0.02,
                 alpha: float = 0.3, hysteresis: float = 0.25):
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        if not (1 <= min_chunk <= max_chunk):
            raise ValueError("need 1 <= min_chunk <= max_chunk")
        if target_s <= 0:
            raise ValueError("target_s must be positive")
        if hysteresis < 0.0:
            raise ValueError("hysteresis must be >= 0")
        self.initial = initial
        self.min_chunk = min_chunk
        self.max_chunk = max_chunk
        self.target_s = target_s
        self.alpha = alpha
        self.hysteresis = hysteresis
        self._rate: Optional[float] = None
        self._held: Optional[int] = None

    def observe(self, events: int, wall_s: float) -> None:
        """Record one completed chunk: ``events`` swept in ``wall_s``
        seconds (host-observed, same clock as the backend's)."""
        if events <= 0 or wall_s <= 0:
            return
        rate = events / wall_s
        self._rate = rate if self._rate is None else (
            self.alpha * rate + (1 - self.alpha) * self._rate)

    @property
    def scan_rate(self) -> Optional[float]:
        """Smoothed events/second, or None before the first chunk."""
        return self._rate

    def chunk(self) -> int:
        """Chunk size for the next dispatch: the clamped ``rate *
        target_s`` proposal, filtered through the hysteresis dead-band."""
        if self._rate is None:
            target = max(self.min_chunk,
                         min(self.max_chunk, self.initial))
        else:
            target = max(self.min_chunk,
                         min(self.max_chunk,
                             int(round(self._rate * self.target_s))))
        if self._held is None or \
                abs(target - self._held) > self.hysteresis * self._held:
            self._held = target
        return self._held


@dataclasses.dataclass(frozen=True)
class PlanSplit:
    """The mixed-window kernel/jnp split of one fragment plan's targets.

    ``kernel_cols`` are the target slots (roots-then-materialized order,
    exactly :meth:`~repro.core.query.FragmentPlan.targets` order) whose
    expressions matched the fused ``event_filter`` kernel's conjunctive
    family (``match_epilogue``); they run as ONE kernel sub-batch per
    chunk with ``thresholds`` (the ``(4, K_kernel)`` layout of
    ``batch_kernel_params``) and ``var_idx``.  ``jnp_cols`` hold the
    out-of-family targets (``jnp_targets`` the matching AST nodes),
    evaluated through the same shared-memo jnp walk the plan itself
    uses.  Per chunk the two sub-batches are reassembled in the original
    slot order, so partial streams and prefixes stay bit-identical to
    the pure-jnp path regardless of how the split falls."""

    kernel_cols: Tuple[int, ...]
    jnp_cols: Tuple[int, ...]
    thresholds: Optional[object]        # jnp (4, len(kernel_cols)) or None
    var_idx: Tuple[int, ...]
    jnp_targets: Tuple[object, ...]     # AST nodes, aligned with jnp_cols

    @property
    def any_kernel(self) -> bool:
        """True when at least one target runs through the kernel."""
        return bool(self.kernel_cols)

    @property
    def full_kernel(self) -> bool:
        """True when EVERY target runs through the kernel (the
        all-in-family case the pre-split fusion hook required)."""
        return bool(self.kernel_cols) and not self.jnp_cols


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-unfinalized chunk: lazy device values plus the
    slot bookkeeping needed to emit its partial in order."""
    seq: int
    brick_id: int
    start: int
    size: int
    owner: int
    span: object = None
    # "plan" chunks are fully evaluated at dispatch (the eval_plan_slice
    # primitive materializes internally); "split" chunks hold lazy
    # kernel/jnp device arrays finalized later.
    res: Optional[List[merge_lib.QueryResult]] = None
    mask_dev: object = None             # (size, K_kernel) device array
    var_dev: object = None              # (size,) device array
    jnp_masks: Optional[list] = None    # lazy (size,) arrays, jnp_cols order
    ids: Optional[np.ndarray] = None


@functools.lru_cache(maxsize=64)
def _sharded_kernel_call(n_dev: int, var_idx: Tuple[int, ...],
                         calib_iters: int, interpret: Optional[bool],
                         block_e: int, block_t: int):
    """Build (and cache) the jitted ``shard_map`` kernel call for a
    ``(1, "scan")`` device mesh: the stacked ``(D, n, ...)`` chunk slabs
    are sharded over the leading axis (each device owns one sub-chunk —
    the logical sharding constraint), thresholds replicated, outputs
    sharded back.  Reuses the exact version-compat idiom proven in
    ``core/brick_attention.py``."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P

    try:  # jax >= 0.5 exposes shard_map at top level
        _shard_map = jax.shard_map
        _sm_nocheck = {"check_vma": False}
    except AttributeError:  # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map as _shard_map
        _sm_nocheck = {"check_rep": False}

    from repro.kernels.event_filter.kernel import event_filter_batch_pallas

    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("scan",))

    def body(sc, tr, ntr, thr):
        # per-device view: leading axis is this shard's single sub-chunk
        mask, var = event_filter_batch_pallas(
            sc[0], tr[0], ntr[0], thr, var_idx=var_idx,
            calib_iters=calib_iters, interpret=interpret,
            block_e=block_e, block_t=block_t)
        return mask[None], var[None]

    fn = _shard_map(body, mesh=mesh,
                    in_specs=(P("scan"), P("scan"), P("scan"), P(None, None)),
                    out_specs=(P("scan"), P("scan")), **_sm_nocheck)
    return jax.jit(fn)


class SpmdBackend:
    """The SPMD realization of the contract: a chunked streaming scan
    over the brick shards.

    Bricks play the role of mesh shards (data that never moves); the
    scan visits them in brick-id order and sweeps each in chunks of
    ``chunk_events``.  Every chunk runs the SAME fragment-factored
    evaluation primitive as the simulation
    (:func:`~repro.core.jse.eval_plan_slice`), so unique fragments are
    evaluated once per chunk and a chunk's partials are bit-identical to
    the simulated backend's partials for the same slice.  Per-chunk
    :class:`~repro.core.jse.PacketPartial`\\ s stream out through
    ``on_partial`` in deterministic merge order, which is what makes
    prefix snapshots (via :class:`~repro.core.merge.MergeAccumulator`)
    bit-identical to ``tree_merge`` of the same prefix — the streaming
    guarantee the simulated path already had, now on the SPMD path.

    Differences from the simulation, by design:

    - **Clock**: ``t_virtual`` on emitted partials and
      ``JobStats.makespan_s`` are seconds on the backend's injectable
      ``clock`` (wall by default) since the window started.  With
      ``mesh_devices > 1`` on fewer physical devices, the stamps switch
      to the **lockstep mesh clock**: chunks are grouped ``mesh_devices``
      at a time, each group's cost is the *maximum* of its measured
      sub-chunk walls (all shards execute a group simultaneously on a
      real mesh), and stamps/makespan accumulate those group maxima —
      the critical-path time a D-device lockstep mesh would take for the
      measured per-shard compute.  With enough physical jax devices the
      group actually executes as one ``shard_map`` call and the clock is
      plain wall again.
    - **Failures**: shards are resident compute state, not remote disks;
      ``failure_script`` is a simulated-grid concept and a non-empty one
      raises ``ValueError`` rather than being silently ignored.
    - **Pallas fusion** (``use_pallas=True``): every plan target —
      per-query roots AND materialized boolean fragments — that matches
      the fused ``event_filter`` kernel's conjunctive family runs in the
      kernel epilogue in one track-streaming pass per chunk; the rest
      run through the jnp fragment walk on the same resident slice and
      the two sub-batches are reassembled in slot order
      (:class:`PlanSplit`), so a single out-of-family target no longer
      drops the whole window to pure jnp.  ``interpret=None``
      auto-detects (compiled on TPU/GPU, interpreter on CPU);
      ``autotune=True`` sweeps ``(block_e, block_t)`` per chunk shape
      and caches the winner in-process
      (``repro.kernels.event_filter.tune``).  Either way the per-chunk
      telemetry (``PacketTelemetry``) is recorded, so
      ``planner.fit_cost_weights`` calibrates from SPMD runs too.
    - **Double buffering** (``double_buffer=True``, the default): chunk
      ``i+1`` is dispatched before chunk ``i`` is finalized, so host-side
      ``MergeAccumulator`` prefix merging and partial emission overlap
      the device compute of the next chunk.  Merge order is unchanged
      (finalize strictly follows dispatch order).  Disabled automatically
      in emulated-mesh mode, where per-sub-chunk walls must be measured
      in isolation for the lockstep clock to be honest.
    - **Adaptive chunks** (``adaptive_chunks=True``): ``chunk_events``
      becomes the :class:`ChunkController`'s initial value and
      subsequent chunks are sized from measured per-chunk walls toward
      ``chunk_target_s`` seconds each.  Off by default — fixed chunks
      are what make matched-packetization bit-identity tests possible.
    """

    def __init__(self, catalog: MetadataCatalog, store: BrickStore, *,
                 chunk_events: int = 64, packet_ramp: Optional[int] = None,
                 ramp_factor: float = 2.0, use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 autotune: bool = False,
                 mesh_devices: int = 1,
                 adaptive_chunks: bool = False,
                 chunk_target_s: float = 0.02,
                 double_buffer: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        if chunk_events <= 0:
            raise ValueError("chunk_events must be positive")
        if packet_ramp is not None and packet_ramp <= 0:
            raise ValueError("packet_ramp must be positive")
        if ramp_factor <= 1.0:
            raise ValueError("ramp_factor must be > 1")
        if mesh_devices < 1:
            raise ValueError("mesh_devices must be >= 1")
        self.catalog = catalog
        self.store = store
        self.chunk_events = chunk_events
        self.packet_ramp = packet_ramp
        self.ramp_factor = ramp_factor
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.autotune = autotune
        self.mesh_devices = mesh_devices
        self.adaptive_chunks = adaptive_chunks
        self.chunk_target_s = chunk_target_s
        self.double_buffer = double_buffer
        self.clock = clock
        self.cost_weights = None  # installed by the service after refits
        #: shards are resident compute state, not killable virtual nodes
        self.supports_failure_injection = False
        #: no per-node routing either — chunks visit shards in place, so
        #: policy decisions (avoid/probe/speculate) don't apply here
        self.supports_routing_policy = False
        # observability plane (repro.obs.Observability); None = disabled
        self.obs = None
        #: most recent autotune verdict (TunedShape) — bench reporting
        self.last_autotune = None
        # resolved lazily on first run (jax import deferred until needed)
        self._mesh_real: Optional[bool] = None

    # ------------------------------------------------------------------ #
    def _chunk_size(self, seq: int, remaining: int, ramp: Optional[int],
                    controller: Optional[ChunkController]) -> int:
        """Size of chunk ``seq``: the configured chunk (or the adaptive
        controller's proposal), capped early by the shared geometric
        stream ramp (``core/packets.py``), clipped to the shard
        remainder."""
        size = controller.chunk() if controller is not None \
            else self.chunk_events
        if ramp is not None:
            cap = ramp_cap(seq, ramp, self.ramp_factor)
            if cap < size:
                size = max(1, int(cap))
        return min(size, remaining)

    def _split_plan(self, plan: query_lib.FragmentPlan) -> PlanSplit:
        """Partition the plan's targets into the kernel sub-batch
        (targets inside the fused kernel's conjunctive family) and the
        jnp sub-batch (everything else) — see :class:`PlanSplit`.  With
        ``use_pallas=False`` every target lands in the jnp sub-batch."""
        targets = plan.targets()
        if not self.use_pallas:
            return PlanSplit(kernel_cols=(), jnp_cols=tuple(
                range(len(targets))), thresholds=None, var_idx=(),
                jnp_targets=tuple(targets))
        from repro.kernels.event_filter import ops as ef_ops
        params = [ef_ops.match_epilogue(t, self.store.schema)
                  for t in targets]
        kcols = tuple(i for i, p in enumerate(params) if p is not None)
        jcols = tuple(i for i, p in enumerate(params) if p is None)
        thresholds, var_idx = (None, ())
        if kcols:
            thresholds, var_idx = ef_ops.batch_kernel_params(
                [params[i] for i in kcols])
        return PlanSplit(kernel_cols=kcols, jnp_cols=jcols,
                         thresholds=thresholds, var_idx=var_idx,
                         jnp_targets=tuple(targets[i] for i in jcols))

    def _fuse_plan(self, plan: query_lib.FragmentPlan):
        """Back-compat fusion hook: the batched kernel params when EVERY
        plan target is in-family, else None.  Mixed windows no longer
        fall back wholesale — see :meth:`_split_plan` — but this remains
        the cheap "fully fused?" probe tests and tools use."""
        split = self._split_plan(plan)
        return (split.thresholds, split.var_idx) if split.full_kernel \
            else None

    # ------------------------------------------------------------------ #
    def _mesh_is_real(self) -> bool:
        """True when jax actually has ``mesh_devices`` devices (the
        ``shard_map`` fast path); False emulates the mesh with lockstep
        critical-path accounting.  Resolved once — jax pins its device
        count at first init."""
        if self._mesh_real is None:
            if self.mesh_devices <= 1:
                self._mesh_real = False
            else:
                import jax
                self._mesh_real = len(jax.devices()) >= self.mesh_devices
        return self._mesh_real

    def _maybe_autotune(self, split: PlanSplit, brick_id: int,
                        calib_iters: int) -> Tuple[int, int]:
        """Resolve the kernel block shapes for this window: the in-process
        autotune winner for the (chunk shape x K x calib) class when
        ``autotune=True``, the fixed default otherwise."""
        from repro.kernels.event_filter import tune as ef_tune
        if not (self.autotune and split.any_kernel):
            return ef_tune.DEFAULT_SHAPE
        batch = self.store.bricks[brick_id]
        n = min(self.chunk_events, batch["scalars"].shape[0])
        import jax.numpy as jnp
        tuned = ef_tune.autotune_block_shapes(
            jnp.asarray(batch["scalars"][:n]),
            jnp.asarray(batch["tracks"][:n]),
            jnp.asarray(batch["n_tracks"][:n]),
            split.thresholds, var_idx=split.var_idx,
            calib_iters=calib_iters, interpret=self.interpret)
        self.last_autotune = tuned
        if self.obs is not None:
            self.obs.metrics.gauge("spmd.autotune.block_e").set(
                tuned.block_e)
            self.obs.metrics.gauge("spmd.autotune.block_t").set(
                tuned.block_t)
        return tuned.block_e, tuned.block_t

    # ------------------------------------------------------------------ #
    def _dispatch_chunk(self, plan: query_lib.FragmentPlan,
                        split: PlanSplit, seq: int, brick_id: int,
                        start: int, size: int, owner: int,
                        calib_iters: int,
                        block_shapes: Tuple[int, int]) -> _Inflight:
        """Dispatch one chunk: kernel sub-batch + jnp sub-batch launched
        asynchronously (device values stay lazy), or — for windows with
        no kernel targets — the shared ``eval_plan_slice`` primitive
        evaluated in place."""
        infl = _Inflight(seq=seq, brick_id=brick_id, start=start,
                         size=size, owner=owner)
        if not split.any_kernel:
            infl.res = eval_plan_slice(self.store, plan, brick_id, start,
                                       size, calib_iters)
            return infl
        import jax.numpy as jnp
        from repro.kernels.event_filter import ops as ef_ops
        batch = self.store.bricks[brick_id]
        sl = {k: v[start:start + size] for k, v in batch.items()}
        infl.ids = np.asarray(sl["event_id"])
        be, bt = block_shapes
        infl.mask_dev, infl.var_dev = ef_ops.event_filter_batch(
            jnp.asarray(sl["scalars"]), jnp.asarray(sl["tracks"]),
            jnp.asarray(sl["n_tracks"]), split.thresholds,
            var_idx=split.var_idx, calib_iters=calib_iters,
            interpret=self.interpret, block_e=be, block_t=bt)
        if split.jnp_cols:
            # out-of-family targets: the same shared-memo jnp walk the
            # plan runs, restricted to the jnp sub-batch (values are
            # memo-independent, so restricting the memo cannot change
            # bits — only sharing)
            slj = {k: jnp.asarray(v) for k, v in sl.items()}
            if calib_iters:
                slj = dict(slj, tracks=query_lib.calibrate(slj,
                                                           calib_iters))
            memo: Optional[dict] = {} if plan.shared else None
            infl.jnp_masks = [
                query_lib.eval_node(t, slj, self.store.schema, False, memo)
                for t in split.jnp_targets]
        return infl

    def _dispatch_group(self, plan: query_lib.FragmentPlan,
                        split: PlanSplit,
                        slots: List[Tuple[int, int, int]], brick_id: int,
                        owner: int, calib_iters: int,
                        block_shapes: Tuple[int, int]) -> List[_Inflight]:
        """Dispatch one mesh group — up to ``mesh_devices`` chunk slots
        of one brick — as a single ``shard_map`` kernel call over the
        stacked, zero-padded ``(D, n_max, ...)`` slabs (each device owns
        one sub-chunk).  Partials are still sliced back out per slot, so
        packetization — and therefore prefix bit-identity — is unchanged
        by the group width.  jnp sub-batch targets (mixed windows) run
        per slot on the host path as usual."""
        import jax.numpy as jnp
        from repro.kernels import resolve_interpret
        batch = self.store.bricks[brick_id]
        n_max = max(size for _, _, size in slots)
        d = self.mesh_devices

        def slab(key, start, size):
            a = np.asarray(batch[key][start:start + size])
            if size < n_max:
                pad = [(0, n_max - size)] + [(0, 0)] * (a.ndim - 1)
                a = np.pad(a, pad)
            return a

        def stacked(key):
            rows = [slab(key, start, size) for _, start, size in slots]
            while len(rows) < d:    # tail group: replicate a dummy slab
                rows.append(np.zeros_like(rows[0]))
            return jnp.asarray(np.stack(rows))

        be, bt = block_shapes
        fn = _sharded_kernel_call(d, split.var_idx, calib_iters,
                                  resolve_interpret(self.interpret),
                                  be, bt)
        gmask, gvar = fn(stacked("scalars"), stacked("tracks"),
                         stacked("n_tracks"), split.thresholds)
        out: List[_Inflight] = []
        for i, (seq, start, size) in enumerate(slots):
            infl = _Inflight(seq=seq, brick_id=brick_id, start=start,
                             size=size, owner=owner)
            infl.ids = np.asarray(batch["event_id"][start:start + size])
            infl.mask_dev = gmask[i, :size]
            infl.var_dev = gvar[i, :size]
            if split.jnp_cols:
                sl = {k: v[start:start + size] for k, v in batch.items()}
                slj = {k: jnp.asarray(v) for k, v in sl.items()}
                if calib_iters:
                    slj = dict(slj, tracks=query_lib.calibrate(
                        slj, calib_iters))
                memo: Optional[dict] = {} if plan.shared else None
                infl.jnp_masks = [
                    query_lib.eval_node(t, slj, self.store.schema, False,
                                        memo)
                    for t in split.jnp_targets]
            out.append(infl)
        return out

    def _finalize_chunk(self, infl: _Inflight,
                        split: PlanSplit) -> List[merge_lib.QueryResult]:
        """Force one dispatched chunk and reassemble its partials in the
        plan's slot order (kernel and jnp sub-batches interleaved back to
        their original target slots)."""
        if infl.res is not None:
            return infl.res
        mask = np.asarray(infl.mask_dev)   # (size, K_kernel)
        var = np.asarray(infl.var_dev)
        n_targets = len(split.kernel_cols) + len(split.jnp_cols)
        out: List[Optional[merge_lib.QueryResult]] = [None] * n_targets
        for j, col in enumerate(split.kernel_cols):
            out[col] = merge_lib.from_mask(mask[:, j], var, infl.ids)
        if infl.jnp_masks is not None:
            for j, col in enumerate(split.jnp_cols):
                out[col] = merge_lib.from_mask(
                    np.asarray(infl.jnp_masks[j]), var, infl.ids)
        infl.res = out
        return out

    # ------------------------------------------------------------------ #
    def run_batch(self, job_ids: List[int], *,
                  plan: Optional[query_lib.FragmentPlan] = None,
                  on_partial: Optional[
                      Callable[[PacketPartial], None]] = None,
                  failure_script: Optional[Dict[float, int]] = None,
                  packet_ramp: Optional[int] = None
                  ) -> Tuple[List[merge_lib.QueryResult], JobStats]:
        """Execute the window as a chunked streaming scan over the brick
        shards (see the class docstring and
        :meth:`ExecutionBackend.run_batch` for the contract)."""
        if failure_script:
            raise ValueError(
                "failure_script is a simulated-grid concept; the SPMD "
                "backend has no virtual nodes to kill (use "
                "SimulatedBackend for failure experiments)")
        rec, plan = prepare_window(self.catalog, job_ids, plan)

        obs = self.obs
        clock = self.clock
        stats = JobStats(n_queries=len(job_ids))
        plan_aggs = query_lib.unique_aggregates(plan.targets())
        split = self._split_plan(plan)
        ramp = packet_ramp if packet_ramp is not None else self.packet_ramp
        controller = (ChunkController(initial=self.chunk_events,
                                      target_s=self.chunk_target_s)
                      if self.adaptive_chunks else None)
        bricks = sorted(rec.bricks)
        block_shapes = (self._maybe_autotune(split, bricks[0],
                                             rec.calib_iters)
                        if bricks and self.use_pallas
                        else (128, 512))
        mesh = max(1, self.mesh_devices)
        lockstep = mesh > 1 and not self._mesh_is_real()
        # with enough physical devices AND kernel targets, whole groups
        # execute as one shard_map call; otherwise (pure-jnp window on a
        # real mesh) the scan degrades to the sequential stream path
        mesh_fast = mesh > 1 and not lockstep and split.any_kernel
        # double buffering applies only where dispatch is actually lazy
        # (kernel sub-batches): a pure-jnp chunk evaluates eagerly at
        # dispatch, so holding it back would just delay its partial by a
        # whole chunk; and lockstep emulation needs isolated walls
        buffered = (self.double_buffer and split.any_kernel
                    and not lockstep and not mesh_fast)

        if obs is not None:
            obs.metrics.gauge("spmd.mesh_devices").set(mesh)

        results: List[List[merge_lib.QueryResult]] = []
        t_start = clock()
        t_lockstep = 0.0    # critical-path seconds (emulated mesh clock)
        t_prev = t_start    # previous finalize completion (chunk walls)
        group_walls: List[float] = []

        def stamp() -> float:
            return t_lockstep if lockstep else clock() - t_start

        def emit(infl: _Inflight, wall: float) -> None:
            """Record one finalized chunk: telemetry, obs, stats, and the
            in-order partial emission."""
            res = infl.res
            stats.packet_telemetry.append(PacketTelemetry(
                size=infl.size, calib_iters=rec.calib_iters,
                n_aggregates=plan_aggs, wall_s=wall,
                n_targets=len(plan.targets()), node=infl.owner))
            if obs is not None:
                if infl.span is not None:
                    obs.tracer.end(
                        infl.span,
                        t_virtual=obs.tracer.virtual_base + stamp())
                obs.metrics.counter("packet.count").inc()
                obs.metrics.histogram("packet.latency_s").observe(wall)
                obs.metrics.histogram("packet.events").observe(infl.size)
                obs.metrics.gauge("spmd.chunk_events").set(infl.size)
                if split.any_kernel:
                    obs.metrics.counter("spmd.kernel_events").inc(
                        infl.size)
                obs.health.observe_packet(infl.owner, infl.size, wall)
            results.append(res)
            stats.events_scanned += infl.size
            if split.any_kernel:
                stats.kernel_events += infl.size
            stats.fragment_evals += plan.evals_per_batch
            stats.fragment_evals_unshared += plan.unshared_evals
            stats.packets += 1
            stats.per_node_busy[infl.owner] = \
                stats.per_node_busy.get(infl.owner, 0.0) + wall
            if controller is not None:
                controller.observe(infl.size, wall)
            if on_partial is not None:
                on_partial(PacketPartial(
                    seq=infl.seq, brick_id=infl.brick_id, start=infl.start,
                    size=infl.size, node=infl.owner, t_virtual=stamp(),
                    failures=0, partials=res))

        pending: Optional[_Inflight] = None

        def finalize(infl: _Inflight) -> None:
            nonlocal t_prev
            self._finalize_chunk(infl, split)
            now = clock()
            emit(infl, max(now - t_prev, 1e-9))
            t_prev = now

        seq = 0
        for bid in bricks:
            n = self.store.specs[bid].n_events
            owner = self.store.specs[bid].node
            start = 0
            while start < n:
                if lockstep:
                    # one lockstep group: up to `mesh` sub-chunks of this
                    # brick, each measured in isolation; the group costs
                    # the MAX of its walls on the mesh clock
                    group: List[_Inflight] = []
                    group_walls.clear()
                    while len(group) < mesh and start < n:
                        size = self._chunk_size(seq, n - start, ramp,
                                                controller)
                        t0 = clock()
                        infl = self._dispatch_chunk(
                            plan, split, seq, bid, start, size, owner,
                            rec.calib_iters, block_shapes)
                        self._finalize_chunk(infl, split)
                        group_walls.append(max(clock() - t0, 1e-9))
                        group.append(infl)
                        seq += 1
                        start += size
                    t_lockstep += max(group_walls)
                    for infl, wall in zip(group, group_walls):
                        emit(infl, wall)
                    continue
                if mesh_fast:
                    # one shard_map call per group of up to `mesh` slots;
                    # partials still per slot, in order
                    slots: List[Tuple[int, int, int]] = []
                    while len(slots) < mesh and start < n:
                        size = self._chunk_size(seq, n - start, ramp,
                                                controller)
                        slots.append((seq, start, size))
                        seq += 1
                        start += size
                    t0 = clock()
                    infls = self._dispatch_group(plan, split, slots, bid,
                                                 owner, rec.calib_iters,
                                                 block_shapes)
                    for infl in infls:
                        self._finalize_chunk(infl, split)
                    per = max(clock() - t0, 1e-9) / len(slots)
                    for infl in infls:
                        emit(infl, per)
                    continue
                size = self._chunk_size(seq, n - start, ramp, controller)
                span = None
                if obs is not None:
                    span = obs.tracer.begin(
                        "packet",
                        t_virtual=obs.tracer.virtual_base + stamp(),
                        seq=seq, brick=bid, start=start, size=size,
                        node=owner)
                infl = self._dispatch_chunk(plan, split, seq, bid, start,
                                            size, owner, rec.calib_iters,
                                            block_shapes)
                infl.span = span
                if not buffered:
                    finalize(infl)
                else:
                    if pending is not None:
                        # chunk i finalizes (host merge + stream emit)
                        # while chunk i+1's device compute is in flight
                        finalize(pending)
                    pending = infl
                seq += 1
                start += size
        if pending is not None:
            finalize(pending)

        k = len(job_ids)
        merged = (merge_lib.merge_batch(results) if results
                  else [merge_lib.QueryResult()
                        for _ in range(len(plan.targets()))])
        stats.fragment_results = dict(
            zip(plan.materialize_keys(), merged[k:]))
        merged = merged[:k]
        stats.makespan_s = t_lockstep if lockstep \
            else clock() - t_start

        end = time.time()
        for jid, m in zip(job_ids, merged):
            self.catalog.update(
                jid, status=DONE, end_time=end,
                events_processed=m.n_processed, failures=0,
                result={
                    "n_selected": m.n_selected,
                    "n_processed": m.n_processed,
                    "sum_var": m.sum_var,
                    "makespan_s": stats.makespan_s,
                })
        return merged, stats


BACKENDS = ("sim", "spmd")


def make_backend(kind: str, catalog: MetadataCatalog, store: BrickStore,
                 **kwargs) -> ExecutionBackend:
    """Build an execution backend by name over a catalogue/store pair.

    ``kind`` is ``"sim"`` (:class:`SimulatedBackend`) or ``"spmd"``
    (:class:`SpmdBackend`); ``kwargs`` pass through to the chosen
    backend's constructor — unknown names raise ``ValueError`` so a
    mistyped ``--backend`` fails at construction, not mid-window."""
    if kind == "sim":
        return SimulatedBackend(catalog, store, **kwargs)
    if kind == "spmd":
        return SpmdBackend(catalog, store, **kwargs)
    raise ValueError(f"unknown backend {kind!r} (choose from {BACKENDS})")
