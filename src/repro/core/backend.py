"""Execution backends: ONE contract for "execute a dispatch window".

The paper's JSE is a single contract — distribute a query over
brick-resident data, merge partials at the submit server — but the repo
grew two divergent realizations of it: the virtual-time simulation
(fragment plans, streaming ``on_partial``, failure scripts, per-packet
telemetry) and the SPMD lockstep step (none of these, merge only at step
end).  This module collapses the divergence behind one interface so every
service/fabric feature (streaming, cache write-through, cost-model
calibration, window planning) works identically on both paths:

- :class:`ExecutionBackend` — the protocol:
  ``run_batch(job_ids, *, plan, on_partial, failure_script, packet_ramp)
  -> (results, JobStats)``.  Exactly the surface
  ``JobSubmissionEngine.run_job_batch_simulated`` already exposes, now
  named and substitutable.
- :class:`SimulatedBackend` — thin wrapper over the event-driven
  virtual-time grid simulation (``core/jse.py``).  Time is virtual, the
  per-packet compute is real.
- :class:`SpmdBackend` — the mesh-shard realization as a **chunked
  streaming scan**: each brick (= shard that never moves) is swept in
  chunks, every chunk evaluated through the same
  :func:`~repro.core.jse.eval_plan_slice` primitive as the simulation,
  and a :class:`~repro.core.jse.PacketPartial` emitted per chunk in
  deterministic merge order (brick id ascending, offset ascending) — so
  prefix snapshots fed to a :class:`~repro.core.merge.MergeAccumulator`
  are bit-identical to ``tree_merge`` of the same prefix, and a window
  executed with the same chunk boundaries on either backend produces
  bit-identical partial streams and final results.  Time here is
  WALL-CLOCK (``t_virtual`` carries elapsed seconds; ``JobStats``
  telemetry feeds ``planner.fit_cost_weights`` exactly as on the
  simulated path).  With ``use_pallas=True`` the fused ``event_filter``
  kernel evaluates the plan's boolean targets — including materialized
  shared fragments — in its epilogue (``interpret=True``), falling back
  to the jnp fragment-plan walk whenever any target is outside the
  kernel's conjunctive family.
- :func:`make_backend` — string-keyed factory (``"sim"`` / ``"spmd"``)
  the service layer and ``launch/serve.py --backend`` use.

See ``docs/backends.md`` for the full contract (merge-order determinism,
clock semantics, failure semantics, Pallas fragment fusion).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Protocol, Tuple, \
    runtime_checkable

import numpy as np

from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.brick import BrickStore
from repro.core.catalog import DONE, MetadataCatalog
from repro.core.jse import (JobStats, JobSubmissionEngine, PacketPartial,
                            PacketTelemetry, TimeModel, eval_plan_slice,
                            prepare_window)
from repro.core.packets import ramp_cap


@runtime_checkable
class ExecutionBackend(Protocol):
    """The one contract the service layer executes dispatch windows
    against.  Implementations own a catalogue + brick store pair and
    TWO mutable attributes the service relies on: ``cost_weights`` (the
    service installs fitted :class:`~repro.service.planner.CostWeights`
    there so the scheduler can bound windows by calibrated cost) and
    ``supports_failure_injection`` (checked BEFORE a window is dequeued;
    a backend that omits it is treated as not supporting failure
    scripts — the safe direction, since an error raised mid-dispatch
    would strand the window's tickets and streams)."""

    catalog: MetadataCatalog
    store: BrickStore
    cost_weights: Optional[object]
    supports_failure_injection: bool

    def run_batch(self, job_ids: List[int], *,
                  plan: Optional[query_lib.FragmentPlan] = None,
                  on_partial: Optional[
                      Callable[[PacketPartial], None]] = None,
                  failure_script: Optional[Dict[float, int]] = None,
                  packet_ramp: Optional[int] = None
                  ) -> Tuple[List[merge_lib.QueryResult], JobStats]:
        """Execute one shared-scan window of catalogued jobs.

        Contract (both backends): jobs must share bricks/calib_iters;
        ``plan`` (a fragment plan whose roots align with ``job_ids``) is
        built when absent; ``on_partial`` is invoked once per evaluated
        packet/chunk, in the exact merge order, with partials whose
        prefix merges are bit-identical to ``tree_merge`` of that
        prefix; ``packet_ramp`` caps early packet sizes for streaming;
        job statuses move RUNNING -> DONE (or FAILED) in the catalogue;
        returns ``(merged, stats)`` with materialized-fragment results
        in ``stats.fragment_results`` and per-packet compute telemetry
        in ``stats.packet_telemetry``."""
        ...


class SimulatedBackend:
    """The event-driven virtual-time grid simulation behind the
    :class:`ExecutionBackend` contract.

    A thin wrapper over :class:`~repro.core.jse.JobSubmissionEngine`
    (exposed as :attr:`engine` for callers tuning simulation knobs such
    as ``adaptive_packets`` or node speeds): scheduling, straggler
    mitigation, failure injection and virtual makespans are all the
    engine's — this class only pins the contract surface."""

    def __init__(self, catalog: MetadataCatalog, store: BrickStore, *,
                 time_model: Optional[TimeModel] = None,
                 node_speed: Optional[Dict[int, float]] = None,
                 adaptive_packets: bool = True,
                 packet_ramp: Optional[int] = None,
                 ramp_factor: float = 2.0):
        self.engine = JobSubmissionEngine(
            catalog, store, time_model=time_model, node_speed=node_speed,
            adaptive_packets=adaptive_packets, packet_ramp=packet_ramp,
            ramp_factor=ramp_factor)
        self.catalog = catalog
        self.store = store
        # fitted cost weights the service installs after telemetry refits
        # (consumed by QueryScheduler window-cost bounding)
        self.cost_weights = None
        #: the virtual grid can kill nodes mid-scan; the service checks
        #: this BEFORE dequeuing a window so an unsupported failure
        #: script fails fast with no state mutated
        self.supports_failure_injection = True
        #: the virtual grid routes packets per node, so the failure
        #: policy's avoid/probe/speculate decision applies here; the
        #: service checks this before passing routing kwargs
        self.supports_routing_policy = True

    @property
    def obs(self):
        """Observability plane handle — stored on the wrapped engine (the
        simulation loop is where packets are scanned), surfaced here so
        the service can install/inspect it backend-agnostically."""
        return self.engine.obs

    @obs.setter
    def obs(self, value):
        """Install the plane on the wrapped engine."""
        self.engine.obs = value

    def submit(self, expr: str, calib_iters: int = 0) -> int:
        """Register a job over every brick in the store (engine passthrough)."""
        return self.engine.submit(expr, calib_iters)

    def run_batch(self, job_ids: List[int], *,
                  plan: Optional[query_lib.FragmentPlan] = None,
                  on_partial: Optional[
                      Callable[[PacketPartial], None]] = None,
                  failure_script: Optional[Dict[float, int]] = None,
                  packet_ramp: Optional[int] = None,
                  route_avoid: Optional[set] = None,
                  probe_quota: Optional[Dict[int, int]] = None,
                  speculate: bool = False,
                  spec_lead_factor: float = 1.5,
                  rereplicated: Optional[List[Tuple[int, int, int]]] = None
                  ) -> Tuple[List[merge_lib.QueryResult], JobStats]:
        """Execute the window on the simulated grid (see
        :meth:`ExecutionBackend.run_batch` for the contract; the routing
        kwargs carry a :class:`~repro.service.policy.PolicyDecision` —
        see ``run_job_batch_simulated`` for their semantics, including
        the ``rereplicated`` brick-copy transfer charge)."""
        return self.engine.run_job_batch_simulated(
            job_ids, plan=plan, on_partial=on_partial,
            failure_script=failure_script, packet_ramp=packet_ramp,
            route_avoid=route_avoid, probe_quota=probe_quota,
            speculate=speculate, spec_lead_factor=spec_lead_factor,
            rereplicated=rereplicated)


class SpmdBackend:
    """The SPMD realization of the contract: a chunked streaming scan
    over the brick shards.

    Bricks play the role of mesh shards (data that never moves); the
    scan visits them in brick-id order and sweeps each in chunks of
    ``chunk_events``.  Every chunk runs the SAME fragment-factored
    evaluation primitive as the simulation
    (:func:`~repro.core.jse.eval_plan_slice`), so unique fragments are
    evaluated once per chunk and a chunk's partials are bit-identical to
    the simulated backend's partials for the same slice.  Per-chunk
    :class:`~repro.core.jse.PacketPartial`\\ s stream out through
    ``on_partial`` in deterministic merge order, which is what makes
    prefix snapshots (via :class:`~repro.core.merge.MergeAccumulator`)
    bit-identical to ``tree_merge`` of the same prefix — the streaming
    guarantee the simulated path already had, now on the SPMD path.

    Differences from the simulation, by design:

    - **Clock**: ``t_virtual`` on emitted partials and
      ``JobStats.makespan_s`` are wall-clock seconds since the window
      started (there is no virtual grid here), so the front-end's
      ``WindowController`` observes real latencies.
    - **Failures**: shards are resident compute state, not remote disks;
      ``failure_script`` is a simulated-grid concept and a non-empty one
      raises ``ValueError`` rather than being silently ignored.
    - **Pallas fusion** (``use_pallas=True``): when every plan target —
      per-query roots AND materialized boolean fragments — matches the
      fused ``event_filter`` kernel's conjunctive family, the kernel
      evaluates all of them in its epilogue in one track-streaming pass
      per chunk (``interpret=True`` off-TPU); otherwise the chunk falls
      back to the jnp fragment-plan walk.  Either way the per-chunk
      telemetry (``PacketTelemetry``) is recorded, so
      ``planner.fit_cost_weights`` calibrates from SPMD runs too.
    """

    def __init__(self, catalog: MetadataCatalog, store: BrickStore, *,
                 chunk_events: int = 64, packet_ramp: Optional[int] = None,
                 ramp_factor: float = 2.0, use_pallas: bool = False,
                 interpret: bool = True):
        if chunk_events <= 0:
            raise ValueError("chunk_events must be positive")
        if packet_ramp is not None and packet_ramp <= 0:
            raise ValueError("packet_ramp must be positive")
        if ramp_factor <= 1.0:
            raise ValueError("ramp_factor must be > 1")
        self.catalog = catalog
        self.store = store
        self.chunk_events = chunk_events
        self.packet_ramp = packet_ramp
        self.ramp_factor = ramp_factor
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.cost_weights = None  # installed by the service after refits
        #: shards are resident compute state, not killable virtual nodes
        self.supports_failure_injection = False
        #: no per-node routing either — chunks visit shards in place, so
        #: policy decisions (avoid/probe/speculate) don't apply here
        self.supports_routing_policy = False
        # observability plane (repro.obs.Observability); None = disabled
        self.obs = None

    # ------------------------------------------------------------------ #
    def _chunk_size(self, seq: int, remaining: int,
                    ramp: Optional[int]) -> int:
        """Size of chunk ``seq``: the configured chunk, capped early by
        the shared geometric stream ramp (``core/packets.py``), clipped
        to the shard remainder."""
        size = self.chunk_events
        if ramp is not None:
            cap = ramp_cap(seq, ramp, self.ramp_factor)
            if cap < size:
                size = max(1, int(cap))
        return min(size, remaining)

    def _fuse_plan(self, plan: query_lib.FragmentPlan):
        """Kernel-epilogue fusion: map EVERY plan target into the fused
        ``event_filter`` kernel's threshold encoding, or None when any
        target is outside the conjunctive family (chunks then take the
        jnp fragment-plan walk)."""
        if not self.use_pallas:
            return None
        from repro.kernels.event_filter import ops as ef_ops
        params = [ef_ops.match_epilogue(t, self.store.schema)
                  for t in plan.targets()]
        if any(p is None for p in params):
            return None
        return ef_ops.batch_kernel_params(params)

    def _eval_chunk(self, plan: query_lib.FragmentPlan, fused,
                    brick_id: int, start: int, size: int,
                    calib_iters: int) -> List[merge_lib.QueryResult]:
        """One chunk -> one partial per plan target (kernel epilogue when
        fused, shared jnp primitive otherwise)."""
        if fused is None:
            return eval_plan_slice(self.store, plan, brick_id, start, size,
                                   calib_iters)
        import jax.numpy as jnp
        from repro.kernels.event_filter import ops as ef_ops
        thresholds, var_idx = fused
        batch = self.store.bricks[brick_id]
        sl = {k: v[start:start + size] for k, v in batch.items()}
        mask, var = ef_ops.event_filter_batch(
            jnp.asarray(sl["scalars"]), jnp.asarray(sl["tracks"]),
            jnp.asarray(sl["n_tracks"]), thresholds, var_idx=var_idx,
            calib_iters=calib_iters, interpret=self.interpret)
        mask = np.asarray(mask)            # (N, K) — one column per target
        var = np.asarray(var)
        ids = np.asarray(sl["event_id"])
        return [merge_lib.from_mask(mask[:, k], var, ids)
                for k in range(mask.shape[1])]

    # ------------------------------------------------------------------ #
    def run_batch(self, job_ids: List[int], *,
                  plan: Optional[query_lib.FragmentPlan] = None,
                  on_partial: Optional[
                      Callable[[PacketPartial], None]] = None,
                  failure_script: Optional[Dict[float, int]] = None,
                  packet_ramp: Optional[int] = None
                  ) -> Tuple[List[merge_lib.QueryResult], JobStats]:
        """Execute the window as a chunked streaming scan over the brick
        shards (see the class docstring and
        :meth:`ExecutionBackend.run_batch` for the contract)."""
        if failure_script:
            raise ValueError(
                "failure_script is a simulated-grid concept; the SPMD "
                "backend has no virtual nodes to kill (use "
                "SimulatedBackend for failure experiments)")
        rec, plan = prepare_window(self.catalog, job_ids, plan)

        obs = self.obs
        stats = JobStats(n_queries=len(job_ids))
        plan_aggs = query_lib.unique_aggregates(plan.targets())
        fused = self._fuse_plan(plan)
        ramp = packet_ramp if packet_ramp is not None else self.packet_ramp
        results: List[List[merge_lib.QueryResult]] = []
        t_start = time.perf_counter()
        seq = 0
        for bid in sorted(rec.bricks):
            n = self.store.specs[bid].n_events
            owner = self.store.specs[bid].node
            start = 0
            while start < n:
                size = self._chunk_size(seq, n - start, ramp)
                pkt_span = None
                if obs is not None:
                    pkt_span = obs.tracer.begin(
                        "packet",
                        t_virtual=(obs.tracer.virtual_base
                                   + time.perf_counter() - t_start),
                        seq=seq, brick=bid, start=start, size=size,
                        node=owner)
                t0 = time.perf_counter()
                res = self._eval_chunk(plan, fused, bid, start, size,
                                       rec.calib_iters)
                wall = time.perf_counter() - t0
                stats.packet_telemetry.append(PacketTelemetry(
                    size=size, calib_iters=rec.calib_iters,
                    n_aggregates=plan_aggs, wall_s=wall,
                    n_targets=len(plan.targets()), node=owner))
                if obs is not None:
                    obs.tracer.end(
                        pkt_span,
                        t_virtual=(obs.tracer.virtual_base
                                   + time.perf_counter() - t_start))
                    obs.metrics.counter("packet.count").inc()
                    obs.metrics.histogram("packet.latency_s").observe(wall)
                    obs.metrics.histogram("packet.events").observe(size)
                    obs.health.observe_packet(owner, size, wall)
                results.append(res)
                stats.events_scanned += size
                stats.fragment_evals += plan.evals_per_batch
                stats.fragment_evals_unshared += plan.unshared_evals
                stats.packets += 1
                stats.per_node_busy[owner] = \
                    stats.per_node_busy.get(owner, 0.0) + wall
                if on_partial is not None:
                    on_partial(PacketPartial(
                        seq=seq, brick_id=bid, start=start, size=size,
                        node=owner,
                        t_virtual=time.perf_counter() - t_start,
                        failures=0, partials=res))
                seq += 1
                start += size

        k = len(job_ids)
        merged = (merge_lib.merge_batch(results) if results
                  else [merge_lib.QueryResult()
                        for _ in range(len(plan.targets()))])
        stats.fragment_results = dict(
            zip(plan.materialize_keys(), merged[k:]))
        merged = merged[:k]
        stats.makespan_s = time.perf_counter() - t_start

        end = time.time()
        for jid, m in zip(job_ids, merged):
            self.catalog.update(
                jid, status=DONE, end_time=end,
                events_processed=m.n_processed, failures=0,
                result={
                    "n_selected": m.n_selected,
                    "n_processed": m.n_processed,
                    "sum_var": m.sum_var,
                    "makespan_s": stats.makespan_s,
                })
        return merged, stats


BACKENDS = ("sim", "spmd")


def make_backend(kind: str, catalog: MetadataCatalog, store: BrickStore,
                 **kwargs) -> ExecutionBackend:
    """Build an execution backend by name over a catalogue/store pair.

    ``kind`` is ``"sim"`` (:class:`SimulatedBackend`) or ``"spmd"``
    (:class:`SpmdBackend`); ``kwargs`` pass through to the chosen
    backend's constructor — unknown names raise ``ValueError`` so a
    mistyped ``--backend`` fails at construction, not mid-window."""
    if kind == "sim":
        return SimulatedBackend(catalog, store, **kwargs)
    if kind == "spmd":
        return SpmdBackend(catalog, store, **kwargs)
    raise ValueError(f"unknown backend {kind!r} (choose from {BACKENDS})")
