"""Metadata catalogue + node information service.

Plays the roles of the paper's PostgreSQL meta-data catalogue (job tuples,
raw-data distribution, results) and of GRIS/LDAP in MDS (per-node resource
info: processors, bandwidth, liveness).  The JSE broker polls this object
exactly as the paper's broker "searches from time to time into the
Meta-data catalogue".

Persisted as JSON so a restarted JSE recovers job state (checkpoint/restart
at the control plane).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional, Tuple

PENDING, RUNNING, DONE, FAILED = "PENDING", "RUNNING", "DONE", "FAILED"


@dataclasses.dataclass
class NodeInfo:
    """Per-node resource record (the paper's GRIS/LDAP entry): liveness,
    nominal capacity, and the PROOF-style throughput EMA the adaptive
    packet scheduler sizes packets from."""
    node_id: int
    n_cpus: int = 8
    bandwidth_mbps: float = 100.0  # paper: fast Ethernet
    alive: bool = True
    throughput_ema: float = 1.0    # events/s, PROOF-style speed estimate
    packets_done: int = 0

    def observe(self, events: int, seconds: float, decay: float = 0.7):
        """Fold one completed packet's measured rate into the EMA."""
        if seconds <= 0:
            return
        rate = events / seconds
        self.throughput_ema = decay * self.throughput_ema + (1 - decay) * rate
        self.packets_done += 1


@dataclasses.dataclass
class JobRecord:
    """One job tuple in the catalogue: expression, lifecycle status and
    timestamps, target bricks, and the merged result summary."""
    job_id: int
    expr: str
    calib_iters: int
    status: str = PENDING
    submit_time: float = 0.0
    start_time: float = 0.0
    end_time: float = 0.0
    bricks: Tuple[int, ...] = ()
    result: Optional[dict] = None
    events_processed: int = 0
    failures: int = 0
    note: str = ""
    tenant: str = ""       # multi-tenant service: submitting tenant
    batch_id: int = -1     # shared-scan batch this job was coalesced into


class MetadataCatalog:
    """The paper's PostgreSQL meta-data catalogue + GRIS in one object:
    job tuples, per-node resource info, dataset versioning (epoch hooks
    drive cache invalidation), and JSON persistence."""

    def __init__(self, n_nodes: int = 0):
        self.jobs: Dict[int, JobRecord] = {}
        self.nodes: Dict[int, NodeInfo] = {
            i: NodeInfo(i) for i in range(n_nodes)}
        self._next_job = 0
        # dataset version: bumped whenever the raw-data distribution
        # changes (new run appended, brick recalibrated, ...) — consumers
        # (the service result cache) subscribe to invalidate stale results
        self.dataset_epoch = 0
        self._epoch_hooks: List[Callable[[int], None]] = []

    # ------------------------- job tuples --------------------------- #
    def submit(self, expr: str, calib_iters: int = 4,
               bricks: Tuple[int, ...] = (), *, tenant: str = "",
               batch_id: int = -1) -> int:
        """Insert a PENDING job tuple; returns the new job id."""
        jid = self._next_job
        self._next_job += 1
        self.jobs[jid] = JobRecord(jid, expr, calib_iters,
                                   submit_time=time.time(), bricks=bricks,
                                   tenant=tenant, batch_id=batch_id)
        return jid

    # ------------------------- dataset versioning ------------------- #
    def on_dataset_bump(self, hook: Callable[[int], None]):
        """Register a callback fired with the new epoch on every bump."""
        self._epoch_hooks.append(hook)

    def off_dataset_bump(self, hook: Callable[[int], None]):
        """Remove a previously registered bump callback (no-op if absent)."""
        try:
            self._epoch_hooks.remove(hook)
        except ValueError:
            pass

    def bump_dataset_version(self) -> int:
        """Record a change to the raw-data distribution (paper: the
        catalogue tracks where the data lives; here also *which version*)."""
        self.dataset_epoch += 1
        for hook in self._epoch_hooks:
            hook(self.dataset_epoch)
        return self.dataset_epoch

    def set_dataset_epoch(self, epoch: int) -> int:
        """Adopt an externally reconciled dataset epoch (the fabric's
        gossip layer merges version vectors and pushes the result here).
        Epochs only move forward — a stale digest can never roll the
        catalogue back — and an actual advance fires the same bump hooks
        as a local ``bump_dataset_version`` so caches invalidate
        identically either way.  Returns the (possibly unchanged) epoch."""
        if epoch > self.dataset_epoch:
            self.dataset_epoch = epoch
            for hook in self._epoch_hooks:
                hook(self.dataset_epoch)
        return self.dataset_epoch

    def next_pending(self) -> Optional[JobRecord]:
        """Oldest PENDING job, or None (what the polling broker picks up)."""
        for jid in sorted(self.jobs):
            if self.jobs[jid].status == PENDING:
                return self.jobs[jid]
        return None

    def update(self, jid: int, **fields):
        """Set fields on a job tuple (status transitions, results, ...)."""
        rec = self.jobs[jid]
        for k, v in fields.items():
            setattr(rec, k, v)

    # ------------------------- node info (GRIS) --------------------- #
    def node(self, node_id: int) -> NodeInfo:
        """NodeInfo for ``node_id`` (created on first reference)."""
        return self.nodes.setdefault(node_id, NodeInfo(node_id))

    def mark_dead(self, node_id: int):
        """Record a node death (failover and re-queue consult this)."""
        self.node(node_id).alive = False

    def mark_alive(self, node_id: int):
        """Bring a node back (rejoin after repair/elastic scale-up)."""
        self.node(node_id).alive = True

    def alive_nodes(self) -> List[int]:
        """Sorted ids of nodes currently marked alive."""
        return sorted(n for n, info in self.nodes.items() if info.alive)

    def dead_nodes(self) -> set:
        """Ids of nodes currently marked dead."""
        return {n for n, info in self.nodes.items() if not info.alive}

    def grid_info(self, node_id: int) -> dict:
        """The paper's 'query properties of the grid nodes' (LDAP port 2135)."""
        info = self.node(node_id)
        return dataclasses.asdict(info)

    # ------------------------- persistence -------------------------- #
    def to_json(self) -> str:
        """Serialize the whole catalogue (jobs, nodes, epoch) to JSON."""
        return json.dumps({
            "jobs": {k: dataclasses.asdict(v) for k, v in self.jobs.items()},
            "nodes": {k: dataclasses.asdict(v) for k, v in self.nodes.items()},
            "next_job": self._next_job,
            "dataset_epoch": self.dataset_epoch,
        })

    @classmethod
    def from_json(cls, text: str) -> "MetadataCatalog":
        """Rebuild a catalogue from :meth:`to_json` output (JSE restart
        recovery at the control plane)."""
        data = json.loads(text)
        cat = cls()
        for k, v in data["jobs"].items():
            v["bricks"] = tuple(v["bricks"])
            cat.jobs[int(k)] = JobRecord(**v)
        for k, v in data["nodes"].items():
            cat.nodes[int(k)] = NodeInfo(**v)
        cat._next_job = data["next_job"]
        cat.dataset_epoch = data.get("dataset_epoch", 0)
        return cat
