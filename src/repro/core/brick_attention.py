"""Grid-brick KV-cache attention.

The paper's core move — split the data into node-resident bricks, run the
job where the data lives, merge the small per-node results at the JSE — is
applied here to the decode-time KV cache:

- the cache sequence dim W is sharded over the ``model`` axis (each chip
  owns a *brick* of the context, which never moves),
- every chip computes online-softmax statistics (m, l, acc) over its brick
  only — the "job" ships to the brick, not the brick to the job,
- the per-brick partials are merged with an exact log-sum-exp combine
  (pmax + two psums of tiny tensors) — the "result merge at the JSE".

Per-chip cache memory for qwen3-32b decode_32k drops 16x (68 GB -> 4.3 GB),
which is the difference between the cell fitting v5e HBM or not.  Cross-pod
(``pod`` axis) traffic stays zero, faithful to GEPS's WAN-avoidance.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at top level
    _shard_map = jax.shard_map
    _SM_NOCHECK = {"check_vma": False}
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_NOCHECK = {"check_rep": False}

from repro.models.attention import NEG_INF, repeat_kv
from repro.models.layers import softcap as apply_softcap


def brick_active(cfg, shd, cache_w: int) -> bool:
    """Use the brick-sharded cache when the context is large, unwindowed,
    and the mesh has a model axis the cache length divides."""
    if not cfg.decode_cache_seq_shard or shd.tensor_size <= 1:
        return False
    if cfg.sliding_window or cfg.attention_window:
        return False  # window-bounded caches are already small
    return cache_w > 4096 and cache_w % shd.tensor_size == 0


def decode_attention(
    cfg,
    shd,
    q: jax.Array,       # (B, 1, Hp, hd)  heads sharded over model
    k_cache: jax.Array,  # (B, W, K, hd)  W sharded over model (brick axis)
    v_cache: jax.Array,
    kpos: jax.Array,    # (W,) absolute positions, -1 = empty (replicated)
    new_k: jax.Array,   # (B, 1, K, hd)  replicated over model
    new_v: jax.Array,
    slot: jax.Array,    # () int32: ring-buffer slot being written
    t: jax.Array,       # () int32: absolute position of the new token
):
    """Returns (out (B,1,Hp,hd) replicated-over-model, k_cache', v_cache')."""
    mesh = shd.mesh
    batch = shd.batch_axes if q.shape[0] % shd.batch_size_total == 0 else ()
    scale = (cfg.attn_scale_override
             if cfg.attn_scale_override is not None else cfg.head_dim ** -0.5)

    fn = functools.partial(
        _brick_attn_local,
        axis="model",
        scale=scale,
        logit_cap=cfg.attn_logit_softcap,
    )
    return _shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            P(batch, None, "model", None),   # q (head-sharded)
            P(batch, "model", None, None),   # k brick
            P(batch, "model", None, None),   # v brick
            P(None),                          # kpos (replicated)
            P(batch, None, None, None),      # new_k
            P(batch, None, None, None),      # new_v
            P(),                              # slot
            P(),                              # t
        ),
        out_specs=(
            P(batch, None, None, None),      # out: replicated over model
            P(batch, "model", None, None),
            P(batch, "model", None, None),
        ),
        **_SM_NOCHECK,
    )(q, k_cache, v_cache, kpos, new_k, new_v, slot, t)


def _brick_attn_local(q, k, v, kpos, new_k, new_v, slot, t, *, axis, scale,
                      logit_cap):
    """Per-shard body: local brick update + partial softmax + JSE merge.

    GQA is computed in the grouped (B,1,K,G,hd) formulation — inside
    shard_map there is no GSPMD partitioning to appease, so no repeat-KV
    materialization: the cache is read once in its storage dtype and the
    dots accumulate in f32 via preferred_element_type (MXU-native)."""
    b, w_loc, kh, hd = k.shape
    my = jax.lax.axis_index(axis)

    # ---- write the new token's KV into the owning brick --------------- #
    # non-owners re-write their existing slice: the `where` touches only
    # the (B,1,K,hd) slice, never the whole cache (a whole-cache select
    # makes XLA materialize carry copies)
    local_slot = jnp.clip(slot - my * w_loc, 0, w_loc - 1)
    owns = (slot >= my * w_loc) & (slot < (my + 1) * w_loc)
    old_k = jax.lax.dynamic_slice_in_dim(k, local_slot, 1, axis=1)
    old_v = jax.lax.dynamic_slice_in_dim(v, local_slot, 1, axis=1)
    upd_k = jnp.where(owns, new_k.astype(k.dtype), old_k)
    upd_v = jnp.where(owns, new_v.astype(v.dtype), old_v)
    k = jax.lax.dynamic_update_slice_in_dim(k, upd_k, local_slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(v, upd_v, local_slot, axis=1)

    # ---- local partial attention over this brick ----------------------- #
    q_full = jax.lax.all_gather(q, axis, axis=2, tiled=True)  # (B,1,H,hd)
    h = q_full.shape[2]
    g = h // kh
    kpos_updated = jnp.where(jnp.arange(kpos.shape[0]) == slot, t, kpos)
    kpos_loc = jax.lax.dynamic_slice_in_dim(kpos_updated, my * w_loc, w_loc)

    qg = (q_full.astype(jnp.float32) * scale).astype(q.dtype)
    qg = qg.reshape(b, 1, kh, g, hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k,
                   preferred_element_type=jnp.float32)  # (B,1,K,G,W_loc)
    s = apply_softcap(s, logit_cap)
    valid = (kpos_loc >= 0) & (kpos_loc <= t)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)

    m = jnp.maximum(jnp.max(s, axis=-1), 0.1 * NEG_INF)  # (B,1,K,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)

    # ---- JSE merge: exact log-sum-exp combine across bricks ----------- #
    m_g = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis)
    acc_g = jax.lax.psum(acc * corr[..., None], axis)
    out = acc_g / jnp.maximum(l_g[..., None], 1e-30)
    return out.reshape(b, 1, h, hd).astype(q.dtype), k, v
