"""Filter-expression compiler: the paper's "filter expression" web-form field.

GEPS users submit jobs with a filter expression over event variables (paper
section 5, Fig 4).  We compile a small expression language to a pure-JAX
predicate over an EventBatch, so the same user-facing query runs SPMD over
brick-sharded arrays.

Grammar (precedence low->high):
    expr    := or
    or      := and ("||" and)*
    and     := cmp ("&&" cmp)*
    cmp     := sum (("<"|"<="|">"|">="|"=="|"!=") sum)?
    sum     := prod (("+"|"-") prod)*
    prod    := unary (("*"|"/") unary)*
    unary   := "-" unary | "!" unary | atom
    atom    := NUMBER | IDENT | AGG "(" IDENT ")" | "(" expr ")"
    AGG     := "sum" | "max" | "min" | "count" | "mean"

IDENT resolves scalar variables by name (events.SCALAR_VARS) or, inside an
aggregation, track variables (events.TRACK_VARS); ``n_tracks`` is built in.
Aggregations reduce over the valid tracks of each event, e.g.::

    "pt_lead > 50 && count(pt > 20) >= 2 && sum(pt) < 500"
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import events as ev

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>\d+\.?\d*(?:[eE][+-]?\d+)?)|(?P<id>[A-Za-z_]\w*)"
    r"|(?P<op>&&|\|\||<=|>=|==|!=|[-+*/<>!()]))"
)

AGGS = ("sum", "max", "min", "count", "mean")


class QueryError(ValueError):
    """Malformed or schema-invalid filter expression."""


def tokenize(src: str) -> List[str]:
    """Split an expression into number/identifier/operator tokens."""
    out, pos = [], 0
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if not m or m.end() == pos:
            if src[pos:].strip():
                raise QueryError(f"bad token at: {src[pos:]!r}")
            break
        out.append(m.group(m.lastgroup))
        pos = m.end()
    return out


# ---------------------------- AST ---------------------------------------- #
@dataclasses.dataclass
class Num:
    """AST leaf: a numeric literal."""
    value: float


@dataclasses.dataclass
class Var:
    """AST leaf: a scalar/track variable reference (resolved at eval)."""
    name: str


@dataclasses.dataclass
class Agg:
    """AST node: a track aggregation (sum/max/min/count/mean) over the
    valid tracks of each event."""
    fn: str
    arg: "Node"


@dataclasses.dataclass
class Unary:
    """AST node: unary negation (``-``) or logical not (``!``)."""
    op: str
    arg: "Node"


@dataclasses.dataclass
class Bin:
    """AST node: binary arithmetic / comparison / logic operator."""
    op: str
    lhs: "Node"
    rhs: "Node"


Node = Union[Num, Var, Agg, Unary, Bin]


class _Parser:
    def __init__(self, tokens: List[str]):
        self.toks = tokens
        self.i = 0

    def peek(self) -> Optional[str]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def take(self, expect: Optional[str] = None) -> str:
        tok = self.peek()
        if tok is None or (expect is not None and tok != expect):
            raise QueryError(f"expected {expect!r}, got {tok!r}")
        self.i += 1
        return tok

    def parse(self) -> Node:
        node = self.or_()
        if self.peek() is not None:
            raise QueryError(f"trailing tokens: {self.toks[self.i:]}")
        return node

    def or_(self):
        node = self.and_()
        while self.peek() == "||":
            self.take()
            node = Bin("||", node, self.and_())
        return node

    def and_(self):
        node = self.cmp()
        while self.peek() == "&&":
            self.take()
            node = Bin("&&", node, self.cmp())
        return node

    def cmp(self):
        node = self.sum_()
        if self.peek() in ("<", "<=", ">", ">=", "==", "!="):
            op = self.take()
            node = Bin(op, node, self.sum_())
        return node

    def sum_(self):
        node = self.prod()
        while self.peek() in ("+", "-"):
            node = Bin(self.take(), node, self.prod())
        return node

    def prod(self):
        node = self.unary()
        while self.peek() in ("*", "/"):
            node = Bin(self.take(), node, self.unary())
        return node

    def unary(self):
        if self.peek() in ("-", "!"):
            return Unary(self.take(), self.unary())
        return self.atom()

    def atom(self):
        tok = self.peek()
        if tok == "(":
            self.take()
            node = self.or_()
            self.take(")")
            return node
        tok = self.take()
        if re.fullmatch(r"\d+\.?\d*(?:[eE][+-]?\d+)?", tok):
            return Num(float(tok))
        if tok in AGGS and self.peek() == "(":
            self.take("(")
            arg = self.or_()
            self.take(")")
            return Agg(tok, arg)
        return Var(tok)


def parse(src: str) -> Node:
    """Parse a filter expression into its AST (QueryError on bad input)."""
    return _Parser(tokenize(src)).parse()


def unparse(node: Node) -> str:
    """Deterministic fully-parenthesized rendering of an AST."""
    if isinstance(node, Num):
        return repr(node.value)
    if isinstance(node, Var):
        return node.name
    if isinstance(node, Agg):
        return f"{node.fn}({unparse(node.arg)})"
    if isinstance(node, Unary):
        return f"{node.op}({unparse(node.arg)})"
    if isinstance(node, Bin):
        return f"({unparse(node.lhs)} {node.op} {unparse(node.rhs)})"
    raise QueryError(f"bad node {node}")


def canonical_expr(src: str) -> str:
    """Canonical form of a filter expression: whitespace, redundant parens
    and number spellings ("3" vs "3.0") are normalized away, so textually
    different but identical queries share one result-cache key."""
    return unparse(parse(src))


def validate_expr(src: str, schema: ev.EventSchema) -> Node:
    """Parse + resolve every variable against the schema (admission-time
    check: a bad query must be rejected at submit, not on a grid node)."""
    ast = parse(src)

    def walk(node: Node, track_ctx: bool):
        if isinstance(node, Var):
            if node.name == "n_tracks":
                return
            if track_ctx and node.name in ev.TRACK_VARS:
                return
            try:
                if schema.scalar_index(node.name) >= schema.n_scalars:
                    raise ValueError
            except ValueError:
                raise QueryError(f"unknown variable {node.name!r}") from None
        elif isinstance(node, Agg):
            walk(node.arg, True)
        elif isinstance(node, Unary):
            walk(node.arg, track_ctx)
        elif isinstance(node, Bin):
            walk(node.lhs, track_ctx)
            walk(node.rhs, track_ctx)

    walk(ast, False)
    return ast


# ---------------------------- compiler ----------------------------------- #
def eval_node(node: Node, batch, schema: ev.EventSchema,
              track_ctx: bool = False, memo: Optional[dict] = None):
    """Evaluate one AST node over an EventBatch.

    This is the single source of truth for query semantics: ``compile_query``
    calls it without a memo (one evaluation per node *occurrence*, the PR 1
    behaviour) and the fragment planner calls it with a shared ``memo`` dict
    keyed on ``(id(node), track_ctx)`` so interned common subexpressions are
    evaluated ONCE across a whole dispatch window.  Memoization reuses the
    exact arrays an unmemoized walk would recompute from identical inputs,
    so per-query outputs are bit-identical either way.
    """
    if memo is not None:
        key = (id(node), track_ctx)
        hit = memo.get(key)
        if hit is not None:
            return hit
    val = _eval_node_raw(node, batch, schema, track_ctx, memo)
    if memo is not None:
        memo[key] = val
    return val


def _eval_node_raw(node: Node, batch, schema: ev.EventSchema,
                   track_ctx: bool, memo: Optional[dict]):
    if isinstance(node, Num):
        return jnp.float32(node.value)
    if isinstance(node, Var):
        if node.name == "n_tracks":
            return batch["n_tracks"].astype(jnp.float32)
        if track_ctx:
            try:
                idx = schema.track_index(node.name)
                return batch["tracks"][..., idx]
            except ValueError:
                pass
        try:
            idx = schema.scalar_index(node.name)
        except ValueError:
            raise QueryError(f"unknown variable {node.name!r}") from None
        if idx >= schema.n_scalars:
            raise QueryError(f"variable {node.name!r} outside schema")
        val = batch["scalars"][..., idx]
        if track_ctx:
            val = val[..., None]  # broadcast over tracks
        return val
    if isinstance(node, Agg):
        inner = eval_node(node.arg, batch, schema, True, memo)  # (N, T)
        t = jnp.arange(inner.shape[-1])
        valid = t[None, :] < batch["n_tracks"][:, None]
        if node.fn == "count":
            return jnp.sum(jnp.where(valid, (inner != 0).astype(
                jnp.float32), 0.0), axis=-1)
        if node.fn == "sum":
            return jnp.sum(jnp.where(valid, inner, 0.0), axis=-1)
        if node.fn == "mean":
            s = jnp.sum(jnp.where(valid, inner, 0.0), axis=-1)
            return s / jnp.maximum(batch["n_tracks"].astype(jnp.float32), 1)
        if node.fn == "max":
            return jnp.max(jnp.where(valid, inner, -jnp.inf), axis=-1)
        if node.fn == "min":
            return jnp.min(jnp.where(valid, inner, jnp.inf), axis=-1)
        raise QueryError(node.fn)
    if isinstance(node, Unary):
        val = eval_node(node.arg, batch, schema, track_ctx, memo)
        return -val if node.op == "-" else (val == 0).astype(jnp.float32)
    if isinstance(node, Bin):
        a = eval_node(node.lhs, batch, schema, track_ctx, memo)
        b = eval_node(node.rhs, batch, schema, track_ctx, memo)
        ops = {
            "+": lambda: a + b,
            "-": lambda: a - b,
            "*": lambda: a * b,
            "/": lambda: a / jnp.where(b == 0, 1e-30, b),
            "<": lambda: (a < b).astype(jnp.float32),
            "<=": lambda: (a <= b).astype(jnp.float32),
            ">": lambda: (a > b).astype(jnp.float32),
            ">=": lambda: (a >= b).astype(jnp.float32),
            "==": lambda: (a == b).astype(jnp.float32),
            "!=": lambda: (a != b).astype(jnp.float32),
            "&&": lambda: ((a != 0) & (b != 0)).astype(jnp.float32),
            "||": lambda: ((a != 0) | (b != 0)).astype(jnp.float32),
        }
        if node.op not in ops:
            raise QueryError(node.op)
        return ops[node.op]()
    raise QueryError(f"bad node {node}")


def compile_query(src: str, schema: ev.EventSchema) -> Callable:
    """Compile to ``fn(batch) -> (N,) f32`` (bool predicates return 0/1)."""
    ast = parse(src)

    def fn(batch):
        return eval_node(ast, batch, schema, False)

    return fn


# ---------------------------- fragment plans ------------------------------ #
def node_key(node: Node) -> str:
    """Canonical string identity of a subexpression (the fragment key used
    by the planner and the fragment-level result cache).  Two ASTs with the
    same ``node_key`` evaluate identically on every batch."""
    return unparse(node)


class Interner:
    """Hash-conses ASTs so structurally identical subexpressions across a
    window of queries become the SAME node object; shared identity is what
    lets a memoized :func:`eval_node` walk evaluate each unique fragment
    exactly once."""

    def __init__(self):
        self._table: dict = {}

    def intern(self, node: Node) -> Node:
        """Return the canonical shared instance of ``node``'s structure
        (recursively interning children first)."""
        if isinstance(node, Num):
            key = ("num", node.value)
        elif isinstance(node, Var):
            key = ("var", node.name)
        elif isinstance(node, Agg):
            arg = self.intern(node.arg)
            key = ("agg", node.fn, id(arg))
            node = Agg(node.fn, arg)
        elif isinstance(node, Unary):
            arg = self.intern(node.arg)
            key = ("unary", node.op, id(arg))
            node = Unary(node.op, arg)
        elif isinstance(node, Bin):
            lhs, rhs = self.intern(node.lhs), self.intern(node.rhs)
            key = ("bin", node.op, id(lhs), id(rhs))
            node = Bin(node.op, lhs, rhs)
        else:
            raise QueryError(f"bad node {node}")
        return self._table.setdefault(key, node)

    def __len__(self) -> int:
        return len(self._table)


def count_occurrences(node: Node) -> int:
    """Total node *occurrences* in a tree — the number of evaluations an
    unmemoized walk (PR 1's per-query compile) performs."""
    if isinstance(node, (Num, Var)):
        return 1
    if isinstance(node, (Agg, Unary)):
        return 1 + count_occurrences(node.arg)
    if isinstance(node, Bin):
        return 1 + count_occurrences(node.lhs) + count_occurrences(node.rhs)
    raise QueryError(f"bad node {node}")


def _reachable(node: Node, track_ctx: bool, seen: set):
    """Walk unique (interned node, context) pairs reachable from ``node``."""
    key = (id(node), track_ctx)
    if key in seen:
        return
    seen.add(key)
    if isinstance(node, Agg):
        _reachable(node.arg, True, seen)
    elif isinstance(node, Unary):
        _reachable(node.arg, track_ctx, seen)
    elif isinstance(node, Bin):
        _reachable(node.lhs, track_ctx, seen)
        _reachable(node.rhs, track_ctx, seen)


def is_boolean(node: Node) -> bool:
    """True when the node's value is a 0/1 mask (comparison, logic, not)."""
    if isinstance(node, Bin):
        return node.op in ("<", "<=", ">", ">=", "==", "!=", "&&", "||")
    return isinstance(node, Unary) and node.op == "!"


@dataclasses.dataclass
class FragmentPlan:
    """Deduplicated execution plan for a window of queries.

    ``roots`` are the per-query interned ASTs; structurally identical
    subexpressions are the same object, so :meth:`evaluate` with one shared
    memo computes each unique fragment once per batch and reassembles every
    query's predicate from fragment outputs.  ``materialize`` lists extra
    shared fragments whose masks the executor should surface as first-class
    results (fed to the fragment-level cache by the service).

    ``unique_fragments`` (evaluations this plan performs per batch) vs.
    ``unshared_evals`` (evaluations K independent compiles would perform)
    is the factoring win the planner benchmark measures.  ``shared=False``
    disables cross-query memo sharing — the PR 1 baseline semantics.
    """
    exprs: List[str]
    roots: List[Node]
    unique_fragments: int
    unshared_evals: int
    shared: bool = True
    materialize: List[Node] = dataclasses.field(default_factory=list)

    @property
    def evals_per_batch(self) -> int:
        """Fragment evaluations this plan performs on one resident batch."""
        return self.unique_fragments if self.shared else self.unshared_evals

    def targets(self) -> List[Node]:
        """Everything the executor surfaces: roots, then materialized
        shared fragments."""
        return list(self.roots) + list(self.materialize)

    def materialize_keys(self) -> List[str]:
        """Canonical cache keys of the materialized shared fragments."""
        return [node_key(m) for m in self.materialize]

    def evaluate(self, batch, schema: ev.EventSchema) -> List:
        """Evaluate every root (then every materialized fragment) on one
        batch; returns a list of (N,) arrays, roots first.  In unshared
        mode no memo is used at all, so the work performed matches
        ``unshared_evals`` exactly (one evaluation per node occurrence)."""
        memo: Optional[dict] = {} if self.shared else None
        return [eval_node(tgt, batch, schema, False, memo)
                for tgt in self.targets()]


def unique_aggregates(roots: Sequence[Node]) -> int:
    """Number of distinct interned :class:`Agg` nodes reachable from
    ``roots`` — the track sweeps one fragment-factored pass performs per
    resident batch (the cost-model calibration's per-packet feature)."""
    return sum(1 for _, node in _id_nodes(roots) if isinstance(node, Agg))


def _id_nodes(roots: Sequence[Node]):
    """Unique (id, node) pairs reachable from ``roots`` (helper for
    counting by node type)."""
    out: dict = {}

    def walk(node):
        if id(node) in out:
            return
        out[id(node)] = node
        if isinstance(node, (Agg, Unary)):
            walk(node.arg)
        elif isinstance(node, Bin):
            walk(node.lhs)
            walk(node.rhs)

    for r in roots:
        walk(r)
    return out.items()


def build_fragment_plan(exprs: Sequence[str], *, shared: bool = True,
                        interner: Optional[Interner] = None) -> FragmentPlan:
    """Canonicalize + hash-cons every subexpression of each query into a
    deduplicated fragment plan (the planner's common-subexpression
    factoring).  Near-duplicate queries (same aggregates under different
    outer filters) end up sharing fragment objects, hence compute.

    Pass a pre-seeded ``interner`` (the fabric's fragment registry seeds
    one with cross-window hot fragments) so fragments already interned
    share node identity with this window's queries; seeding never changes
    the plan's results, only what the planner can recognize by ``id()``."""
    interner = interner if interner is not None else Interner()
    roots = [interner.intern(parse(e)) for e in exprs]
    seen: set = set()
    for r in roots:
        _reachable(r, False, seen)
    return FragmentPlan(
        exprs=[node_key(r) for r in roots],
        roots=roots,
        unique_fragments=len(seen),
        unshared_evals=sum(count_occurrences(r) for r in roots),
        shared=shared,
    )


def compile_query_batch(exprs: Sequence[str],
                        schema: ev.EventSchema) -> Callable:
    """Stack K predicates into ONE fused, fragment-factored pass.

    Returns ``fn(batch) -> (K, N) f32``.  The window is compiled through a
    :class:`FragmentPlan`, so common subexpressions (scalar loads, validity
    masks, shared track aggregates like ``count(pt > 30)``) are evaluated
    once per sweep and reused by every query that references them; under
    jit XLA fuses the remainder.  Per-query rows are bit-identical to K
    independent ``compile_query`` evaluations."""
    plan = build_fragment_plan(exprs)

    def fn(batch):
        return jnp.stack(plan.evaluate(batch, schema), axis=0)

    return fn


def calibrate(batch, iters: int = 4):
    """The paper's per-event "calibration procedure" (section 4.1): an
    iterative refinement over track parameters — the compute-heavy part of
    event processing.  Returns a new tracks array."""
    tracks = batch["tracks"]

    def body(i, trk):
        pt = trk[..., 0:1]
        corr = 1.0 + 0.01 * jnp.tanh(trk) * jax.lax.rsqrt(1.0 + pt * pt)
        return trk * corr

    return jax.lax.fori_loop(0, iters, body, tracks)
