"""Failure policy engine: the RSS-style *acting* half of failure handling.

The paper names node failure as the Grid-Brick design's biggest
disadvantage, with replication as the workaround.  PR 6 built the
*seeing* half — per-node latency/failure EWMAs gossiped fleet-wide
(``obs/health.py``).  This module turns that evidence into action, the
shape DIRAC's Resource Status System gives it: an explicit per-node
state machine driving routing, mitigation, and recovery.

State machine (one transition per decision window, hysteresis counters
so it cannot oscillate)::

            unhealthy x degrade_after      suspect x ban_after
      ok ────────────────────────▶ degraded ────────────────▶ banned
       ▲                            │    ▲                      │
       │   healthy x recover_after  │    │ (probe fails)        │ dwell
       └────────────────────────────┘    │                      │ probe_after
       ▲                                 │                      ▼
       └──────── clean x probe_packets ──┴───────────────── probing

- **ok → degraded**: ``degrade_after`` consecutive windows of unhealthy
  evidence (degraded or suspect classification from the
  :class:`~repro.obs.health.HealthReport`).
- **degraded → ok**: ``recover_after`` consecutive clean windows — the
  hysteresis band that stops a borderline node from flapping.
- **degraded → banned**: ``ban_after`` consecutive *suspect* windows.
  Banned nodes are excluded from packet routing entirely.
- **banned → probing**: after ``probe_after`` windows of dwell the node
  gets ``probe_packets`` of probe quota per window — it leases at most
  that many packets, so a still-sick node damages one probe, not a scan.
- **probing → ok**: ``probe_packets`` clean probe packets observed.  The
  probes themselves are the fresh evidence: each clean packet also decays
  the node's failure EWMA in the health monitor, so by the time the probe
  budget clears, the stale verdict that banned the node has decayed too.
- Dead nodes (catalogue liveness) are forced to **banned**, so a later
  rejoin re-enters service through probing, never straight to ok.

Routing consumes the decision three ways: the engine's pull heap skips
avoided nodes (``route_avoid`` / ``probe_quota`` on
``run_job_batch_simulated``), brick failover prefers owners that are
neither dead nor banned (:func:`~repro.core.replication.failover_owner`
over ``dead | banned``), and the :class:`~repro.service.scheduler
.QueryScheduler` narrows admission windows by the routable fraction.
Availability always beats policy: if avoidance would starve a scan the
engine ignores it wholesale.

Sustained degradation (``rereplicate_after`` consecutive unhealthy
windows) triggers proactive re-replication: the policy treats the sick
node as already lost, runs
:func:`~repro.core.replication.rereplication_plan`, and applies the
copies to the store — so when the node *does* die, failover finds a
fresh replica instead of a hole.

Speculative re-execution of straggler packets rides the same decision
(``speculate`` / ``spec_lead_factor`` pass through to the engine);
see ``docs/policy.md`` for the first-result-wins correctness argument.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.brick import BrickStore
from repro.core.catalog import MetadataCatalog
from repro.core.replication import rereplication_plan
from repro.obs.health import (HEALTH_OK, HEALTH_SUSPECT, HealthReport)

POLICY_OK = "ok"
POLICY_DEGRADED = "degraded"
POLICY_PROBING = "probing"
POLICY_BANNED = "banned"
POLICY_STATES = (POLICY_OK, POLICY_DEGRADED, POLICY_PROBING, POLICY_BANNED)


@dataclasses.dataclass
class PolicyConfig:
    """Hysteresis thresholds and mitigation knobs (all counted in
    decision windows, i.e. calls to :meth:`FailurePolicy.decide`).

    ``rate_evidence`` gates the relative-rate classifications from the
    health report; with it off only failure-EWMA evidence (node deaths)
    counts — deterministic regardless of host wall-clock noise, which is
    what the scenario matrix runs with."""
    degrade_after: int = 2       # unhealthy windows before ok -> degraded
    recover_after: int = 2       # clean windows before degraded -> ok
    ban_after: int = 3           # suspect windows before degraded -> banned
    probe_after: int = 4         # banned dwell windows before probing
    probe_packets: int = 3       # probe quota per window / clean probes to ok
    rereplicate_after: int = 3   # unhealthy windows before re-replication
    failure_threshold: float = 0.3   # failure EWMA that reads as suspect
    rate_evidence: bool = True   # trust relative-rate classifications
    speculate: bool = True       # straggler speculative re-execution
    spec_lead_factor: float = 1.5    # min remaining/duplicate time ratio


@dataclasses.dataclass
class NodeState:
    """One node's position in the state machine plus its hysteresis
    counters (consecutive-window streaks, probe/ban bookkeeping)."""
    node: int
    state: str = POLICY_OK
    unhealthy: int = 0       # consecutive unhealthy windows (in ok)
    healthy: int = 0         # consecutive clean windows (in degraded)
    suspect_streak: int = 0  # consecutive suspect windows (in degraded)
    banned_for: int = 0      # dwell windows since ban
    probe_ok: int = 0        # clean probe packets observed
    degraded_run: int = 0    # windows spent not-ok (re-replication clock)
    rereplicated: bool = False   # this sickness episode already re-replicated


@dataclasses.dataclass
class PolicyDecision:
    """One window's routing verdict: nodes to avoid, per-node probe
    quotas, the transitions taken, and re-replication copies applied."""
    avoid: set = dataclasses.field(default_factory=set)
    probe_quota: Dict[int, int] = dataclasses.field(default_factory=dict)
    transitions: List[Tuple[int, str, str]] = \
        dataclasses.field(default_factory=list)
    rereplicated: List[Tuple[int, int, int]] = \
        dataclasses.field(default_factory=list)
    speculate: bool = False
    spec_lead_factor: float = 1.5

    def backend_kwargs(self) -> Dict:
        """Keyword arguments for a routing-capable backend's
        ``run_batch`` (``SimulatedBackend.supports_routing_policy``)."""
        return {"route_avoid": set(self.avoid),
                "probe_quota": dict(self.probe_quota),
                "speculate": self.speculate,
                "spec_lead_factor": self.spec_lead_factor,
                "rereplicated": list(self.rereplicated)}


class FailurePolicy:
    """Per-node state machine over health evidence, one decision per
    dispatch window.

    Drive it with :meth:`decide` (before ``run_batch``, feeding the
    current :class:`~repro.obs.health.HealthReport`) and
    :meth:`observe_window` (after, feeding the window's
    :class:`~repro.core.jse.JobStats` so probe outcomes resolve).  The
    service (:class:`~repro.service.frontend.QueryService`) does both
    when constructed with ``policy=``."""

    def __init__(self, catalog: MetadataCatalog, store: BrickStore, *,
                 obs=None, config: Optional[PolicyConfig] = None):
        self.catalog = catalog
        self.store = store
        self.obs = obs
        self.config = config or PolicyConfig()
        self.nodes: Dict[int, NodeState] = {
            n: NodeState(node=n) for n in range(store.n_nodes)}
        self.rereplications = 0
        # flight-recorder scope (repro.obs.flight.FlightScope); None =
        # off.  Records transitions, re-replication and decisions.
        self.flight = None

    # --------------------------- transitions -------------------------- #
    def _transition(self, st: NodeState, new: str,
                    decision: Optional[PolicyDecision] = None):
        old = st.state
        if old == new:
            return
        st.state = new
        st.unhealthy = st.healthy = st.suspect_streak = 0
        if new == POLICY_BANNED:
            st.banned_for = 0
        if new == POLICY_PROBING:
            st.probe_ok = 0
        if new == POLICY_OK:
            st.degraded_run = 0
            st.rereplicated = False
        if decision is not None:
            decision.transitions.append((st.node, old, new))
        if self.flight is not None:
            self.flight.record("policy_transition", node=st.node,
                               old=old, new=new)
        if self.obs is not None:
            self.obs.tracer.event(
                "policy_transition",
                t_virtual=self.obs.tracer.virtual_base,
                node=st.node, old=old, new=new)
            self.obs.metrics.counter(f"policy.to_{new}").inc()

    def _evidence(self, node: int, report: Optional[HealthReport]) -> str:
        """Map the report onto this node: suspect on failure evidence
        over threshold always; rate classifications only when trusted."""
        if report is None:
            return HEALTH_OK
        if report.failures.get(node, 0.0) >= self.config.failure_threshold:
            return HEALTH_SUSPECT
        if self.config.rate_evidence:
            return report.states.get(node, HEALTH_OK)
        return HEALTH_OK

    def _rereplicate(self, st: NodeState, decision: PolicyDecision):
        """Proactively restore the replication factor as if ``st.node``
        were already lost (its healthy copies remain valid sources)."""
        dead = set(self.catalog.dead_nodes()) | {st.node}
        copies = rereplication_plan(self.store.specs, dead,
                                    self.store.n_nodes)
        applied = []
        for bid, src, dst in copies:
            spec = self.store.specs[bid]
            if dst not in spec.replicas and dst != spec.node:
                spec.replicas = spec.replicas + (dst,)
                applied.append((bid, src, dst))
        st.rereplicated = True
        if applied:
            self.rereplications += 1
            decision.rereplicated.extend(applied)
            if self.flight is not None:
                self.flight.record("rereplicate", node=st.node,
                                   copies=len(applied))
            if self.obs is not None:
                self.obs.tracer.event(
                    "rereplicate",
                    t_virtual=self.obs.tracer.virtual_base,
                    node=st.node, copies=len(applied))
                self.obs.metrics.counter(
                    "policy.rereplications").inc(len(applied))

    # ----------------------------- driving ---------------------------- #
    def decide(self, report: Optional[HealthReport]) -> PolicyDecision:
        """Advance every node's state machine one window and return the
        routing decision (at most one transition per node per window —
        the hysteresis granularity)."""
        cfg = self.config
        decision = PolicyDecision(speculate=cfg.speculate,
                                  spec_lead_factor=cfg.spec_lead_factor)
        dead = set(self.catalog.dead_nodes())
        for node in sorted(self.nodes):
            st = self.nodes[node]
            if node in dead:
                # liveness is authoritative: a dead node is banned, so a
                # rejoin re-enters service through probing
                self._transition(st, POLICY_BANNED, decision)
                st.degraded_run += 1
                continue
            ev = self._evidence(node, report)
            if st.state == POLICY_OK:
                if ev == HEALTH_OK:
                    st.unhealthy = 0
                else:
                    st.unhealthy += 1
                    if st.unhealthy >= cfg.degrade_after:
                        self._transition(st, POLICY_DEGRADED, decision)
            elif st.state == POLICY_DEGRADED:
                st.degraded_run += 1
                if ev == HEALTH_SUSPECT:
                    st.suspect_streak += 1
                    st.healthy = 0
                    if st.suspect_streak >= cfg.ban_after:
                        self._transition(st, POLICY_BANNED, decision)
                elif ev == HEALTH_OK:
                    st.healthy += 1
                    st.suspect_streak = 0
                    if st.healthy >= cfg.recover_after:
                        self._transition(st, POLICY_OK, decision)
                else:
                    st.healthy = 0
            elif st.state == POLICY_BANNED:
                st.degraded_run += 1
                st.banned_for += 1
                if st.banned_for >= cfg.probe_after:
                    self._transition(st, POLICY_PROBING, decision)
            elif st.state == POLICY_PROBING:
                # the stale report that banned the node is ignored here:
                # probe outcomes (observe_window) are the only jury
                st.degraded_run += 1
            if st.state in (POLICY_DEGRADED, POLICY_BANNED) \
                    and st.degraded_run >= cfg.rereplicate_after \
                    and not st.rereplicated:
                self._rereplicate(st, decision)
        for node, st in self.nodes.items():
            if st.state == POLICY_BANNED:
                decision.avoid.add(node)
            elif st.state == POLICY_PROBING:
                decision.avoid.add(node)
                decision.probe_quota[node] = cfg.probe_packets
        if self.flight is not None and (decision.avoid
                                        or decision.transitions
                                        or decision.rereplicated):
            self.flight.record("policy_decide",
                               avoid=sorted(decision.avoid),
                               probes=sorted(decision.probe_quota),
                               speculate=decision.speculate)
        return decision

    def observe_window(self, stats) -> None:
        """Resolve probe outcomes from a window's execution telemetry:
        ``probe_packets`` clean packets on a probing node clear it."""
        by_node: Dict[int, int] = {}
        for t in getattr(stats, "packet_telemetry", ()):
            n = getattr(t, "node", -1)
            if n >= 0:
                by_node[n] = by_node.get(n, 0) + 1
        for node, st in self.nodes.items():
            if st.state != POLICY_PROBING:
                continue
            st.probe_ok += by_node.get(node, 0)
            if st.probe_ok >= self.config.probe_packets:
                self._transition(st, POLICY_OK)

    # ---------------------------- inspection -------------------------- #
    def states(self) -> Dict[int, str]:
        """Snapshot of every node's policy state."""
        return {n: st.state for n, st in sorted(self.nodes.items())}

    def routable_fraction(self) -> float:
        """Fraction of alive nodes the policy will route to (probing
        counts as routable — it holds quota)."""
        alive = self.catalog.alive_nodes()
        if not alive:
            return 1.0
        usable = [n for n in alive
                  if self.nodes[n].state != POLICY_BANNED]
        return len(usable) / len(alive)
