"""Multi-tenant query service over the GEPS grid-brick substrate:
shared-aggregate query planner (fragment factoring + cost model),
shared-scan batched execution, result cache, a concurrent job queue
with cost-budgeted admission and adaptive dispatch windows, and
streaming partial-merge result delivery (progressive histograms)."""
from repro.service.cache import CacheStats, ResultCache
from repro.service.frontend import (QUEUED, REJECTED, SERVED, QueryService,
                                    ServiceStats, Ticket, WindowController)
from repro.service.planner import (CostWeights, boolean_fragment_refs,
                                   cost_from_features, count_aggregates,
                                   estimate_cost, fit_cost_weights,
                                   plan_window, shared_boolean_fragments,
                                   window_cost)
from repro.service.scheduler import (AdmissionError, QueryScheduler,
                                     Submission, make_submission)
from repro.service.streaming import (ResultStream, StreamSnapshot,
                                     WindowStreamPublisher)

__all__ = [
    "AdmissionError", "CacheStats", "CostWeights", "QueryScheduler",
    "QueryService", "QUEUED", "REJECTED", "ResultCache", "ResultStream",
    "SERVED", "ServiceStats", "StreamSnapshot", "Submission", "Ticket",
    "WindowController", "WindowStreamPublisher", "boolean_fragment_refs",
    "cost_from_features", "count_aggregates", "estimate_cost",
    "fit_cost_weights", "make_submission", "plan_window",
    "shared_boolean_fragments", "window_cost",
]
