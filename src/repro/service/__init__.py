"""Multi-tenant query service over the GEPS grid-brick substrate:
shared-scan batched execution + result cache + concurrent job queue."""
from repro.service.cache import CacheStats, ResultCache
from repro.service.frontend import (QUEUED, REJECTED, SERVED, QueryService,
                                    ServiceStats, Ticket)
from repro.service.scheduler import (AdmissionError, QueryScheduler,
                                     Submission, make_submission)

__all__ = [
    "AdmissionError", "CacheStats", "QueryScheduler", "QueryService",
    "QUEUED", "REJECTED", "ResultCache", "SERVED", "ServiceStats",
    "Submission", "Ticket", "make_submission",
]
