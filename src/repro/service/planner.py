"""Shared-aggregate query planner: the layer between the scheduler's
dispatch window and shared-scan execution.

PR 1's coalescing dedups *identical* canonical queries; interactive HEP
analysis traffic (the DIAL regime) is dominated by *near*-duplicates —
the same expensive track aggregates under different outer scalar filters.
The planner closes that gap with three mechanisms:

1. **Common-subexpression factoring** — every subexpression of every
   pending query is canonicalized and hash-consed
   (:func:`repro.core.query.build_fragment_plan`); the resulting
   :class:`~repro.core.query.FragmentPlan` evaluates each unique fragment
   once per resident packet and reassembles per-query predicates from
   fragment outputs.  Per-query results stay bit-identical to unshared
   execution (same ops on same inputs, just computed once).

2. **Materialization policy** — shared boolean fragments (referenced by
   two or more queries in the window, e.g. a common ``count(pt > 30) >= 2``
   conjunct) are surfaced as first-class merged results so the service can
   install them in the result cache; a later query equal to such a
   fragment is answered with zero brick I/O.

3. **Cost model** — :func:`estimate_cost` scores a query as
   ``events x calibration work x per-event expression work`` (aggregates
   weighted by the track sweep they imply).  The scheduler uses it for
   per-tenant cost budgets; :func:`window_cost` totals a window.

The adaptive dispatch-window controller lives in
:class:`repro.service.frontend.WindowController` (it needs arrival/latency
telemetry only the front-end sees).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

from repro.core import query as query_lib

# ---------------------------- cost model --------------------------------- #
# Cost units are "per-event evaluation units": a pure scalar expression
# costs ~1 per event; every track aggregate adds a sweep over the padded
# tracks axis (AGG_WEIGHT events-equivalents); each calibration iteration
# multiplies the per-event work (the paper's compute-heavy refinement).
AGG_WEIGHT = 4.0
CALIB_WEIGHT = 1.0


def count_aggregates(node: query_lib.Node) -> int:
    """Number of track-aggregate occurrences in a query AST."""
    if isinstance(node, query_lib.Agg):
        return 1 + count_aggregates(node.arg)
    if isinstance(node, query_lib.Unary):
        return count_aggregates(node.arg)
    if isinstance(node, query_lib.Bin):
        return count_aggregates(node.lhs) + count_aggregates(node.rhs)
    return 0


def estimate_cost(expr_or_ast: Union[str, query_lib.Node], *,
                  n_events: int, calib_iters: int = 0) -> float:
    """Estimated cost of one query: events x calib work x aggregate depth.

    ``cost = n_events * (1 + CALIB_WEIGHT*calib_iters)
                      * (1 + AGG_WEIGHT*n_aggregates)``

    Deliberately coarse — it only has to rank queries well enough for
    admission budgets (a 6-aggregate calibrated query over the full store
    must cost more than a scalar cut), not predict wall-clock.
    """
    ast = (query_lib.parse(expr_or_ast)
           if isinstance(expr_or_ast, str) else expr_or_ast)
    per_event = 1.0 + AGG_WEIGHT * count_aggregates(ast)
    return float(n_events) * (1.0 + CALIB_WEIGHT * calib_iters) * per_event


def window_cost(exprs: Sequence[str], *, n_events: int,
                calib_iters: int = 0) -> float:
    """Total unshared cost of a window (what admission budgeting charges)."""
    return sum(estimate_cost(e, n_events=n_events, calib_iters=calib_iters)
               for e in exprs)


# ---------------------------- window planning ---------------------------- #
def shared_boolean_fragments(plan: query_lib.FragmentPlan,
                             *, min_refs: int = 2) -> List[query_lib.Node]:
    """Boolean-valued fragments referenced by >= ``min_refs`` distinct
    queries of the window, excluding whole-query roots (those are already
    cached under their own canonical key).  Only scalar-context fragments
    qualify — a track-context array is not a per-event mask.  Trivial
    fragments (bare comparisons of two leaves with no aggregate) are kept
    too: they are exactly the "shared ``count(pt > B)`` conjunct" shape the
    roadmap calls out, and materializing a mask we already computed is
    nearly free."""
    refs: dict = {}

    def walk(node, seen):
        if id(node) in seen:
            return
        seen.add(id(node))
        refs.setdefault(id(node), [0, node])
        refs[id(node)][0] += 1
        # do not descend into aggregates: their arguments are track-context
        if isinstance(node, query_lib.Agg):
            return
        if isinstance(node, query_lib.Unary):
            walk(node.arg, seen)
        elif isinstance(node, query_lib.Bin):
            walk(node.lhs, seen)
            walk(node.rhs, seen)

    for root in plan.roots:
        walk(root, set())  # fresh `seen` per root: refs = #roots referencing
    root_ids = {id(r) for r in plan.roots}
    out = []
    for nrefs, node in refs.values():
        if (nrefs >= min_refs and id(node) not in root_ids
                and query_lib.is_boolean(node)):
            out.append(node)
    # deterministic order for stable merge/caching downstream
    out.sort(key=query_lib.node_key)
    return out


def plan_window(exprs: Sequence[str], *, materialize: bool = True,
                max_materialized: int = 8,
                shared: bool = True) -> query_lib.FragmentPlan:
    """Build the fragment plan for one dispatch window.

    Factors common subexpressions across ``exprs`` (one entry per unique
    canonical query) and, when ``materialize`` is set, marks up to
    ``max_materialized`` shared boolean fragments for first-class
    materialization (largest first, so compound conjuncts win the budget
    over their own sub-comparisons).  ``shared=False`` builds the PR 1
    baseline plan (no cross-query factoring) for A/B measurement."""
    plan = query_lib.build_fragment_plan(exprs, shared=shared)
    if materialize and shared:
        cands = shared_boolean_fragments(plan)
        cands.sort(key=query_lib.count_occurrences, reverse=True)
        plan.materialize = cands[:max_materialized]
    return plan
