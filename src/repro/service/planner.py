"""Shared-aggregate query planner: the layer between the scheduler's
dispatch window and shared-scan execution.

PR 1's coalescing dedups *identical* canonical queries; interactive HEP
analysis traffic (the DIAL regime) is dominated by *near*-duplicates —
the same expensive track aggregates under different outer scalar filters.
The planner closes that gap with three mechanisms:

1. **Common-subexpression factoring** — every subexpression of every
   pending query is canonicalized and hash-consed
   (:func:`repro.core.query.build_fragment_plan`); the resulting
   :class:`~repro.core.query.FragmentPlan` evaluates each unique fragment
   once per resident packet and reassembles per-query predicates from
   fragment outputs.  Per-query results stay bit-identical to unshared
   execution (same ops on same inputs, just computed once).

2. **Materialization policy** — shared boolean fragments (referenced by
   two or more queries in the window, e.g. a common ``count(pt > 30) >= 2``
   conjunct) are surfaced as first-class merged results so the service can
   install them in the result cache; a later query equal to such a
   fragment is answered with zero brick I/O.

3. **Cost model** — :func:`estimate_cost` scores a query as
   ``events x calibration work x per-event expression work`` (aggregates
   weighted by the track sweep they imply).  The scheduler uses it for
   per-tenant cost budgets; :func:`window_cost` totals a window.

The adaptive dispatch-window controller lives in
:class:`repro.service.frontend.WindowController` (it needs arrival/latency
telemetry only the front-end sees).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import query as query_lib

# ---------------------------- cost model --------------------------------- #
# Cost units are "per-event evaluation units": a pure scalar expression
# costs ~1 per event; every track aggregate adds a sweep over the padded
# tracks axis (AGG_WEIGHT events-equivalents); each calibration iteration
# multiplies the per-event work (the paper's compute-heavy refinement).
# These module constants are the COLD-START PRIOR: `fit_cost_weights`
# replaces them with values regressed from measured per-packet compute
# once the service has telemetry.
AGG_WEIGHT = 4.0
CALIB_WEIGHT = 1.0


@dataclasses.dataclass(frozen=True)
class CostWeights:
    """One set of cost-model coefficients: the aggregate and calibration
    weights of :func:`estimate_cost`, plus the fitted per-event scale
    (seconds per event for a scalar, uncalibrated query — informational;
    admission budgets only need relative ranking).  ``fitted`` records
    whether the values came from telemetry or are the static prior."""
    agg_weight: float = AGG_WEIGHT
    calib_weight: float = CALIB_WEIGHT
    scale: float = 1.0
    fitted: bool = False


def fit_cost_weights(telemetry: Iterable, *,
                     prior: Optional[CostWeights] = None) -> CostWeights:
    """Least-squares fit of the cost-model weights from measured
    per-packet compute (ROADMAP: "Cost-model calibration").

    ``telemetry`` is an iterable of
    :class:`~repro.core.jse.PacketTelemetry` (or of
    :class:`~repro.core.jse.JobStats`, whose ``packet_telemetry`` lists
    are flattened).  A packet measurement covers the WHOLE window plan —
    ``wall_s`` evaluates every target and ``n_aggregates`` counts the
    plan's unique aggregates — so observations are first normalized per
    target (rate ``t/(size*targets)``, aggregate depth
    ``aggs/targets``); otherwise window width would be an omitted
    variable correlated with both and the fitted weights would be
    mis-scaled for single-query costing.  Fragment sharing makes the
    per-target attribution approximate, which is fine: admission only
    needs the weights to *rank* queries.  The normalized cost model is
    multiplicative::

        t / (size*targets) = k * (1 + c*calib) * (1 + a*aggs/targets)
                           = k + k*c*calib + k*a*A + k*c*a*(calib*A)

    (``A = aggs/targets``) which is LINEAR in the monomial basis
    ``[1, calib, A, calib*A]`` — so one ``lstsq`` solve recovers
    ``b0..b3`` and the weights follow as ``c = b1/b0``, ``a = b2/b0``.
    Degenerate designs fall back to the prior *per weight*: with no
    variation in observed ``calib`` there is nothing to identify ``c``
    from (ditto ``A`` and ``a``), and a non-positive base rate ``b0``
    rejects the whole fit.  The static module constants remain the
    cold-start prior."""
    prior = prior or CostWeights()
    obs = []
    for item in telemetry:
        rows = getattr(item, "packet_telemetry", None)
        obs.extend(rows if rows is not None else [item])
    obs = [o for o in obs if o.size > 0 and o.wall_s > 0]
    if len(obs) < 4:
        return prior
    targets = np.array([max(1, getattr(o, "n_targets", 1)) for o in obs],
                       np.float64)
    calib = np.array([o.calib_iters for o in obs], np.float64)
    aggs = np.array([o.n_aggregates for o in obs], np.float64) / targets
    rate = np.array([o.wall_s / o.size for o in obs], np.float64) / targets
    design = np.stack([np.ones_like(calib), calib, aggs, calib * aggs],
                      axis=1)
    coef, *_ = np.linalg.lstsq(design, rate, rcond=None)
    b0 = float(coef[0])
    if b0 <= 0:
        return prior
    calib_w = prior.calib_weight
    agg_w = prior.agg_weight
    if len(set(calib.tolist())) >= 2:
        calib_w = max(0.0, float(coef[1]) / b0)
    if len(set(aggs.tolist())) >= 2:
        agg_w = max(0.0, float(coef[2]) / b0)
    return CostWeights(agg_weight=agg_w, calib_weight=calib_w, scale=b0,
                       fitted=True)


def count_aggregates(node: query_lib.Node) -> int:
    """Number of track-aggregate occurrences in a query AST."""
    if isinstance(node, query_lib.Agg):
        return 1 + count_aggregates(node.arg)
    if isinstance(node, query_lib.Unary):
        return count_aggregates(node.arg)
    if isinstance(node, query_lib.Bin):
        return count_aggregates(node.lhs) + count_aggregates(node.rhs)
    return 0


def cost_from_features(n_events: int, calib_iters: int, n_aggregates: int,
                       *, weights: Optional[CostWeights] = None) -> float:
    """The cost model evaluated on pre-extracted features:

    ``cost = n_events * (1 + calib_weight*calib_iters)
                      * (1 + agg_weight*n_aggregates)``

    Pure arithmetic — callers that captured a query's features at
    admission (``Submission.n_events`` / ``n_aggregates``) can recost it
    under newly fitted weights without re-parsing; the scheduler's
    window-cost bounding does exactly that every dispatch."""
    w = weights or CostWeights()
    return (float(n_events) * (1.0 + w.calib_weight * calib_iters)
            * (1.0 + w.agg_weight * n_aggregates))


def estimate_cost(expr_or_ast: Union[str, query_lib.Node], *,
                  n_events: int, calib_iters: int = 0,
                  weights: Optional[CostWeights] = None) -> float:
    """Estimated cost of one query: events x calib work x aggregate depth
    (see :func:`cost_from_features` for the formula).

    ``weights`` defaults to the static module constants (the cold-start
    prior); the service passes its fitted :class:`CostWeights` once
    telemetry-based calibration has run.  Deliberately coarse — it only
    has to rank queries well enough for admission budgets (a 6-aggregate
    calibrated query over the full store must cost more than a scalar
    cut), not predict wall-clock.
    """
    ast = (query_lib.parse(expr_or_ast)
           if isinstance(expr_or_ast, str) else expr_or_ast)
    return cost_from_features(n_events, calib_iters, count_aggregates(ast),
                              weights=weights)


def window_cost(exprs: Sequence[str], *, n_events: int,
                calib_iters: int = 0) -> float:
    """Total unshared cost of a window (what admission budgeting charges)."""
    return sum(estimate_cost(e, n_events=n_events, calib_iters=calib_iters)
               for e in exprs)


# ---------------------------- window planning ---------------------------- #
def boolean_fragment_refs(plan: query_lib.FragmentPlan
                          ) -> List[Tuple[query_lib.Node, int]]:
    """Every boolean-valued scalar-context fragment of the window with the
    number of distinct query roots referencing it, whole-query roots
    excluded (those are already cached under their own canonical key),
    ordered deterministically by canonical key.  Only scalar-context
    fragments qualify — a track-context array is not a per-event mask.
    This is the shared walk behind both per-window materialization
    (:func:`shared_boolean_fragments`) and the fabric's cross-window
    fragment registry (which also heats single-reference fragments)."""
    refs: dict = {}

    def walk(node, seen):
        if id(node) in seen:
            return
        seen.add(id(node))
        refs.setdefault(id(node), [0, node])
        refs[id(node)][0] += 1
        # do not descend into aggregates: their arguments are track-context
        if isinstance(node, query_lib.Agg):
            return
        if isinstance(node, query_lib.Unary):
            walk(node.arg, seen)
        elif isinstance(node, query_lib.Bin):
            walk(node.lhs, seen)
            walk(node.rhs, seen)

    for root in plan.roots:
        walk(root, set())  # fresh `seen` per root: refs = #roots referencing
    root_ids = {id(r) for r in plan.roots}
    out = [(node, nrefs) for nrefs, node in refs.values()
           if id(node) not in root_ids and query_lib.is_boolean(node)]
    out.sort(key=lambda p: query_lib.node_key(p[0]))
    return out


def shared_boolean_fragments(plan: query_lib.FragmentPlan,
                             *, min_refs: int = 2) -> List[query_lib.Node]:
    """Boolean fragments referenced by >= ``min_refs`` distinct queries of
    the window (see :func:`boolean_fragment_refs` for what qualifies).
    Trivial fragments (bare comparisons of two leaves with no aggregate)
    are kept too: they are exactly the "shared ``count(pt > B)``
    conjunct" shape the roadmap calls out, and materializing a mask we
    already computed is nearly free."""
    return [node for node, nrefs in boolean_fragment_refs(plan)
            if nrefs >= min_refs]


def plan_window(exprs: Sequence[str], *, materialize: bool = True,
                max_materialized: int = 8, shared: bool = True,
                registry=None, metrics=None) -> query_lib.FragmentPlan:
    """Build the fragment plan for one dispatch window.

    Factors common subexpressions across ``exprs`` (one entry per unique
    canonical query) and, when ``materialize`` is set, marks up to
    ``max_materialized`` shared boolean fragments for first-class
    materialization (largest first, so compound conjuncts win the budget
    over their own sub-comparisons).  ``shared=False`` builds the PR 1
    baseline plan (no cross-query factoring) for A/B measurement.

    ``registry`` (a :class:`~repro.fabric.registry.FragmentRegistry`)
    enables cross-window pre-warming: the registry's hot fragments seed
    the window's interner BEFORE the queries are interned, and any hot
    fragment that actually occurs in this window is materialized even
    when only one query references it — its mask is a scan by-product,
    and caching it makes the next submission equal to it (on any fleet
    front-end) a zero-I/O hit.  Materialization never changes per-query
    results; the registry budget rides on top of ``max_materialized``.

    ``metrics`` (a :class:`repro.obs.metrics.MetricsRegistry`, or None)
    records the planner's share of the observability catalog: windows
    planned, unique-fragment evaluations per packet vs. what unshared
    execution would cost, and fragments marked for materialization."""
    interner = query_lib.Interner()
    hot_nodes: Dict[str, query_lib.Node] = {}
    if registry is not None and shared:
        hot_nodes = registry.seed_interner(interner)
    plan = query_lib.build_fragment_plan(exprs, shared=shared,
                                         interner=interner)
    if materialize and shared:
        cands = shared_boolean_fragments(plan)
        cands.sort(key=query_lib.count_occurrences, reverse=True)
        plan.materialize = cands[:max_materialized]
        if hot_nodes:
            chosen = {id(m) for m in plan.materialize}
            root_ids = {id(r) for r in plan.roots}
            present: set = set()
            for r in plan.roots:
                query_lib._reachable(r, False, present)
            reachable_ids = {nid for nid, ctx in present if not ctx}
            for key in sorted(hot_nodes):
                node = hot_nodes[key]
                if (id(node) in reachable_ids and id(node) not in chosen
                        and id(node) not in root_ids
                        and query_lib.is_boolean(node)):
                    plan.materialize.append(node)
                    chosen.add(id(node))
    if metrics is not None:
        metrics.counter("plan.windows").inc()
        metrics.counter("plan.fragment_evals").inc(plan.evals_per_batch)
        metrics.counter("plan.fragment_evals_unshared").inc(
            plan.unshared_evals)
        metrics.counter("plan.materialized").inc(len(plan.materialize))
    return plan
