"""Multi-tenant query front-end over the JSE/brick substrate.

The flow per dispatch window::

    submit(expr, tenant) --admission--> scheduler queues (per tenant,
                       |                count caps + cost budgets)
                       \\--cache hit--> answered with zero brick I/O
    step(): window = scheduler.next_batch()        (fairness + coalescing +
                                                    window-cost bounding)
            dedup identical canonical queries      (one execution, fan-out)
            planner.plan_window(uniques)           (fragment factoring +
                                                    materialization policy)
            backend.run_batch(jobs, plan=plan)     (ONE shared scan —
                                                    simulated grid OR SPMD
                                                    chunked shard scan,
                                                    each unique fragment
                                                    evaluated once/packet)
            results -> cache (queries AND shared fragments), tickets,
            catalog; WindowController observes scan latency and retunes
            scheduler.max_batch for the next window

The execution backend is pluggable (``core/backend.py``): the service
programs only against ``ExecutionBackend.run_batch``, so streaming, cache
write-through, cost-model calibration and window planning behave
identically whether the window runs on the virtual-time grid simulation
or as an SPMD chunked scan over the brick shards.

    streamed tickets additionally get per-packet prefix merges published
    into their ResultStream DURING the scan (service/streaming.py), with
    a final snapshot bit-identical to the batch result.

Everything lands in the existing ``MetadataCatalog`` job records (tenant +
batch id included), so failover, stragglers and persistence keep working
unchanged underneath the service.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Union

from repro.core import backend as backend_lib
from repro.core import merge as merge_lib
from repro.core.brick import BrickStore
from repro.core.catalog import DONE, FAILED, MetadataCatalog
from repro.core.jse import TimeModel
from repro.service import planner as planner_lib
from repro.service import streaming as streaming_lib
from repro.service.cache import ResultCache
from repro.service.scheduler import (AdmissionError, QueryScheduler,
                                     Submission, make_submission)

QUEUED, SERVED, REJECTED = "QUEUED", "SERVED", "REJECTED"


@dataclasses.dataclass
class Ticket:
    """Per-submission record a tenant polls via ``QueryService.result``.

    ``status`` moves QUEUED -> SERVED/REJECTED/FAILED; ``note`` carries the
    rejection/failure reason; ``from_cache`` marks zero-I/O answers and
    ``adopted`` answers taken from another front-end's in-flight lease
    stream (single-flight execution — also zero local I/O)."""
    ticket_id: int
    tenant: str
    expr: str
    calib_iters: int
    status: str = QUEUED
    job_id: int = -1
    batch_id: int = -1
    from_cache: bool = False
    result: Optional[merge_lib.QueryResult] = None
    note: str = ""
    streamed: bool = False  # progressive delivery via QueryService.stream()
    adopted: bool = False   # resolved from a remote lease owner's stream


@dataclasses.dataclass
class ServiceStats:
    """Service-lifetime counters (monotonic; see also ``ResultCache.stats``
    and the per-window history in ``QueryService.window_history``)."""
    submitted: int = 0
    served: int = 0
    rejected: int = 0
    cache_hits: int = 0
    batches: int = 0
    jobs_run: int = 0
    events_scanned: int = 0
    # planner accounting: unique-fragment evaluations actually performed
    # vs. what K independent per-query compiles would have performed
    # (fragment-cache installs are counted by ResultCache.stats)
    fragment_evals: int = 0
    fragment_evals_unshared: int = 0
    # single-flight accounting: tickets resolved by adopting a remote
    # lease owner's stream, and adoptions that had to fall back (owner
    # death/ban/epoch bump — resolved from cache or by rescanning)
    adopted: int = 0
    lease_fallbacks: int = 0


class WindowController:
    """EWMA controller for dispatch-window width.

    The queueing sweet spot for a batching server: a window should be
    about as wide as the number of arrivals during one scan, ``w = λ·L``
    (arrival rate x scan latency).  Narrower windows waste sweeps on
    near-empty batches; wider windows add queueing delay without extra
    amortization.  The controller tracks an EWMA of submission
    inter-arrival gaps and of observed scan latencies and proposes
    ``clamp(round(λ·L), min_window, max_window)``.

    Arrival timestamps and scan latencies must share ONE clock.  The
    simulated service feeds virtual-time scan makespans, so drive arrivals
    with a virtual clock too (``QueryService(clock=...)``); a wall-clock
    deployment feeds wall-clock latencies instead.

    ``hysteresis`` is a relative dead-band on the output: the held window
    only moves when the proposal differs from it by more than
    ``hysteresis x current``.  Under square-wave (bursty) arrivals the
    raw EWMA proposal straddles two widths and flaps every window —
    resizing churn with no amortization gain; the dead-band holds the
    width until the demand shift is real.  ``hysteresis=0`` reproduces
    the raw controller exactly.
    """

    def __init__(self, *, initial: int = 16, min_window: int = 1,
                 max_window: int = 256, alpha: float = 0.3,
                 hysteresis: float = 0.25):
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        if not (1 <= min_window <= max_window):
            raise ValueError("need 1 <= min_window <= max_window")
        if hysteresis < 0.0:
            raise ValueError("hysteresis must be >= 0")
        self.initial = initial
        self.min_window = min_window
        self.max_window = max_window
        self.alpha = alpha
        self.hysteresis = hysteresis
        self._interarrival: Optional[float] = None
        self._latency: Optional[float] = None
        self._last_arrival: Optional[float] = None
        self._held: Optional[int] = None

    def observe_arrival(self, t: float):
        """Record one submission at time ``t`` (controller clock units)."""
        if self._last_arrival is not None:
            gap = max(0.0, t - self._last_arrival)
            if self._interarrival is None:
                self._interarrival = gap
            else:
                self._interarrival = (self.alpha * gap
                                      + (1 - self.alpha) * self._interarrival)
        self._last_arrival = t

    def observe_scan(self, latency_s: float):
        """Record one dispatch window's measured scan latency."""
        if latency_s <= 0:
            return
        if self._latency is None:
            self._latency = latency_s
        else:
            self._latency = (self.alpha * latency_s
                             + (1 - self.alpha) * self._latency)

    @property
    def arrival_rate(self) -> Optional[float]:
        """Smoothed arrivals/second, or None before two arrivals."""
        if self._interarrival is None:
            return None
        return 1.0 / max(self._interarrival, 1e-9)

    @property
    def scan_latency(self) -> Optional[float]:
        """Smoothed scan latency (seconds), or None before one window."""
        return self._latency

    def window(self) -> int:
        """Window width for the next dispatch: the clamped ``λ·L``
        proposal, filtered through the hysteresis dead-band."""
        lam, lat = self.arrival_rate, self.scan_latency
        if lam is None or lat is None:
            target = max(self.min_window,
                         min(self.max_window, self.initial))
        else:
            target = max(self.min_window,
                         min(self.max_window, round(lam * lat)))
        if self._held is None or \
                abs(target - self._held) > self.hysteresis * self._held:
            self._held = target
        return self._held


@dataclasses.dataclass
class _Adoption:
    # one in-flight single-flight adoption: the dequeued submissions of a
    # canonical group riding a remote lease owner's proxied stream
    key: str
    owner: str
    subs: List[Submission]
    proxy: streaming_lib.ResultStream
    epoch: int
    fp: str
    adopted_round: int = 0   # bus round the adoption was made
    last_published: int = 0  # proxy progress at the last stall check
    checked_round: int = 0   # bus round of the last stall check


class QueryService:
    """Multi-tenant query service: tickets in, shared scans underneath.

    Public API: :meth:`submit` (admission + cache probe), :meth:`step`
    (one dispatch window), :meth:`drain` (windows until idle),
    :meth:`result` (ticket lookup), :meth:`stream` (progressive
    partial-merge delivery for tickets submitted with ``stream=True``).

    Parameters
    ----------
    store / catalog:
        The brick-sharded event store and the metadata catalogue (one is
        created when not supplied).
    backend:
        The execution backend dispatch windows run on: ``"sim"`` (the
        virtual-time grid simulation, default), ``"spmd"`` (the chunked
        streaming scan over brick shards), or a pre-built
        :class:`~repro.core.backend.ExecutionBackend` instance — which
        must be constructed over this service's ``store``; its catalogue
        is adopted when ``catalog`` is not passed and must match when it
        is.  Every service feature (streaming, caching, cost admission,
        window planning, telemetry refits) routes through the backend
        contract, so behaviour is backend-agnostic by construction.
    cache / scheduler:
        Injectable :class:`ResultCache` / :class:`QueryScheduler`; pass a
        scheduler with cost budgets for cost-based admission.
    window_controller:
        Optional :class:`WindowController`; when present the service feeds
        it arrival timestamps (from ``clock``) and per-window virtual scan
        makespans, and retunes ``scheduler.max_batch`` before each window.
    clock:
        Timestamp source for arrival telemetry (default
        ``time.monotonic``).  Use a virtual clock when replaying traffic
        so arrivals and the simulator's makespans share units.
    planner_materialize:
        Cache shared boolean fragments of each window as first-class
        results (fragment-level cache entries).
    stream_capacity:
        Buffer depth of each per-ticket
        :class:`~repro.service.streaming.ResultStream` (see
        ``submit(stream=True)`` / :meth:`stream`).
    registry:
        Optional :class:`~repro.fabric.registry.FragmentRegistry`: every
        window's plan is observed into it and seeds the next window's
        interner with cross-window hot fragments (fabric pre-warming).
    refit_cost_every:
        Every K dispatch windows, refit the admission cost model from
        accumulated per-packet compute telemetry
        (:func:`~repro.service.planner.fit_cost_weights`); ``None``
        keeps the static cold-start weights forever.
    stream_ramp:
        When a window has stream subscribers, cap its first packets at
        this many events (growing geometrically — see
        :class:`~repro.core.packets.AdaptivePacketScheduler`), so
        time-to-first-partial stays small WITHOUT disabling
        PROOF-adaptive sizing for the rest of the scan.  ``None``
        disables the ramp.
    frontend_id:
        Stable identity of this front-end inside a fleet (fabric gossip
        and stream fan-out address it by this id).
    backend_kwargs:
        Extra constructor kwargs for a string-selected backend — the
        SPMD performance knobs (``use_pallas``, ``interpret``,
        ``chunk_events``, ``adaptive_chunks``, ``mesh_devices``,
        ``autotune``, ``double_buffer``; see ``docs/backends.md``,
        "Performance tuning") or simulation extras.  Rejected alongside
        a pre-built backend instance, same as ``time_model``.
    obs:
        Optional :class:`repro.obs.Observability` bundle.  When present
        the service traces every ticket (submit/window/plan/dispatch/
        per-packet/stream/final spans on one deterministic virtual
        timeline), records the metric catalog of
        ``docs/observability.md``, feeds the per-node health monitor,
        and installs the bundle on the execution backend and scheduler.
        ``None`` (default) disables the whole plane — every
        instrumentation site is one ``is not None`` test.
    """

    #: sliding-window size of retained per-packet telemetry observations
    #: (cost refits only need recent history)
    TELEMETRY_WINDOW = 4096

    def __init__(self, store: BrickStore,
                 catalog: Optional[MetadataCatalog] = None, *,
                 backend: Union[str, "backend_lib.ExecutionBackend",
                                None] = None,
                 cache: Optional[ResultCache] = None,
                 scheduler: Optional[QueryScheduler] = None,
                 time_model: Optional[TimeModel] = None,
                 node_speed: Optional[Dict[int, float]] = None,
                 use_cache: bool = True,
                 window_controller: Optional[WindowController] = None,
                 clock: Callable[[], float] = time.monotonic,
                 planner_materialize: bool = True,
                 stream_capacity: int = 32,
                 registry=None,
                 refit_cost_every: Optional[int] = None,
                 stream_ramp: Optional[int] = None,
                 frontend_id: str = "fe0",
                 obs=None,
                 policy=None,
                 leases=None,
                 backend_kwargs: Optional[Dict[str, object]] = None):
        self.store = store
        if backend is not None and not isinstance(backend, str):
            # instance backend: it owns a catalogue/store pair already
            if backend.store is not store:
                raise ValueError(
                    "backend was built over a different brick store")
            if catalog is None:
                catalog = backend.catalog
            elif backend.catalog is not catalog:
                raise ValueError(
                    "backend and service must share one catalogue")
        self.catalog = catalog or MetadataCatalog(store.n_nodes)
        if backend is None or isinstance(backend, str):
            kind = backend or "sim"
            if kind != "sim" and (time_model is not None
                                  or node_speed is not None):
                raise ValueError(
                    "time_model/node_speed are simulation knobs; the "
                    f"{kind!r} backend would silently ignore them")
            kwargs = ({"time_model": time_model, "node_speed": node_speed}
                      if kind == "sim" else {})
            # performance knobs (use_pallas/interpret/chunk_events/
            # mesh_devices/autotune/...) pass straight through to the
            # chosen backend's constructor
            kwargs.update(backend_kwargs or {})
            backend = backend_lib.make_backend(kind, self.catalog, store,
                                               **kwargs)
        elif time_model is not None or node_speed is not None:
            raise ValueError(
                "pass time_model/node_speed when constructing the "
                "backend, not alongside a pre-built instance")
        elif backend_kwargs:
            raise ValueError(
                "pass backend tuning kwargs when constructing the "
                "backend, not alongside a pre-built instance")
        self.backend = backend
        # back-compat handle for simulation-tuning callers (None on
        # non-simulated backends)
        self.jse = getattr(backend, "engine", None)
        # `is not None`, NOT truthiness: an empty injected cache is falsy
        # (it has __len__) and must not be silently replaced
        self.cache = (cache if cache is not None
                      else ResultCache(catalog=self.catalog))
        self.scheduler = (scheduler if scheduler is not None
                          else QueryScheduler())
        if self.scheduler.backend is None:
            # the scheduler recosts queued submissions against the
            # backend's calibrated cost weights when bounding windows
            self.scheduler.backend = self.backend
        self.use_cache = use_cache
        self.window_controller = window_controller
        self.clock = clock
        self.planner_materialize = planner_materialize
        self.stream_capacity = stream_capacity
        self.registry = registry
        self.refit_cost_every = refit_cost_every
        self.stream_ramp = stream_ramp
        self.frontend_id = frontend_id
        self.cost_weights: Optional[planner_lib.CostWeights] = None
        self.tickets: Dict[int, Ticket] = {}
        self.streams: Dict[int, streaming_lib.ResultStream] = {}
        self.stats = ServiceStats()
        self.window_history: List[int] = []  # max_batch used per window
        self._telemetry: List = []  # per-packet compute, for cost refits
        self._next_ticket = 0
        self._next_batch = 0
        self._closed = False
        # observability plane: install the bundle on the execution
        # backend (per-packet spans/health) and the scheduler (advisory
        # health hints); the service's own virtual timeline accumulates
        # window makespans so every span shares one deterministic axis
        self.obs = obs
        self._virtual_now = 0.0
        self._stream_spans: Dict[int, object] = {}
        if obs is not None:
            if getattr(self.backend, "obs", "missing") is None:
                self.backend.obs = obs
            if getattr(self.scheduler, "obs", "missing") is None:
                self.scheduler.obs = obs
        # failure policy (service/policy.py): decided before each window
        # (routing avoidance + speculation on capable backends), resolved
        # after it (probe outcomes); the scheduler narrows admission by
        # the routable fraction
        self.policy = policy
        if policy is not None and \
                getattr(self.scheduler, "policy", "missing") is None:
            self.scheduler.policy = policy
        # single-flight leases (fabric/leases.py): scan intents are
        # announced at admission, remote leases adopted at dispatch, and
        # adoptions resolved by poll_adoptions (the Fleet pumps it).
        # None (standalone service / single_flight off) disables every
        # lease site, exactly like obs/policy.
        self.leases = leases
        self._adoptions: Dict[str, _Adoption] = {}
        if leases is not None and \
                getattr(self.scheduler, "leases", "missing") is None:
            # adopted submissions cost ~0 against window budgets
            self.scheduler.leases = leases

    # ------------------------------------------------------------------ #
    def submit(self, expr: str, *, tenant: str = "default",
               calib_iters: int = 0, stream: bool = False) -> int:
        """Accept (or reject) one query; returns a ticket id.

        Admission: the expression is validated and costed
        (``planner.estimate_cost`` over the store size), then checked
        against the scheduler's count caps and cost budgets.  Cache hits
        are answered immediately — the catalog still gets a job record
        (marked DONE, zero events processed) so the tenant's history is
        complete.  Rejections surface as ticket status REJECTED with the
        reason in ``note``; nothing raises.

        With ``stream=True`` the ticket additionally gets a
        :class:`~repro.service.streaming.ResultStream` (read it via
        :meth:`stream`): the dispatch window publishes an exact prefix
        merge + coverage after every packet, and the final snapshot is
        bit-identical to the batch result.  A cache hit streams a single
        final snapshot; a rejection aborts the stream with the reason."""
        tid = self._next_ticket
        self._next_ticket += 1
        ticket = Ticket(tid, tenant, expr, calib_iters, streamed=stream)
        self.tickets[tid] = ticket
        self.stats.submitted += 1
        obs = self.obs
        span = None
        if obs is not None:
            span = obs.tracer.begin("submit", t_virtual=self._virtual_now,
                                    ticket=tid, tenant=tenant,
                                    stream=stream)
        rs = None
        if stream:
            rs = streaming_lib.ResultStream(tid,
                                            capacity=self.stream_capacity)
            self.streams[tid] = rs
            if obs is not None:
                # the stream span lives until the stream closes (finish
                # OR abort — the on_close hook covers every path, so an
                # aborted stream can never leak an open span)
                self._stream_spans[tid] = obs.tracer.begin(
                    "stream", t_virtual=self._virtual_now, ticket=tid,
                    parent=span)
                rs.on_close(self._close_stream_span)
        try:
            sub = make_submission(tid, tenant, expr, calib_iters,
                                  self.store.schema,
                                  n_events=self.store.n_events,
                                  stream=stream,
                                  weights=self.cost_weights)
        except AdmissionError as e:
            ticket.status = REJECTED
            ticket.note = str(e)
            self.stats.rejected += 1
            if rs is not None:
                rs.abort(str(e))
            if obs is not None:
                obs.metrics.counter("submit.rejected").inc()
                obs.tracer.end(span, t_virtual=self._virtual_now,
                               status="error", note=str(e))
            return tid

        if self.use_cache:
            l2_before = self.cache.stats.l2_hits
            hit = self.cache.get(expr, calib_iters,
                                 self.catalog.dataset_epoch,
                                 canonical=sub.canonical)
            if hit is not None:
                jid = self.catalog.submit(expr, calib_iters,
                                          tuple(sorted(self.store.bricks)),
                                          tenant=tenant)
                self.catalog.update(jid, status=DONE, note="cache-hit",
                                    result={"n_selected": hit.n_selected,
                                            "n_processed": hit.n_processed,
                                            "sum_var": hit.sum_var})
                ticket.status = SERVED
                ticket.job_id = jid
                ticket.from_cache = True
                ticket.result = hit
                self.stats.served += 1
                self.stats.cache_hits += 1
                if rs is not None:
                    # zero-I/O answer: one final snapshot, complete coverage
                    rs.finish(streaming_lib.StreamSnapshot(
                        seq=0, result=hit,
                        coverage=merge_lib.Coverage(
                            events_scanned=hit.n_processed,
                            events_total=hit.n_processed),
                        t_virtual=0.0, final=True))
                if obs is not None:
                    # a cache hit is still a complete (short) ticket
                    # trace: tier-attributed metric, closed submit span,
                    # final event — never a telemetry bypass
                    tier = ("l2" if self.cache.stats.l2_hits > l2_before
                            else "l1")
                    obs.metrics.counter(f"cache.hits_{tier}").inc()
                    # a cache hit is a served ticket: tickets.served must
                    # reconcile with ServiceStats.served across the fleet
                    obs.metrics.counter("tickets.served").inc()
                    span.attrs["cache_tier"] = tier
                    obs.tracer.end(span, t_virtual=self._virtual_now)
                    obs.tracer.event("final", t_virtual=self._virtual_now,
                                     ticket=tid, outcome=SERVED,
                                     cached=True)
                return tid
            if obs is not None:
                obs.metrics.counter("cache.misses").inc()

        try:
            self.scheduler.enqueue(sub)
            # only queued work counts as an arrival: cache hits and
            # rejections never reach a dispatch window, and sizing the
            # window from them would defer scans past the lambda*L spot
            if self.window_controller is not None:
                self.window_controller.observe_arrival(self.clock())
            if self.leases is not None:
                # single-flight: announce the scan intent NOW, so by
                # dispatch time the fleet has resolved one owner per
                # duplicated canonical (deterministic bus-order tiebreak)
                self.leases.announce(sub.canonical, sub.calib_iters)
            if obs is not None:
                span.attrs["queued"] = True
                obs.tracer.end(span, t_virtual=self._virtual_now)
        except AdmissionError as e:
            ticket.status = REJECTED
            ticket.note = str(e)
            self.stats.rejected += 1
            if rs is not None:
                rs.abort(str(e))
            if obs is not None:
                obs.metrics.counter("submit.rejected").inc()
                obs.tracer.end(span, t_virtual=self._virtual_now,
                               status="error", note=str(e))
        return tid

    # ------------------------------------------------------------------ #
    def step(self, *, failure_script=None) -> List[int]:
        """Run one dispatch window; returns the ticket ids served
        SUCCESSFULLY (failed tickets resolve to status FAILED with the
        reason in their note, and are not returned).

        The window is deduplicated on canonical form, fragment-factored by
        the planner (each unique subexpression evaluated once per resident
        packet), and executed as ONE shared scan; shared boolean fragments
        the planner materialized are installed in the result cache
        alongside the per-query results.

        Tickets submitted with ``stream=True`` receive progressive
        snapshots *during* the scan: a
        :class:`~repro.service.streaming.WindowStreamPublisher` rides the
        JSE's per-packet hook, folds each column's partial into a prefix
        merge, and publishes exact intermediate results into every
        subscribed stream.  A DONE window closes the streams with a final
        snapshot bit-identical to the ticket result; a FAILED window
        aborts them without one."""
        if failure_script and not getattr(
                self.backend, "supports_failure_injection", False):
            # fail BEFORE dequeuing: a mid-dispatch error would strand
            # the window's tickets/streams with no way to re-run them
            raise ValueError(
                "this execution backend does not support failure "
                "injection (failure scripts are a simulated-grid "
                "concept)")
        if self.window_controller is not None:
            self.scheduler.max_batch = self.window_controller.window()
        # failure policy: one decision per window, from the freshest
        # health evidence (local + gossip-merged); the scheduler's
        # next_batch narrows admission by the resulting routable fraction
        decision = None
        if self.policy is not None:
            report = (self.obs.health.report()
                      if self.obs is not None else None)
            if self.obs is not None:
                # transition/rereplicate events land on the service's
                # virtual timeline, not at 0 (reset after the dispatch)
                self.obs.tracer.virtual_base = self._virtual_now
            try:
                decision = self.policy.decide(report)
            finally:
                if self.obs is not None:
                    self.obs.tracer.virtual_base = 0.0
        window = self.scheduler.next_batch()
        if not window:
            return []
        if self.leases is not None:
            # single-flight: a canonical group another front-end holds a
            # fresh lease on is ADOPTED — its tickets ride the owner's
            # in-flight stream (fan-out buffered-prefix replay, zero
            # local I/O) and resolve in poll_adoptions; only what is
            # left dispatches as our own scan
            keep: List[Submission] = []
            byc: "OrderedDict[str, List[Submission]]" = OrderedDict()
            for sub in window:
                byc.setdefault(sub.canonical, []).append(sub)
            for canonical, subs in byc.items():
                key = self.leases.key_for(canonical, subs[0].calib_iters)
                owner = self.leases.holder(key)
                if owner is not None and owner != self.leases.node_id:
                    self._adopt(key, owner, subs)
                else:
                    keep.extend(subs)
            window = keep
            if not window:
                return []
        self.window_history.append(self.scheduler.max_batch)
        batch_id = self._next_batch
        self._next_batch += 1
        self.stats.batches += 1
        obs = self.obs
        wspan = None
        if obs is not None:
            wspan = obs.tracer.begin("window", t_virtual=self._virtual_now,
                                     batch=batch_id, queries=len(window))
            obs.tracer.push(wspan)
            obs.metrics.counter("window.dispatched").inc()
            obs.metrics.histogram("window.queries").observe(len(window))

        # dedup: identical canonical queries execute once, fan out to all
        groups: "OrderedDict[str, List[Submission]]" = OrderedDict()
        for sub in window:
            groups.setdefault(sub.canonical, []).append(sub)

        # fragment factoring across the window's unique queries; the
        # fabric registry (when present) seeds the interner with
        # cross-window hot fragments and pre-warms their materialization
        pspan = None
        if obs is not None:
            pspan = obs.tracer.begin("plan", t_virtual=self._virtual_now,
                                     batch=batch_id, unique=len(groups))
        plan = planner_lib.plan_window(
            list(groups), materialize=self.planner_materialize
            and self.use_cache, registry=self.registry,
            metrics=None if obs is None else obs.metrics)
        if obs is not None:
            pspan.attrs["materialized"] = len(plan.materialize)
            obs.tracer.end(pspan, t_virtual=self._virtual_now)
        if self.registry is not None:
            self.registry.observe_plan(plan)

        bricks = tuple(sorted(self.store.bricks))
        epoch = self.catalog.dataset_epoch
        job_ids = []
        for canonical, subs in groups.items():
            rep = subs[0]
            jid = self.catalog.submit(
                rep.expr, rep.calib_iters, bricks, tenant=rep.tenant,
                batch_id=batch_id)
            job_ids.append(jid)
        # streaming: per-column prefix-merge publisher over the subscribed
        # tickets of this window (dedup fan-out included); columns with no
        # subscriber cost nothing
        publisher = None
        col_streams = [[self.streams[s.ticket] for s in subs
                        if s.ticket in self.streams]
                       for subs in groups.values()]
        # single-flight owner side: export one lease stream per query
        # column we are scanning, plus one per materialized fragment
        # (fragment columns align with the plan's partials layout —
        # roots first, then materialize order), so adoptees receive the
        # bit-identical per-packet prefix stream with zero I/O
        window_leases: List[str] = []
        if self.leases is not None:
            calib_w = window[0].calib_iters
            for ci, canonical in enumerate(groups):
                key = self.leases.key_for(canonical, calib_w)
                es = streaming_lib.ResultStream(
                    key, capacity=self.stream_capacity)
                self.leases.export(key, es)
                col_streams[ci].append(es)
                window_leases.append(key)
            for fk in plan.materialize_keys():
                key = self.leases.announce(fk, calib_w)
                es = streaming_lib.ResultStream(
                    key, capacity=self.stream_capacity)
                self.leases.export(key, es)
                col_streams.append([es])
                window_leases.append(key)
        if any(col_streams):
            publisher = streaming_lib.WindowStreamPublisher(
                col_streams,
                events_total=sum(self.store.specs[b].n_events
                                 for b in bricks),
                bricks_total=len(bricks), obs=obs)
        # stream-aware packet sizing: a window someone is streaming gets
        # the small-early/growing-later ramp (fast first partial) while
        # keeping PROOF-adaptive sizing for the bulk of the scan
        dspan = None
        if obs is not None:
            dspan = obs.tracer.begin("dispatch",
                                     t_virtual=self._virtual_now,
                                     batch=batch_id, jobs=len(job_ids))
            # per-packet spans from the engine nest under this dispatch
            # and land on the service's cumulative virtual timeline
            obs.tracer.push(dspan)
            obs.tracer.virtual_base = self._virtual_now
        routing_kwargs = {}
        if decision is not None and getattr(
                self.backend, "supports_routing_policy", False):
            routing_kwargs = decision.backend_kwargs()
        try:
            merged, stats = self.backend.run_batch(
                job_ids, failure_script=failure_script, plan=plan,
                on_partial=publisher.on_partial if publisher is not None
                else None,
                packet_ramp=self.stream_ramp if publisher is not None
                else None,
                **routing_kwargs)
        finally:
            if obs is not None:
                obs.tracer.virtual_base = 0.0
        if self.policy is not None:
            # resolve probe outcomes from this window's telemetry (any
            # resulting transition stamps at the window's end time)
            if obs is not None:
                obs.tracer.virtual_base = \
                    self._virtual_now + stats.makespan_s
            try:
                self.policy.observe_window(stats)
            finally:
                if obs is not None:
                    obs.tracer.virtual_base = 0.0
        if obs is not None:
            ok_all = all(self.catalog.jobs[j].status == DONE
                         for j in job_ids)
            self._virtual_now += stats.makespan_s
            obs.tracer.end(dspan, t_virtual=self._virtual_now,
                           status="ok" if ok_all else "error")
            obs.tracer.pop()
            obs.metrics.histogram("window.makespan_s").observe(
                stats.makespan_s)
            if getattr(self.backend, "obs", None) is not obs:
                # backend without native instrumentation (a custom
                # ExecutionBackend): fall back to feeding metrics and
                # health from the telemetry the contract guarantees
                for t in stats.packet_telemetry:
                    obs.metrics.counter("packet.count").inc()
                    obs.metrics.histogram("packet.latency_s").observe(
                        t.wall_s)
                    obs.metrics.histogram("packet.events").observe(t.size)
                    obs.health.observe_packet(getattr(t, "node", -1),
                                              t.size, t.wall_s)
        self.stats.jobs_run += len(job_ids)
        self.stats.events_scanned += stats.events_scanned
        self.stats.fragment_evals += stats.fragment_evals
        self.stats.fragment_evals_unshared += stats.fragment_evals_unshared
        if self.window_controller is not None:
            self.window_controller.observe_scan(stats.makespan_s)
        if self.refit_cost_every:
            # accumulate per-packet compute and periodically refit the
            # admission cost model (static weights stay the cold prior).
            # Keep a bounded sliding window: the fit only needs recent
            # telemetry, and a long-lived service must not grow (or
            # re-fit) an unbounded history.
            self._telemetry.extend(stats.packet_telemetry)
            del self._telemetry[:-self.TELEMETRY_WINDOW]
            if self.stats.batches % self.refit_cost_every == 0:
                self.cost_weights = planner_lib.fit_cost_weights(
                    self._telemetry, prior=self.cost_weights)
                # calibrated weights live on the backend too: the
                # scheduler's window-cost bounding recosts queued work
                # against the backend it dispatches to
                self.backend.cost_weights = self.cost_weights

        calib = window[0].calib_iters
        served = []
        batch_ok = all(self.catalog.jobs[j].status == DONE for j in job_ids)
        if publisher is not None:
            if batch_ok:
                # final snapshot IS the batch-merged result object (the
                # prefix property guarantees the accumulator agrees);
                # with lease exports the fragment columns get their
                # merged fragment results, same order as the plan
                finals = list(merged)
                if self.leases is not None:
                    finals += [stats.fragment_results[k]
                               for k in plan.materialize_keys()]
                publisher.finish(finals, stats.makespan_s)
            else:
                publisher.abort(self.catalog.jobs[job_ids[0]].note)
        for (canonical, subs), jid, res in zip(groups.items(), job_ids,
                                               merged):
            ok = self.catalog.jobs[jid].status == DONE
            if ok and self.use_cache:
                self.cache.put(subs[0].expr, subs[0].calib_iters, epoch, res,
                               canonical=canonical)
            for sub in subs:
                ticket = self.tickets[sub.ticket]
                ticket.job_id = jid
                ticket.batch_id = batch_id
                ticket.result = res if ok else None
                ticket.status = SERVED if ok else FAILED
                ticket.note = "" if ok else self.catalog.jobs[jid].note
                if ok:
                    self.stats.served += 1
                    served.append(sub.ticket)
                if obs is not None:
                    obs.tracer.event(
                        "final", t_virtual=self._virtual_now,
                        ticket=sub.ticket, batch=batch_id,
                        outcome=ticket.status)
                    obs.metrics.counter(
                        "tickets.served" if ok
                        else "tickets.failed").inc()
        # fragment-level cache entries: a future query equal to a shared
        # conjunct of this window is then a zero-I/O hit
        if batch_ok and self.use_cache:
            for frag_key, frag_res in stats.fragment_results.items():
                self.cache.put_fragment(frag_key, calib, epoch, frag_res)
        # single-flight: the window resolved (DONE or FAILED), release
        # its leases — adoptees still waiting get the release promptly
        # instead of waiting out the TTL; finished exports stay readable
        # for late subscribers until the lease GC reclaims them
        if self.leases is not None:
            for key in window_leases:
                self.leases.release(key)
        if obs is not None:
            obs.tracer.end(wspan, t_virtual=self._virtual_now,
                           status="ok" if batch_ok else "error")
            obs.tracer.pop()
        return served

    def drain(self, *, max_windows: int = 10_000) -> List[int]:
        """Dispatch windows until no work is pending (bounded by
        ``max_windows``); returns every ticket id served successfully
        across those windows."""
        served: List[int] = []
        for _ in range(max_windows):
            if self.scheduler.n_pending == 0:
                break
            served.extend(self.step())
        return served

    # ------------------------- single-flight -------------------------- #
    @property
    def adoptions_pending(self) -> bool:
        """True while any adopted canonical group is still waiting for
        its remote lease owner's final (or for fallback)."""
        return bool(self._adoptions)

    def _adopt(self, key: str, owner: str,
               subs: List[Submission]) -> None:
        """Attach a dequeued canonical group to a remote owner's lease
        stream: proxy it through the fan-out, withdraw our own intent,
        and mirror live proxy snapshots into the group's ticket streams
        (non-final only — an adopted partial is NEVER surfaced as
        final; the final lands in :meth:`_resolve_adoption`)."""
        self.leases.withdraw(key)
        ad = self._adoptions.get(key)
        if ad is not None:
            # a later window re-adopted the same key: the new tickets
            # catch up on the buffered prefix, then ride the live feed
            for snap in ad.proxy.buffered():
                if not snap.final:
                    self._mirror(ad, snap)
            ad.subs.extend(subs)
            return
        proxy = self.leases.fanout.proxy(key, owner)
        ad = _Adoption(key=key, owner=owner, subs=list(subs), proxy=proxy,
                       epoch=self.catalog.dataset_epoch,
                       fp=self.leases.current_fp(),
                       adopted_round=self.leases.bus.round,
                       checked_round=self.leases.bus.round)
        self._adoptions[key] = ad
        proxy.subscribe(lambda snap, a=ad: None if snap.final
                        else self._mirror(a, snap))
        self.stats.adopted += len(subs)
        if self.leases.flight is not None:
            self.leases.flight.record("lease_adopt", key=key, owner=owner,
                                      tickets=[s.ticket for s in subs])
        if self.obs is not None:
            self.obs.metrics.counter("lease.adopted").inc(len(subs))
            for sub in subs:
                self.obs.tracer.event(
                    "lease_adopt", t_virtual=self._virtual_now,
                    ticket=sub.ticket, owner=owner)

    def _mirror(self, ad: _Adoption, snap) -> None:
        # forward one non-final owner snapshot into the adopted tickets'
        # streams (same snapshot object: bit-identical prefixes)
        for sub in ad.subs:
            rs = self.streams.get(sub.ticket)
            if rs is not None:
                rs.publish(snap)

    def poll_adoptions(self) -> None:
        """Advance every pending adoption (the Fleet calls this each
        fabric round): a DONE proxy under a still-current epoch resolves
        its tickets from the owner's final; an aborted proxy, an
        expired/released/revoked lease, or a mid-stream epoch bump falls
        back — shared-cache re-probe first (the owner's completed result
        is reachable in-process even across a bus partition), own rescan
        on a miss.  A stalled-but-fresh adoption re-subscribes, healing
        snapshots a partition dropped."""
        if self.leases is None:
            return
        for key in list(self._adoptions):
            ad = self._adoptions.get(key)
            if ad is None:
                continue
            if ad.proxy.done:
                if self.leases.fp_current(ad.fp):
                    self._resolve_adoption(ad)
                else:
                    self._fallback(ad, "epoch bumped mid-adoption")
            elif ad.proxy.state == streaming_lib.ABORTED:
                self._fallback(ad, f"owner aborted: {ad.proxy.note}")
            elif not self.leases.fp_current(ad.fp):
                self._fallback(ad, "epoch bumped mid-adoption")
            else:
                owner_now = self.leases.holder(key)
                if owner_now != ad.owner \
                        and not self.leases.released_recently(key):
                    self._fallback(ad, "lease lost (owner dead or "
                                       "banned mid-stream)")
                    continue
                rnd = self.leases.bus.round
                if rnd - ad.checked_round >= self.leases.ttl:
                    ad.checked_round = rnd
                    if ad.proxy.published == ad.last_published:
                        # fresh lease but no progress for a full TTL:
                        # re-subscribe — the owner replays its buffered
                        # prefix (and final, if any), healing whatever a
                        # partition dropped
                        self.leases.fanout.resubscribe(key, ad.owner)
                    ad.last_published = ad.proxy.published

    def _resolve_adoption(self, ad: _Adoption) -> None:
        final = ad.proxy.latest()
        res = final.result
        self._adoptions.pop(ad.key, None)
        self.leases.fanout.release(ad.key)
        if self.use_cache:
            # same write-through as a local scan: later duplicates are
            # L1 hits here and zero-I/O everywhere via L2
            self.cache.put(ad.subs[0].expr, ad.subs[0].calib_iters,
                           ad.epoch, res, canonical=ad.subs[0].canonical)
        for sub in ad.subs:
            jid = self.catalog.submit(sub.expr, sub.calib_iters,
                                      tuple(sorted(self.store.bricks)),
                                      tenant=sub.tenant)
            self.catalog.update(jid, status=DONE, note="adopted",
                                result={"n_selected": res.n_selected,
                                        "n_processed": res.n_processed,
                                        "sum_var": res.sum_var})
            ticket = self.tickets[sub.ticket]
            ticket.status = SERVED
            ticket.job_id = jid
            ticket.adopted = True
            ticket.result = res
            ticket.note = f"adopted from {ad.owner}"
            self.stats.served += 1
            rs = self.streams.get(sub.ticket)
            if rs is not None:
                rs.finish(final)  # the owner's final snapshot, verbatim
            if self.obs is not None:
                self.obs.metrics.counter("tickets.served").inc()
                self.obs.tracer.event(
                    "final", t_virtual=self._virtual_now,
                    ticket=sub.ticket, outcome=SERVED, adopted=True)

    def _fallback(self, ad: _Adoption, reason: str) -> None:
        self._adoptions.pop(ad.key, None)
        self.leases.fanout.release(ad.key)
        self.stats.lease_fallbacks += 1
        if self.leases.flight is not None:
            self.leases.flight.record(
                "lease_fallback", key=ad.key, owner=ad.owner,
                reason=reason, tickets=[s.ticket for s in ad.subs])
        if self.obs is not None:
            self.obs.metrics.counter("lease.fallbacks").inc()
            self.obs.tracer.event("lease_fallback",
                                  t_virtual=self._virtual_now,
                                  note=reason)
        sub0 = ad.subs[0]
        hit = (self.cache.get(sub0.expr, sub0.calib_iters,
                              self.catalog.dataset_epoch,
                              canonical=sub0.canonical)
               if self.use_cache else None)
        if hit is not None:
            # the owner finished (its result is in the shared tier) but
            # the final/release never reached us: a zero-I/O resolve —
            # "never lose a final" without a duplicate scan
            for sub in ad.subs:
                jid = self.catalog.submit(sub.expr, sub.calib_iters,
                                          tuple(sorted(self.store.bricks)),
                                          tenant=sub.tenant)
                self.catalog.update(
                    jid, status=DONE, note="adopted (cache fallback)",
                    result={"n_selected": hit.n_selected,
                            "n_processed": hit.n_processed,
                            "sum_var": hit.sum_var})
                ticket = self.tickets[sub.ticket]
                ticket.status = SERVED
                ticket.job_id = jid
                ticket.adopted = True
                ticket.from_cache = True
                ticket.result = hit
                ticket.note = f"adopted via cache ({reason})"
                self.stats.served += 1
                self.stats.cache_hits += 1
                rs = self.streams.get(sub.ticket)
                if rs is not None:
                    rs.finish(streaming_lib.StreamSnapshot(
                        seq=0, result=hit,
                        coverage=merge_lib.Coverage(
                            events_scanned=hit.n_processed,
                            events_total=hit.n_processed),
                        t_virtual=0.0, final=True))
                if self.obs is not None:
                    self.obs.metrics.counter("tickets.served").inc()
            return
        # genuine fallback: requeue for our own scan and re-announce a
        # fresh intent — N-1 simultaneous fallbacks re-race and resolve
        # to exactly one rescanner, the others re-adopt
        for sub in ad.subs:
            self.scheduler.requeue(sub)
        self.leases.announce(sub0.canonical, sub0.calib_iters)

    # ------------------------------------------------------------------ #
    def result(self, ticket_id: int) -> Ticket:
        """Look up the :class:`Ticket` for a submission (KeyError if the
        id was never issued)."""
        return self.tickets[ticket_id]

    def stream(self, ticket_id: int) -> streaming_lib.ResultStream:
        """Look up the :class:`~repro.service.streaming.ResultStream` of a
        ticket submitted with ``stream=True`` (KeyError otherwise)."""
        return self.streams[ticket_id]

    def close(self) -> None:
        """Shut the service down: detach the result cache's invalidation
        hook from the catalogue (a long-lived catalogue must not keep
        every cache ever attached alive through its hook list) and abort
        any still-open streams so no tenant waits on a final that will
        never come.  Idempotent; the service must not be used after."""
        if self._closed:
            return
        self._closed = True
        self.cache.detach()
        for rs in self.streams.values():
            rs.abort("service closed")

    def _close_stream_span(self, stream) -> None:
        """Stream ``on_close`` hook: close the ticket's stream span with
        the stream's terminal state (error on ABORTED — rejected tickets,
        truncated scans and service shutdown all land here, so no path
        leaks an open span)."""
        span = self._stream_spans.pop(stream.ticket_id, None)
        if span is None or self.obs is None:
            return
        if stream.state == streaming_lib.ABORTED:
            self.obs.tracer.end(span, t_virtual=self._virtual_now,
                                status="error", note=stream.note)
        else:
            self.obs.tracer.end(span, t_virtual=self._virtual_now)

    def release_stream(self, ticket_id: int) -> None:
        """Drop a finished consumer's stream (and its buffered snapshots)
        from the service.  Streams — like tickets — live for the service
        lifetime by default so late readers can still drain them; a
        long-running tenant loop should release each stream once read.
        No-op if the ticket has no stream; the ticket itself (and its
        final ``result``) is unaffected."""
        self.streams.pop(ticket_id, None)
