"""Multi-tenant query front-end over the JSE/brick substrate.

The flow per dispatch window::

    submit(expr, tenant) --admission--> scheduler queues (per tenant)
                       \\--cache hit--> answered with zero brick I/O
    step(): window = scheduler.next_batch()        (fairness + coalescing)
            dedup identical canonical queries      (one execution, fan-out)
            jse.run_job_batch_simulated(jobs)      (ONE shared scan)
            results -> cache, tickets, catalog

Everything lands in the existing ``MetadataCatalog`` job records (tenant +
batch id included), so failover, stragglers and persistence keep working
unchanged underneath the service.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional

from repro.core import merge as merge_lib
from repro.core.brick import BrickStore
from repro.core.catalog import DONE, FAILED, MetadataCatalog
from repro.core.jse import JobSubmissionEngine, TimeModel
from repro.service.cache import ResultCache
from repro.service.scheduler import (AdmissionError, QueryScheduler,
                                     Submission, make_submission)

QUEUED, SERVED, REJECTED = "QUEUED", "SERVED", "REJECTED"


@dataclasses.dataclass
class Ticket:
    ticket_id: int
    tenant: str
    expr: str
    calib_iters: int
    status: str = QUEUED
    job_id: int = -1
    batch_id: int = -1
    from_cache: bool = False
    result: Optional[merge_lib.QueryResult] = None
    note: str = ""


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    served: int = 0
    rejected: int = 0
    cache_hits: int = 0
    batches: int = 0
    jobs_run: int = 0
    events_scanned: int = 0


class QueryService:
    def __init__(self, store: BrickStore,
                 catalog: Optional[MetadataCatalog] = None, *,
                 cache: Optional[ResultCache] = None,
                 scheduler: Optional[QueryScheduler] = None,
                 time_model: Optional[TimeModel] = None,
                 node_speed: Optional[Dict[int, float]] = None,
                 use_cache: bool = True):
        self.store = store
        self.catalog = catalog or MetadataCatalog(store.n_nodes)
        self.jse = JobSubmissionEngine(self.catalog, store,
                                       time_model=time_model,
                                       node_speed=node_speed)
        self.cache = cache or ResultCache(catalog=self.catalog)
        self.scheduler = scheduler or QueryScheduler()
        self.use_cache = use_cache
        self.tickets: Dict[int, Ticket] = {}
        self.stats = ServiceStats()
        self._next_ticket = 0
        self._next_batch = 0

    # ------------------------------------------------------------------ #
    def submit(self, expr: str, *, tenant: str = "default",
               calib_iters: int = 0) -> int:
        """Accept (or reject) one query; returns a ticket id.

        Cache hits are answered immediately — the catalog still gets a job
        record (marked DONE, zero events processed) so the tenant's history
        is complete."""
        tid = self._next_ticket
        self._next_ticket += 1
        ticket = Ticket(tid, tenant, expr, calib_iters)
        self.tickets[tid] = ticket
        self.stats.submitted += 1
        try:
            sub = make_submission(tid, tenant, expr, calib_iters,
                                  self.store.schema)
        except AdmissionError as e:
            ticket.status = REJECTED
            ticket.note = str(e)
            self.stats.rejected += 1
            return tid

        if self.use_cache:
            hit = self.cache.get(expr, calib_iters,
                                 self.catalog.dataset_epoch,
                                 canonical=sub.canonical)
            if hit is not None:
                jid = self.catalog.submit(expr, calib_iters,
                                          tuple(sorted(self.store.bricks)),
                                          tenant=tenant)
                self.catalog.update(jid, status=DONE, note="cache-hit",
                                    result={"n_selected": hit.n_selected,
                                            "n_processed": hit.n_processed,
                                            "sum_var": hit.sum_var})
                ticket.status = SERVED
                ticket.job_id = jid
                ticket.from_cache = True
                ticket.result = hit
                self.stats.served += 1
                self.stats.cache_hits += 1
                return tid

        try:
            self.scheduler.enqueue(sub)
        except AdmissionError as e:
            ticket.status = REJECTED
            ticket.note = str(e)
            self.stats.rejected += 1
        return tid

    # ------------------------------------------------------------------ #
    def step(self, *, failure_script=None) -> List[int]:
        """Run one dispatch window; returns the ticket ids served
        SUCCESSFULLY (failed tickets resolve to status FAILED with the
        reason in their note, and are not returned)."""
        window = self.scheduler.next_batch()
        if not window:
            return []
        batch_id = self._next_batch
        self._next_batch += 1
        self.stats.batches += 1

        # dedup: identical canonical queries execute once, fan out to all
        groups: "OrderedDict[str, List[Submission]]" = OrderedDict()
        for sub in window:
            groups.setdefault(sub.canonical, []).append(sub)

        bricks = tuple(sorted(self.store.bricks))
        epoch = self.catalog.dataset_epoch
        job_ids = []
        for canonical, subs in groups.items():
            rep = subs[0]
            jid = self.catalog.submit(
                rep.expr, rep.calib_iters, bricks, tenant=rep.tenant,
                batch_id=batch_id)
            job_ids.append(jid)
        merged, stats = self.jse.run_job_batch_simulated(
            job_ids, failure_script=failure_script)
        self.stats.jobs_run += len(job_ids)
        self.stats.events_scanned += stats.events_scanned

        served = []
        for (canonical, subs), jid, res in zip(groups.items(), job_ids,
                                               merged):
            ok = self.catalog.jobs[jid].status == DONE
            if ok and self.use_cache:
                self.cache.put(subs[0].expr, subs[0].calib_iters, epoch, res,
                               canonical=canonical)
            for sub in subs:
                ticket = self.tickets[sub.ticket]
                ticket.job_id = jid
                ticket.batch_id = batch_id
                ticket.result = res if ok else None
                ticket.status = SERVED if ok else FAILED
                ticket.note = "" if ok else self.catalog.jobs[jid].note
                if ok:
                    self.stats.served += 1
                    served.append(sub.ticket)
        return served

    def drain(self, *, max_windows: int = 10_000) -> List[int]:
        """Dispatch windows until no work is pending; returns every
        ticket id served successfully across those windows."""
        served: List[int] = []
        for _ in range(max_windows):
            if self.scheduler.n_pending == 0:
                break
            served.extend(self.step())
        return served

    # ------------------------------------------------------------------ #
    def result(self, ticket_id: int) -> Ticket:
        return self.tickets[ticket_id]
