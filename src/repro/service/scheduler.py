"""Dispatch-window scheduler: admission control, per-tenant fairness, and
coalescing of compatible pending queries into shared-scan batches.

Every dispatch window the scheduler picks ONE shared-scan-compatible group
(same ``calib_iters`` — those jobs can ride the same calibrated sweep) and
fills it round-robin across tenants, one query per tenant per turn, so a
tenant spraying hundreds of submissions cannot starve everyone else: each
window serves the widest set of tenants first and depth second.  The
round-robin cursor persists across windows.

Admission control is two bounded queues deep: a per-tenant cap (one noisy
tenant saturates only its own allowance) and a global cap (the service
sheds load instead of accumulating unbounded backlog).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional

from repro.core import query as query_lib


class AdmissionError(RuntimeError):
    """Submission rejected at the door (queue caps or a bad expression)."""


@dataclasses.dataclass
class Submission:
    ticket: int
    tenant: str
    expr: str
    canonical: str
    calib_iters: int


class QueryScheduler:
    def __init__(self, *, max_batch: int = 64,
                 max_pending_per_tenant: int = 64,
                 max_pending_total: int = 512):
        self.max_batch = max_batch
        self.max_pending_per_tenant = max_pending_per_tenant
        self.max_pending_total = max_pending_total
        # OrderedDict keeps tenant rotation stable in arrival order
        self._pending: "OrderedDict[str, Deque[Submission]]" = OrderedDict()
        self._total = 0
        self._rr = 0  # persistent round-robin cursor over tenants

    # ------------------------------------------------------------------ #
    @property
    def n_pending(self) -> int:
        return self._total

    def pending_for(self, tenant: str) -> int:
        return len(self._pending.get(tenant, ()))

    def enqueue(self, sub: Submission):
        if self._total >= self.max_pending_total:
            raise AdmissionError(
                f"service overloaded ({self._total} pending)")
        q = self._pending.setdefault(sub.tenant, deque())
        if len(q) >= self.max_pending_per_tenant:
            raise AdmissionError(
                f"tenant {sub.tenant!r} over quota ({len(q)} pending)")
        q.append(sub)
        self._total += 1

    # ------------------------------------------------------------------ #
    def _oldest(self) -> Optional[Submission]:
        heads = [q[0] for q in self._pending.values() if q]
        return min(heads, key=lambda s: s.ticket) if heads else None

    def next_batch(self) -> List[Submission]:
        """One dispatch window: the shared-scan group (calib_iters) of the
        oldest pending query, filled round-robin across tenants."""
        oldest = self._oldest()
        if oldest is None:
            return []
        group = oldest.calib_iters
        out: List[Submission] = []
        tenants = list(self._pending)
        start = self._rr % max(1, len(tenants))
        progressed = True
        while len(out) < self.max_batch and progressed:
            progressed = False
            for off in range(len(tenants)):
                if len(out) >= self.max_batch:
                    break
                tenant = tenants[(start + off) % len(tenants)]
                q = self._pending[tenant]
                taken = self._take_matching(q, group)
                if taken is not None:
                    out.append(taken)
                    self._total -= 1
                    progressed = True
        self._rr += 1
        for tenant in [t for t, q in self._pending.items() if not q]:
            del self._pending[tenant]
        return out

    @staticmethod
    def _take_matching(q: Deque[Submission],
                       group: int) -> Optional[Submission]:
        for i, sub in enumerate(q):
            if sub.calib_iters == group:
                del q[i]
                return sub
        return None


def make_submission(ticket: int, tenant: str, expr: str, calib_iters: int,
                    schema) -> Submission:
    """Validate at the door and canonicalize for dedup/caching."""
    try:
        query_lib.validate_expr(expr, schema)
        canonical = query_lib.canonical_expr(expr)
    except query_lib.QueryError as e:
        raise AdmissionError(f"bad expression: {e}") from e
    return Submission(ticket, tenant, expr, canonical, calib_iters)
