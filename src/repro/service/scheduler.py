"""Dispatch-window scheduler: admission control, per-tenant fairness, and
coalescing of compatible pending queries into shared-scan batches.

Every dispatch window the scheduler picks ONE shared-scan-compatible group
(same ``calib_iters`` — those jobs can ride the same calibrated sweep) and
fills it round-robin across tenants, one query per tenant per turn, so a
tenant spraying hundreds of submissions cannot starve everyone else: each
window serves the widest set of tenants first and depth second.  The
round-robin cursor persists across windows.

Admission control is two bounded queues deep — a per-tenant cap (one noisy
tenant saturates only its own allowance) and a global cap (the service
sheds load instead of accumulating unbounded backlog) — and, when
configured, *cost-budgeted*: each submission carries an estimated cost
(``planner.estimate_cost``: events x calibration x aggregate depth) and a
tenant whose queued cost would exceed ``cost_budget_per_tenant`` is
rejected even if it is under its count quota.  Count caps bound queue
*length*; cost budgets bound queued *work* — a tenant submitting three
6-aggregate calibrated full-store scans can be over budget while a tenant
submitting thirty scalar cuts is not.

Dispatch windows themselves are cost-bounded too: with
``window_cost_budget`` set, ``next_batch`` fills a window by accumulated
query cost instead of query count, recosting each queued submission with
the *fitted* :class:`~repro.service.planner.CostWeights` of the execution
backend it dispatches to (``backend.cost_weights``, installed by the
service's telemetry refits — the static prior before any refit).  The
``max_batch`` count cap is retained as the fallback bound, and a window
always takes at least one submission so an over-budget query still runs
alone rather than starving.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional

from repro.core import query as query_lib
from repro.service import planner as planner_lib


class AdmissionError(RuntimeError):
    """Submission rejected at the door (queue caps, cost budgets, or a bad
    expression)."""


@dataclasses.dataclass
class Submission:
    """One admitted query waiting for a dispatch window.

    ``canonical`` is the normalized expression (dedup/cache key) and
    ``cost`` the planner's estimate of the work this query represents if
    executed unshared (0.0 when the submitter opted out of costing).
    ``stream`` marks submissions whose tenant asked for progressive
    delivery: the front-end attaches a
    :class:`~repro.service.streaming.ResultStream` and the dispatch window
    publishes per-packet prefix merges into it mid-scan.
    """
    ticket: int
    tenant: str
    expr: str
    canonical: str
    calib_iters: int
    cost: float = 0.0
    stream: bool = False
    # cost-model features captured at admission, so dispatch-time
    # recosting under refitted weights is arithmetic (no re-parse):
    # store events the query would sweep, and aggregate occurrences
    n_events: int = 0
    n_aggregates: int = 0


class QueryScheduler:
    """Bounded multi-tenant queue with fair, coalescing dispatch windows.

    Parameters
    ----------
    max_batch:
        Widest dispatch window (queries per shared scan).  The front-end's
        :class:`~repro.service.frontend.WindowController` retunes this
        every window when adaptive sizing is enabled.
    max_pending_per_tenant / max_pending_total:
        Count caps: queue *length* bounds (PR 1 behaviour, always on).
    cost_budget_per_tenant / cost_budget_total:
        Cost budgets in planner cost units; ``None`` disables.  A
        submission is rejected when the submitting tenant's queued cost
        (or the global queued cost) would exceed the budget.
    window_cost_budget:
        Per-dispatch-window cost bound (planner cost units); ``None``
        fills windows by count only (the pre-refactor behaviour).  When
        set, ``next_batch`` stops filling once the next submission would
        push the window's total *fitted* cost over the budget — the
        ``max_batch`` count cap stays on as the fallback bound.
    backend:
        The :class:`~repro.core.backend.ExecutionBackend` this scheduler
        dispatches to (the service wires it).  Its ``cost_weights``
        (telemetry-fitted for that backend) recost queued submissions at
        dispatch time; ``None`` falls back to each submission's
        admission-time cost.
    obs / health_gate:
        ``obs`` is the observability plane bundle
        (:class:`repro.obs.Observability`; the service installs its own
        when None), whose health monitor supplies the fleet
        ok/degraded/suspect report.  ``health_gate`` is the ADVISORY
        flag (default off): when set and the report shows unhealthy
        nodes, :meth:`next_batch` narrows the dispatch window by the
        healthy fraction, so sick nodes see less concurrent work while
        staying in rotation.  This is deliberately a hint, not a
        routing policy — ROADMAP item 4's resource-status system plugs
        into exactly this consumption point.
    """

    def __init__(self, *, max_batch: int = 64,
                 max_pending_per_tenant: int = 64,
                 max_pending_total: int = 512,
                 cost_budget_per_tenant: Optional[float] = None,
                 cost_budget_total: Optional[float] = None,
                 window_cost_budget: Optional[float] = None,
                 backend=None, obs=None, health_gate: bool = False,
                 policy=None):
        self.max_batch = max_batch
        self.obs = obs
        self.health_gate = health_gate
        #: the failure policy (service/policy.py), when one is driving
        #: the service: next_batch narrows admission by its routable
        #: fraction — banned nodes shrink dispatch capacity, so windows
        #: shrink with it (the acting counterpart of health_gate's hint)
        self.policy = policy
        #: last advisory narrowing applied (None when the gate is off or
        #: the fleet is healthy) — what tests and operators inspect
        self.last_health_hint: Optional[Dict] = None
        #: single-flight lease manager (fabric/leases.py), wired by the
        #: service when the fleet runs with leases: a submission another
        #: front-end already holds a fresh lease on will be ADOPTED at
        #: dispatch (zero local I/O), so it costs ~0 against window
        #: budgets — adopted work never crowds out real scans
        self.leases = None
        #: flight-recorder scope (repro.obs.flight.FlightScope); None =
        #: off.  Records each dispatch window's ticket composition.
        self.flight = None
        self.max_pending_per_tenant = max_pending_per_tenant
        self.max_pending_total = max_pending_total
        self.cost_budget_per_tenant = cost_budget_per_tenant
        self.cost_budget_total = cost_budget_total
        self.window_cost_budget = window_cost_budget
        self.backend = backend
        # OrderedDict keeps tenant rotation stable in arrival order
        self._pending: "OrderedDict[str, Deque[Submission]]" = OrderedDict()
        self._total = 0
        self._cost: Dict[str, float] = {}
        self._cost_total = 0.0
        self._rr = 0  # persistent round-robin cursor over tenants

    # ------------------------------------------------------------------ #
    @property
    def n_pending(self) -> int:
        """Queries queued across all tenants."""
        return self._total

    @property
    def pending_cost(self) -> float:
        """Total queued cost across all tenants (planner cost units)."""
        return self._cost_total

    def pending_for(self, tenant: str) -> int:
        """Queries queued for one tenant."""
        return len(self._pending.get(tenant, ()))

    def pending_cost_for(self, tenant: str) -> float:
        """Queued cost for one tenant (planner cost units)."""
        return self._cost.get(tenant, 0.0)

    def enqueue(self, sub: Submission):
        """Admit one submission or raise :class:`AdmissionError`.

        Checks, in order: global count cap, per-tenant count cap, global
        cost budget, per-tenant cost budget.  Nothing is queued on
        rejection."""
        if self._total >= self.max_pending_total:
            raise AdmissionError(
                f"service overloaded ({self._total} pending)")
        q = self._pending.get(sub.tenant)
        if q is not None and len(q) >= self.max_pending_per_tenant:
            raise AdmissionError(
                f"tenant {sub.tenant!r} over quota ({len(q)} pending)")
        if (self.cost_budget_total is not None
                and self._cost_total + sub.cost > self.cost_budget_total):
            raise AdmissionError(
                f"service over cost budget "
                f"({self._cost_total:.0f} + {sub.cost:.0f} queued "
                f"> {self.cost_budget_total:.0f})")
        tenant_cost = self._cost.get(sub.tenant, 0.0)
        if (self.cost_budget_per_tenant is not None
                and tenant_cost + sub.cost > self.cost_budget_per_tenant):
            raise AdmissionError(
                f"tenant {sub.tenant!r} over cost budget "
                f"({tenant_cost:.0f} + {sub.cost:.0f} queued "
                f"> {self.cost_budget_per_tenant:.0f})")
        self._pending.setdefault(sub.tenant, deque()).append(sub)
        self._total += 1
        self._cost[sub.tenant] = tenant_cost + sub.cost
        self._cost_total += sub.cost

    def requeue(self, sub: Submission) -> None:
        """Put a previously dequeued submission back at the FRONT of its
        tenant queue, bypassing admission caps — the single-flight
        fallback path (an adoption whose owner died/was banned must get
        its own scan, and it was already admitted once)."""
        self._pending.setdefault(sub.tenant, deque()).appendleft(sub)
        self._total += 1
        self._cost[sub.tenant] = self._cost.get(sub.tenant, 0.0) + sub.cost
        self._cost_total += sub.cost

    def _remotely_leased(self, sub: Submission) -> bool:
        # a fresh remote lease means this submission will be adopted,
        # not scanned: ~0 window cost
        return (self.leases is not None
                and self.leases.remote_holder(sub.canonical,
                                              sub.calib_iters) is not None)

    # ------------------------------------------------------------------ #
    def _oldest(self) -> Optional[Submission]:
        heads = [q[0] for q in self._pending.values() if q]
        return min(heads, key=lambda s: s.ticket) if heads else None

    def dispatch_cost(self, sub: Submission) -> float:
        """Cost of one queued submission under the CURRENT cost model.

        Recosts the submission's admission-time features with the
        execution backend's telemetry-fitted weights when the scheduler
        is wired to a backend that has them; otherwise the admission-time
        estimate stands.  This is what makes window-cost bounding track
        the *fitted* model rather than the weights in force when the
        query happened to be admitted."""
        weights = getattr(self.backend, "cost_weights", None)
        if weights is None or sub.n_events <= 0:
            return sub.cost
        return planner_lib.cost_from_features(
            sub.n_events, sub.calib_iters, sub.n_aggregates,
            weights=weights)

    def next_batch(self) -> List[Submission]:
        """One dispatch window: the shared-scan group (``calib_iters``) of
        the oldest pending query, filled round-robin across tenants up to
        ``max_batch`` wide — and, with ``window_cost_budget`` set, up to
        that much fitted cost (:meth:`dispatch_cost`): the fill stops at
        the first submission that would overflow the budget (no
        cost-based queue jumping), but always takes at least one so an
        over-budget query runs alone instead of starving.  A submission
        whose canonical form is already in the window being filled is
        FREE — the front-end dedups it onto the same execution, so
        charging it would under-fill windows on hot-query traffic.
        Dequeued submissions release their queued (admission-time)
        cost.

        With ``health_gate`` set and the observability plane's health
        report showing degraded/suspect nodes, the window is narrowed to
        ``max_batch * healthy_fraction`` (floor 1) — the advisory
        consumption of the fleet health telemetry."""
        oldest = self._oldest()
        if oldest is None:
            return []
        max_batch = self.max_batch
        self.last_health_hint = None
        if self.health_gate and self.obs is not None:
            report = self.obs.health.report()
            frac = report.healthy_fraction
            if frac < 1.0:
                max_batch = max(1, int(round(self.max_batch * frac)))
                self.last_health_hint = {
                    "max_batch": max_batch,
                    "healthy_fraction": frac,
                    "suspect": report.suspects,
                    "degraded": report.degraded,
                }
                self.obs.metrics.counter("sched.health_hints").inc()
        if self.policy is not None:
            frac = self.policy.routable_fraction()
            if frac < 1.0:
                # banned nodes shrink scan capacity: admit proportionally
                # fewer queries per window so queueing moves to admission
                # (where fairness applies) instead of the scan itself
                max_batch = max(1, min(max_batch,
                                       int(round(self.max_batch * frac))))
                self.last_health_hint = dict(
                    self.last_health_hint or {},
                    max_batch=max_batch,
                    routable_fraction=frac,
                    policy_states=self.policy.states())
        group = oldest.calib_iters
        budget = self.window_cost_budget
        window_cost = 0.0
        window_canonicals: set = set()
        out: List[Submission] = []
        tenants = list(self._pending)
        start = self._rr % max(1, len(tenants))
        progressed, capped = True, False
        while len(out) < max_batch and progressed and not capped:
            progressed = False
            for off in range(len(tenants)):
                if len(out) >= max_batch:
                    break
                tenant = tenants[(start + off) % len(tenants)]
                q = self._pending[tenant]
                i = self._peek_matching(q, group)
                if i is None:
                    continue
                sub = q[i]
                cost = (0.0 if sub.canonical in window_canonicals
                        or self._remotely_leased(sub)
                        else self.dispatch_cost(sub))
                if budget is not None and out and window_cost + cost > budget:
                    capped = True
                    break
                del q[i]
                out.append(sub)
                window_cost += cost
                window_canonicals.add(sub.canonical)
                self._total -= 1
                self._cost[tenant] = max(
                    0.0, self._cost.get(tenant, 0.0) - sub.cost)
                self._cost_total = max(0.0, self._cost_total - sub.cost)
                progressed = True
        self._rr += 1
        for tenant in [t for t, q in self._pending.items() if not q]:
            del self._pending[tenant]
            self._cost.pop(tenant, None)
        if self.flight is not None and out:
            self.flight.record("window",
                               tickets=[s.ticket for s in out],
                               tenants=sorted({s.tenant for s in out}),
                               group=group, max_batch=max_batch)
        return out

    @staticmethod
    def _peek_matching(q: Deque[Submission], group: int) -> Optional[int]:
        for i, sub in enumerate(q):
            if sub.calib_iters == group:
                return i
        return None


def make_submission(ticket: int, tenant: str, expr: str, calib_iters: int,
                    schema, *, n_events: int = 0, stream: bool = False,
                    weights=None) -> Submission:
    """Validate at the door, canonicalize for dedup/caching, and estimate
    cost for budgeted admission.

    ``n_events`` is the store size the query would sweep (0 disables
    costing — the submission carries cost 0.0 and only count caps apply);
    ``stream`` requests progressive partial-merge delivery; ``weights``
    (a :class:`~repro.service.planner.CostWeights`) selects the cost
    model's coefficients — the service passes its telemetry-fitted
    weights, None means the static cold-start prior.  Raises
    :class:`AdmissionError` on an invalid expression: a bad query must be
    rejected at submit, not on a grid node."""
    try:
        ast = query_lib.validate_expr(expr, schema)
        canonical = query_lib.canonical_expr(expr)
    except query_lib.QueryError as e:
        raise AdmissionError(f"bad expression: {e}") from e
    n_aggregates = planner_lib.count_aggregates(ast)
    cost = (planner_lib.estimate_cost(ast, n_events=n_events,
                                      calib_iters=calib_iters,
                                      weights=weights)
            if n_events > 0 else 0.0)
    return Submission(ticket, tenant, expr, canonical, calib_iters, cost,
                      stream=stream, n_events=max(0, n_events),
                      n_aggregates=n_aggregates)
