"""Streaming partial-merge result delivery: progressive histograms while
the grid job runs.

The batch service resolves a ticket only when its dispatch window
finishes.  DIAL-style interactive analysis wants the opposite UX: a
histogram that fills in as bricks report, with the *guarantee* that the
final picture is exactly the batch answer.  This module is that delivery
layer:

- :class:`StreamSnapshot` — one progressive result: an **exact**
  :class:`~repro.core.merge.QueryResult` over the prefix of packets merged
  so far, plus :class:`~repro.core.merge.Coverage` confidence metadata and
  the virtual grid time it became available.
- :class:`ResultStream` — the per-ticket subscription a tenant reads:
  bounded buffer, conflating backpressure (a slow reader loses
  intermediate granularity, never the final), ``latest()``
  snapshot-at-any-time, and a push ``subscribe`` hook.
- :class:`WindowStreamPublisher` — the producer side the front-end plugs
  into the JSE's ``on_partial`` hook: one
  :class:`~repro.core.merge.MergeAccumulator` per streamed query column of
  the shared scan, fanning each packet's prefix snapshot out to every
  subscribed ticket.

Consistency model (``docs/streaming.md`` has the full argument): partials
are published in merge order, the accumulator's prefix snapshots are
bit-identical to ``tree_merge`` of the same prefix, and therefore the
final snapshot of a DONE job is bit-identical to the batch path's result —
including under node-failure scripts and fragment-factored plans.  A
truncated (FAILED) scan aborts the stream without ever publishing a final
snapshot, mirroring the batch rule that a truncated partial is never
surfaced or cached.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence

from repro.core import merge as merge_lib
from repro.core.jse import PacketPartial

OPEN, DONE, ABORTED = "OPEN", "DONE", "ABORTED"


@dataclasses.dataclass(frozen=True)
class StreamSnapshot:
    """One progressive result published on a :class:`ResultStream`.

    ``result`` is the exact merged answer over the first ``seq + 1``
    packets of the scan (not an estimate — see
    :class:`~repro.core.merge.MergeAccumulator`), ``coverage`` says how
    much of the job that prefix represents, and ``t_virtual`` is when the
    snapshot became available on the simulated grid clock (``final``
    snapshots carry the job makespan).  ``final`` marks the last snapshot
    of a DONE job: bit-identical to the batch ``tree_merge`` result."""
    seq: int
    result: merge_lib.QueryResult
    coverage: merge_lib.Coverage
    t_virtual: float
    final: bool = False


class ResultStream:
    """Per-ticket stream of progressive snapshots (the tenant-facing end).

    Producer side (the service): :meth:`publish` intermediate snapshots,
    then exactly one of :meth:`finish` (job DONE, final snapshot) or
    :meth:`abort` (rejected / cache-miss failure / truncated scan).

    Consumer side (the tenant): :meth:`poll` drains buffered snapshots in
    order, :meth:`latest` peeks at the newest one without consuming
    (snapshot-at-any-time), iteration drains the currently buffered
    snapshots (use :meth:`subscribe` — a push callback invoked on every
    publish — for live consumption while the scan loop is still
    running).

    Backpressure is *conflating*: the buffer holds at most ``capacity``
    snapshots and a publish into a full buffer drops the **oldest**
    buffered one (count in :attr:`dropped`).  Progressive results are
    cumulative states, not deltas, so a lagging reader skips intermediate
    granularity but never loses information — and the final snapshot is
    never dropped.  The producer never blocks the scan."""

    def __init__(self, ticket_id: int, *, capacity: int = 32):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.ticket_id = ticket_id
        self.capacity = capacity
        self.state = OPEN
        self.note = ""
        self.published = 0   # snapshots ever published
        self.dropped = 0     # snapshots conflated away by backpressure
        self._buf: Deque[StreamSnapshot] = deque()
        self._latest: Optional[StreamSnapshot] = None
        self._listeners: List[Callable[[StreamSnapshot], None]] = []
        self._close_listeners: List[Callable[["ResultStream"], None]] = []

    # ---------------------------- producer ---------------------------- #
    def publish(self, snap: StreamSnapshot) -> None:
        """Deliver one snapshot (service-internal; no-op after close)."""
        if self.state != OPEN:
            return
        if len(self._buf) >= self.capacity:
            self._buf.popleft()
            self.dropped += 1
        self._buf.append(snap)
        self._latest = snap
        self.published += 1
        for fn in self._listeners:
            fn(snap)

    def finish(self, snap: StreamSnapshot) -> None:
        """Publish the final snapshot and close the stream as DONE
        (no-op on an already-closed stream: an ABORTED stream must never
        resurrect as done without a final snapshot)."""
        if self.state != OPEN:
            return
        self.publish(snap)
        self.state = DONE
        for fn in self._close_listeners:
            fn(self)

    def abort(self, note: str) -> None:
        """Close the stream without a final snapshot (the reason lands in
        :attr:`note`); already-published prefixes stay readable."""
        if self.state == OPEN:
            self.state = ABORTED
            self.note = note
            for fn in self._close_listeners:
                fn(self)

    # ---------------------------- consumer ---------------------------- #
    @property
    def closed(self) -> bool:
        """True once the stream is DONE or ABORTED (no more publishes)."""
        return self.state != OPEN

    @property
    def done(self) -> bool:
        """True when the job finished and the final snapshot was published."""
        return self.state == DONE

    def latest(self) -> Optional[StreamSnapshot]:
        """Newest snapshot ever published, without consuming the buffer —
        the snapshot-at-any-time read (None before the first partial)."""
        return self._latest

    def poll(self) -> Optional[StreamSnapshot]:
        """Consume and return the oldest buffered snapshot (None if the
        buffer is currently empty)."""
        return self._buf.popleft() if self._buf else None

    def subscribe(self, fn: Callable[[StreamSnapshot], None]) -> None:
        """Register a push callback invoked on every future publish (runs
        synchronously inside the scan loop — keep it cheap)."""
        self._listeners.append(fn)

    def on_close(self, fn: Callable[["ResultStream"], None]) -> None:
        """Register a callback invoked once when the stream closes (both
        DONE and ABORTED) — the fabric's fan-out layer forwards closure
        to remote readers through this hook.  If the stream is already
        closed the callback fires immediately."""
        if self.closed:
            fn(self)
            return
        self._close_listeners.append(fn)

    def buffered(self) -> List[StreamSnapshot]:
        """The currently buffered snapshots, oldest first, WITHOUT
        consuming them — what a late reader attaching now would drain
        (the fan-out layer replays this prefix to remote subscribers)."""
        return list(self._buf)

    def __len__(self) -> int:
        """Snapshots currently buffered (≤ ``capacity``)."""
        return len(self._buf)

    def __iter__(self):
        """Drain buffered snapshots in order; stops when the buffer is
        empty (on a closed stream that means the stream is exhausted)."""
        while self._buf:
            yield self._buf.popleft()


class WindowStreamPublisher:
    """Fans one shared-scan window's per-packet partials out to per-ticket
    streams, maintaining one prefix-merge accumulator per streamed column.

    ``column_streams[k]`` holds the :class:`ResultStream` subscribers of
    the window's *k*-th query column (deduplicated canonical query);
    columns nobody subscribed to cost nothing.  Plug :meth:`on_partial`
    into ``run_job_batch_simulated(on_partial=...)``, then call
    :meth:`finish` with the batch-merged results (DONE) or :meth:`abort`
    (FAILED) — the final snapshot reuses the batch result object itself,
    which the accumulator's prefix property guarantees is the value every
    intermediate prefix was converging to."""

    def __init__(self, column_streams: Sequence[Sequence[ResultStream]], *,
                 events_total: Optional[int] = None,
                 bricks_total: Optional[int] = None, obs=None):
        self.column_streams = [list(streams) for streams in column_streams]
        self._accs: List[Optional[merge_lib.MergeAccumulator]] = [
            merge_lib.MergeAccumulator(events_total=events_total,
                                       bricks_total=bricks_total)
            if streams else None
            for streams in self.column_streams]
        self._failures = 0
        self._t = 0.0  # prefix availability clock (see on_partial)
        # observability plane (repro.obs.Observability); None = disabled
        self.obs = obs

    @property
    def active(self) -> bool:
        """True when at least one column has a subscriber."""
        return any(acc is not None for acc in self._accs)

    def on_partial(self, pp: PacketPartial) -> None:
        """JSE hook: fold packet ``pp`` into every subscribed column's
        accumulator and publish the new prefix snapshots.

        Snapshots are stamped with the *prefix availability time* — the
        running max of packet completion times — because a prefix merge
        exists only once every packet in it has finished; raw completion
        times interleave non-monotonically across nodes."""
        new_failures = pp.failures - self._failures
        self._failures = pp.failures
        self._t = max(self._t, pp.t_virtual)
        obs = self.obs
        if obs is not None:
            obs.tracer.event(
                "merge_prefix",
                t_virtual=obs.tracer.virtual_base + self._t,
                seq=pp.seq, brick=pp.brick_id)
        for col, acc in enumerate(self._accs):
            if acc is None:
                continue
            if new_failures:
                acc.note_failure(new_failures)
            acc.add(pp.partials[col], brick_id=pp.brick_id)
            snap = StreamSnapshot(seq=pp.seq, result=acc.snapshot(),
                                  coverage=acc.coverage(),
                                  t_virtual=self._t)
            if obs is None:
                for stream in self.column_streams[col]:
                    stream.publish(snap)
            else:
                for stream in self.column_streams[col]:
                    d0 = stream.dropped
                    stream.publish(snap)
                    obs.metrics.counter("stream.published").inc()
                    if stream.dropped > d0:
                        # backpressure conflated an older snapshot away
                        obs.metrics.counter("stream.conflated").inc(
                            stream.dropped - d0)
                    # lease-export streams carry their string lease key
                    # as ticket_id; the span schema types ticket as
                    # int|str|None, so the key is stamped directly
                    obs.tracer.event(
                        "stream_partial",
                        t_virtual=obs.tracer.virtual_base + self._t,
                        ticket=stream.ticket_id,
                        seq=pp.seq, col=col)

    def finish(self, merged: Sequence[merge_lib.QueryResult],
               makespan_s: float) -> None:
        """Publish each column's final snapshot (the batch-merged result)
        and close its streams as DONE."""
        obs = self.obs
        for col, acc in enumerate(self._accs):
            if acc is None:
                continue
            snap = StreamSnapshot(
                seq=acc.n_partials - 1, result=merged[col],
                coverage=acc.coverage(), t_virtual=makespan_s, final=True)
            for stream in self.column_streams[col]:
                stream.finish(snap)
                if obs is not None:
                    obs.metrics.counter("stream.finished").inc()

    def abort(self, note: str) -> None:
        """Close every subscribed stream without a final snapshot (the
        truncated-scan rule: a partial is never surfaced as an answer)."""
        obs = self.obs
        for streams in self.column_streams:
            for stream in streams:
                was_open = stream.state == OPEN
                stream.abort(note)
                if obs is not None and was_open:
                    obs.metrics.counter("stream.aborted").inc()
