"""Query-result cache for the multi-tenant service.

At interactive scale the dominant cost is re-reading brick-resident events
for queries the grid has already answered (the LHC operational lesson:
cache and amortize, don't re-scan).  Entries are keyed on

    (canonical expression, calib_iters, dataset epoch)

so textually different but identical queries share one slot, and a
``MetadataCatalog.bump_dataset_version()`` (new run appended, brick
recalibrated) makes every older entry unreachable; a registered
invalidation hook also purges them eagerly to free memory.  Eviction is
plain LRU.

Entries come in at two granularities sharing one keyspace: whole-query
results (``put``) and *fragment-level* results (``put_fragment``) — shared
boolean subexpressions the planner materialized during a shared scan.  A
fragment's key is its canonical form (``query_lib.node_key``), which is
exactly what a future submission of that expression canonicalizes to, so
fragment entries are hit by the ordinary ``get`` path with zero brick I/O.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

from repro.core import merge as merge_lib
from repro.core import query as query_lib


@dataclasses.dataclass
class CacheStats:
    """Monotonic cache counters (hits/misses/evictions/invalidations and
    planner-installed fragment entries)."""
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidated: int = 0
    fragment_puts: int = 0  # fragment-level entries installed by the planner
    # hits satisfied by the fleet's shared L2 tier (always 0 for a plain
    # per-process cache; see repro.fabric.shared_cache.TieredResultCache)
    l2_hits: int = 0


class ResultCache:
    """LRU result cache keyed on (canonical expr, calib_iters, dataset
    epoch), holding whole-query and fragment-level entries in one
    keyspace; a catalogue dataset bump purges stale epochs eagerly."""

    def __init__(self, capacity: int = 256, catalog=None):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._entries: "OrderedDict[Tuple, merge_lib.QueryResult]" = \
            OrderedDict()
        self.stats = CacheStats()
        self._catalog = catalog
        if catalog is not None:
            catalog.on_dataset_bump(self._on_dataset_bump)

    def detach(self):
        """Unhook from the catalog (a long-lived catalog would otherwise
        keep every cache ever attached alive through its hook list)."""
        if self._catalog is not None:
            self._catalog.off_dataset_bump(self._on_dataset_bump)
            self._catalog = None

    @staticmethod
    def key(expr: str, calib_iters: int, epoch: int,
            canonical: Optional[str] = None) -> Tuple:
        """Cache key for a query under one dataset epoch."""
        # pass `canonical` when the caller already canonicalized (the
        # service does at admission) to avoid re-parsing the expression
        if canonical is None:
            canonical = query_lib.canonical_expr(expr)
        return (canonical, int(calib_iters), int(epoch))

    def get(self, expr: str, calib_iters: int, epoch: int, *,
            canonical: Optional[str] = None
            ) -> Optional[merge_lib.QueryResult]:
        """Probe the cache (None on miss); hits refresh LRU recency."""
        k = self.key(expr, calib_iters, epoch, canonical)
        hit = self._entries.get(k)
        if hit is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(k)
        self.stats.hits += 1
        return hit

    def put(self, expr: str, calib_iters: int, epoch: int,
            result: merge_lib.QueryResult, *,
            canonical: Optional[str] = None):
        """Install a whole-query result (canonicalizes ``expr`` unless the
        caller already did); evicts LRU entries over capacity."""
        k = self.key(expr, calib_iters, epoch, canonical)
        self._entries[k] = result
        self._entries.move_to_end(k)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def put_fragment(self, fragment_key: str, calib_iters: int, epoch: int,
                     result: merge_lib.QueryResult):
        """Install a fragment-level result under its canonical fragment key
        (already canonical — produced by ``query_lib.node_key``; no
        re-parse).  Future queries equal to the fragment hit via ``get``."""
        self.put(fragment_key, calib_iters, epoch, result,
                 canonical=fragment_key)
        self.stats.fragment_puts += 1

    def _on_dataset_bump(self, epoch: int):
        stale = [k for k in self._entries if k[2] != epoch]
        for k in stale:
            del self._entries[k]
        self.stats.invalidated += len(stale)

    def clear(self):
        """Drop every entry (counted as invalidations)."""
        self.stats.invalidated += len(self._entries)
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)
