"""Sharded checkpointing with restart-on-any-mesh.

Design (fault-tolerance path for 1000+-node runs):
- leaves are saved by LOGICAL PATH (the ParamTable path), not by position,
  so a checkpoint written on one mesh restores onto any other — this is
  what makes elastic re-meshing (core/elastic.py) a checkpoint round trip;
- writes are atomic (tmp dir + rename) so a node failure mid-save never
  corrupts the latest checkpoint;
- saves can run on a background thread (async) so the train loop only
  blocks on the device->host copy, not the filesystem;
- a retention policy keeps the last N steps.

On a real multi-host pod each host writes only its addressable shards; in
this single-process container that is simply all shards.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree = {}
    for path, v in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(directory, step: int, tree, *, extra: Optional[dict] = None):
    """Atomic full-tree save: <dir>/step_<n>/{manifest.json, arrays.npz}."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        key = path.replace("/", "__")
        arrays[key] = arr
        manifest["leaves"][path] = {"dtype": str(arr.dtype),
                                    "shape": list(arr.shape)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name[5:]) for p in directory.glob("step_*")]
    return max(steps) if steps else None


def restore_checkpoint(directory, step: Optional[int] = None, *,
                       abstract=None, mesh=None):
    """Restore a tree.  If ``abstract`` (ShapeDtypeStructs with shardings)
    is given, leaves are device_put with those shardings — this is the
    restart-on-a-different-mesh path."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")
    flat = {}
    for path in manifest["leaves"]:
        arr = data[path.replace("/", "__")]
        flat[path] = arr
    tree = _unflatten(flat)
    if abstract is not None:
        def put(leaf, abs_leaf):
            sh = getattr(abs_leaf, "sharding", None)
            x = jnp.asarray(leaf, dtype=abs_leaf.dtype)
            return jax.device_put(x, sh) if sh is not None else x

        tree = jax.tree.map(put, tree, abstract,
                            is_leaf=lambda x: isinstance(x, np.ndarray))
    return tree, manifest


class CheckpointManager:
    """Async saves + retention, restart discovery."""

    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.directory = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.saved_steps = []

    def save(self, step: int, tree, extra=None):
        # snapshot to host BEFORE handing to the writer thread: the train
        # loop may donate/overwrite device buffers on the next step
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree, extra)

    def _write(self, step, host_tree, extra):
        save_checkpoint(self.directory, step, host_tree, extra=extra)
        self.saved_steps.append(step)
        self._enforce_retention()

    def _enforce_retention(self):
        steps = sorted(int(p.name[5:]) for p in self.directory.glob("step_*"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, abstract=None, mesh=None):
        self.wait()
        return restore_checkpoint(self.directory, abstract=abstract, mesh=mesh)
