"""Sharding rules: logical tensor roles -> mesh PartitionSpecs.

The production mesh is ``("data", "model")`` single-pod or
``("pod", "data", "model")`` multi-pod (see launch/mesh.py).  The GEPS
grid-brick placement maps:

- the *brick* axes (event/batch shards that never move) -> ``("pod","data")``,
- tensor parallelism inside a node group                 -> ``"model"``,
- FSDP (ZeRO-3) parameter sharding                       -> ``"data"``
  (never ``"pod"``: GEPS keeps cross-pod/WAN traffic to result-merge only,
  so parameters are replicated across pods and gradients are merged
  hierarchically).

Roles are resolved against actual dimension sizes: a dimension that does not
divide the mesh axis falls back to replication (e.g. 24 heads on a 16-way
model axis, 8 kv-heads on 16-way TP).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


class Sharder:
    """Resolves logical roles to mesh axes for one (config, mesh) pair.

    Roles:
      batch   – global batch / brick axis -> ("pod","data") (or ("data",))
      fsdp    – parameter d_model-like dim -> "data" (if cfg.fsdp_params)
      tensor  – TP dim (heads / d_ff / vocab / recurrent width) -> "model"
      expert  – MoE expert dim -> "model" when cfg.moe_sharding == "ep"
      moe_ff  – MoE d_ff dim   -> "model" when cfg.moe_sharding == "tp"
      seq     – sequence dim -> "model" when cfg.seq_shard_norm (SP sections)
      null    – replicated
    """

    def __init__(self, cfg, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        sizes = mesh_axis_sizes(mesh)
        names = mesh.axis_names
        self.batch_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in names
        )
        self.batch_size_total = 1
        for a in self.batch_axes:
            self.batch_size_total *= sizes[a]
        self.fsdp_axis: Optional[str] = "data" if "data" in names else None
        self.tensor_axis: Optional[str] = "model" if "model" in names else None
        self.tensor_size = sizes.get("model", 1)
        self.fsdp_size = sizes.get("data", 1)

    # ------------------------------------------------------------------ #
    def _resolve(self, role: str, dim: int):
        cfg = self.cfg
        if role in (None, "null"):
            return None
        if role == "batch":
            if not self.batch_axes:
                return None
            return self.batch_axes if dim % self.batch_size_total == 0 else None
        if role == "fsdp":
            if not cfg.fsdp_params or self.fsdp_axis is None:
                return None
            return self.fsdp_axis if dim % self.fsdp_size == 0 else None
        if role == "fsdp_act":  # activation dim sharded over data irrespective
            if self.fsdp_axis is None:
                return None
            return self.fsdp_axis if dim % self.fsdp_size == 0 else None
        if role == "tensor":
            if self.tensor_axis is None:
                return None
            return self.tensor_axis if dim % self.tensor_size == 0 else None
        if role == "expert":
            if cfg.num_experts and cfg.moe_sharding == "ep":
                return self._resolve("tensor", dim)
            return None
        if role == "moe_ff":
            if cfg.num_experts and cfg.moe_sharding == "tp":
                return self._resolve("tensor", dim)
            return None
        if role == "moe_d":
            return self._resolve("fsdp", dim)
        if role == "seq":
            if not cfg.seq_shard_norm:
                return None
            return self._resolve("tensor", dim)
        raise ValueError(f"unknown sharding role: {role}")

    def spec(self, roles: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert len(roles) == len(shape), (roles, shape)
        return P(*[self._resolve(r, d) for r, d in zip(roles, shape)])

    def named(self, roles: Sequence[Optional[str]], shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(roles, shape))

    # ------------------------------------------------------------------ #
    def ws(self, x: jax.Array, *roles: Optional[str]) -> jax.Array:
        """with_sharding_constraint by logical roles (no-op outside jit)."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(roles, x.shape))
        )

    # Convenience activation constraints ------------------------------- #
    def act_btd(self, x):  # (batch, seq, d_model)
        return self.ws(x, "batch", None, None)

    def act_bthd(self, x):  # (batch, seq, heads, head_dim)
        return self.ws(x, "batch", None, "tensor", None)

    def act_btf(self, x):  # (batch, seq, d_ff)
        return self.ws(x, "batch", None, "tensor")

    def act_btv(self, x):  # (batch, seq, vocab)
        return self.ws(x, "batch", None, "tensor")

    def batch_spec(self, shape) -> P:
        return self.spec(["batch"] + [None] * (len(shape) - 1), shape)


