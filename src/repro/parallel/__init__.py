from repro.parallel.sharding import Sharder, mesh_axis_sizes  # noqa: F401
