"""Distributed-optimization collectives.

- ``int8 compressed cross-pod gradient merge``: GEPS keeps WAN (cross-pod)
  traffic down to result merges; when gradients must cross pods we compress
  them to int8 with per-tensor scales and error feedback, cutting DCN bytes
  4x vs bf16.  The quantizer is exact-restorable in expectation (error
  feedback carries the residual to the next step).
- ``hierarchical_psum``: reduce-scatter inside the pod first, thin
  all-reduce across pods — the JSE merge tree as a collective schedule.
  (XLA's GSPMD usually synthesizes this automatically from shardings; the
  explicit shard_map version exists for the perf pass and for tests.)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: jax.Array, error: jax.Array
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (grad + carried error); return (q, scale, new_error)."""
    target = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(target)
    new_error = target - dequantize_int8(q, scale)
    return q, scale, new_error


def compressed_cross_pod_mean(grad: jax.Array, error: jax.Array,
                              axis_name: str = "pod"):
    """Inside shard_map over the pod axis: int8 all-reduce with error
    feedback. Returns (mean_grad f32, new_error)."""
    q, scale, new_error = compress_with_feedback(grad, error)
    n = jax.lax.axis_size(axis_name)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # scales differ per pod: psum the dequantized contribution instead when
    # scales diverge; here we use the mean scale (error feedback absorbs
    # the mismatch over steps)
    scale_mean = jax.lax.pmean(scale, axis_name)
    return summed.astype(jnp.float32) * scale_mean / n, new_error


def hierarchical_psum(x: jax.Array, *, inner: str = "data",
                      outer: Optional[str] = "pod"):
    """psum inner axis first, then outer — the GEPS merge order (LAN before
    WAN).  Use inside shard_map with both axes manual."""
    x = jax.lax.psum(x, inner)
    if outer is not None:
        x = jax.lax.psum(x, outer)
    return x
