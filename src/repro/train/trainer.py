"""Training driver: brick-fed data, checkpoint/restart, failure recovery.

The control loop is the GEPS JSE applied to training: the catalogue tracks
node health, the packet scheduler feeds the batch from node-local bricks,
checkpoints make any failure a bounded-loss restart, and elastic re-meshing
(core/elastic.py + checkpoint restore-by-path) handles permanent node loss.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import CheckpointManager, latest_step
from repro.core.catalog import MetadataCatalog
from repro.data.pipeline import BrickDataPipeline, TokenBrickStore
from repro.models import model_zoo
from repro.optim.adamw import AdamW, init_opt_state
from repro.parallel.sharding import Sharder
from repro.train import steps as steps_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    log_every: int = 10
    async_ckpt: bool = True


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, mesh, *,
                 n_data_nodes: int = 4,
                 failure_hook: Optional[Callable[[int], Optional[int]]] = None):
        """failure_hook(step) -> node_id to kill at that step (simulation)."""
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.model = model_zoo.build_model(cfg)
        self.shd = Sharder(cfg, mesh)
        self.opt = AdamW()
        self.failure_hook = failure_hook

        self.catalog = MetadataCatalog(n_data_nodes)
        store = TokenBrickStore(
            vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
            n_bricks=2 * n_data_nodes,
            seqs_per_brick=max(4, tcfg.global_batch),
            n_nodes=n_data_nodes)
        self.pipeline = BrickDataPipeline(
            store, self.catalog, global_batch=tcfg.global_batch, mesh=mesh)

        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts,
                                      async_save=tcfg.async_ckpt)
        step_fn, _ = steps_lib.make_train_step(cfg, self.model, mesh,
                                               self.opt, lr=tcfg.lr)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self.history: list = []

    # ------------------------------------------------------------------ #
    def init_state(self):
        params = self.model.table.init(jax.random.key(0))
        params = jax.device_put(params, self.model.table.shardings(self.shd))
        opt_state = init_opt_state(params, self.opt)
        return params, opt_state

    def _restore_or_init(self):
        step = latest_step(self.tcfg.ckpt_dir)
        if step is None:
            params, opt_state = self.init_state()
            return 0, params, opt_state
        abstract = {
            "params": self.model.table.abstract_sharded(self.shd),
        }
        tree, manifest = self.ckpt.restore_latest(
            abstract=None)  # restore raw then place
        params = jax.device_put(tree["params"],
                                self.model.table.shardings(self.shd))
        opt_state = jax.tree.map(jnp.asarray, tree["opt_state"])
        return manifest["step"], params, opt_state

    # ------------------------------------------------------------------ #
    def train(self) -> Dict[str, float]:
        start_step, params, opt_state = self._restore_or_init()
        step = start_step
        t0 = time.time()
        while step < self.tcfg.total_steps:
            # simulated node failure: mark dead, data fails over to replicas
            if self.failure_hook is not None:
                victim = self.failure_hook(step)
                if victim is not None:
                    self.catalog.mark_dead(victim)
                    self.pipeline.sched.requeue_node(victim)
            batch = self.pipeline.next_device_batch()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            step += 1
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps:
                loss = float(metrics["loss"])
                self.history.append({"step": step, "loss": loss})
            if step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step, {"params": params,
                                      "opt_state": opt_state},
                               extra={"name": self.cfg.name})
        self.ckpt.save(step, {"params": params, "opt_state": opt_state},
                       extra={"name": self.cfg.name})
        self.ckpt.wait()
        return {
            "steps": step - start_step,
            "final_loss": self.history[-1]["loss"] if self.history else None,
            "wall_s": time.time() - t0,
        }
