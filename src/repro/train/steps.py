"""train_step / serve_step builders.

These are the SPMD "jobs" the GEPS JSE dispatches: each step consumes the
brick-resident batch shard on every device, computes locally, and merges
results (gradients / logits) through the hierarchical collective schedule
implied by the shardings — never moving raw event/token data off its brick.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamW, adamw_update
from repro.parallel.sharding import Sharder


def cross_entropy(logits, labels, vocab_size: int):
    """logits (B,S,Vp) any-dtype, labels (B,S) int32; mean CE over real vocab."""
    lf = logits.astype(jnp.float32)
    vp = lf.shape[-1]
    if vp != vocab_size:
        # mask padded vocab slots out of the partition function
        iota = jax.lax.broadcasted_iota(jnp.int32, (vp,), 0)
        lf = jnp.where(iota >= vocab_size, -1e30, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(cfg, model, shd):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, shd)
        # next-token prediction: positions 0..S-2 predict labels 1..S-1
        loss = cross_entropy(logits[:, :-1, :], batch["labels"][:, 1:],
                             cfg.vocab_size)
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def make_train_step(cfg, model, mesh, opt: Optional[AdamW] = None,
                    lr: float = 3e-4):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With cfg.microbatches > 1 the global batch is split into M microbatches
    and gradients are accumulated in f32 over a lax.scan — this is what
    bounds live activation memory (the GEPS "packet" granularity knob at
    the SPMD level; see EXPERIMENTS.md section Perf for its tuning).
    """
    opt = opt or AdamW(moment_dtype=cfg.opt_moment_dtype)
    shd = Sharder(cfg, mesh)
    loss_fn = make_loss_fn(cfg, model, shd)
    M = max(1, cfg.microbatches)
    acc_dt = jnp.dtype(cfg.grad_accum_dtype)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if M == 1:
            (total, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((M, x.shape[0] // M) + x.shape[1:])

            mb = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)

            def acc(carry, mb_i):
                g_sum, tot_sum, m_sum = carry
                (tot, met), g = grads_of(params, mb_i)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_sum, g)
                m_sum = jax.tree.map(lambda a, b: a + b, m_sum, met)
                return (g_sum, tot_sum + tot, m_sum), None

            m0 = {"loss": jnp.float32(0.0), "aux_loss": jnp.float32(0.0)}
            if cfg.unroll_microbatches:
                carry = (g0, jnp.float32(0.0), m0)
                for i in range(M):
                    carry, _ = acc(carry, jax.tree.map(lambda x: x[i], mb))
                g_sum, total, m_sum = carry
            else:
                (g_sum, total, m_sum), _ = jax.lax.scan(
                    acc, (g0, jnp.float32(0.0), m0), mb)
            grads = jax.tree.map(lambda g: g / M, g_sum)
            total = total / M
            metrics = jax.tree.map(lambda x: x / M, m_sum)

        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, lr, opt)
        metrics = dict(metrics, **opt_metrics, total_loss=total)
        return params, opt_state, metrics

    return train_step, shd


def make_prefill_step(cfg, model, mesh):
    """serve prefill: full-sequence forward -> last-position logits."""
    shd = Sharder(cfg, mesh)

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, shd)
        return logits[:, -1, :]

    return prefill_step, shd


def make_decode_step(cfg, model, mesh):
    """serve decode: one token in, one token's logits out, cache updated."""
    shd = Sharder(cfg, mesh)

    def decode_step(params, cache, batch):
        logits, cache = model.decode_step(params, cache, batch["tokens"], shd)
        return logits[:, -1, :], cache

    return decode_step, shd
