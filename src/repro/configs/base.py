"""Base configuration dataclasses for the GEPS grid-brick framework.

Every assigned architecture is expressed as a ``ModelConfig``; every
input-shape cell as a ``ShapeConfig``.  Configs are frozen dataclasses so
they can be hashed into jit static args and recorded verbatim in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_to_multiple(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (exact values from the assignment table)."""

    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention ---
    qk_norm: bool = False
    rope_style: str = "neox"  # neox | half (chatglm 2d) | none
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None  # grok-1 style
    attn_scale_override: Optional[float] = None

    # --- mlp ---
    mlp_style: str = "swiglu"  # swiglu | geglu | gelu

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_sharding: str = "tp"  # tp: shard d_ff over model axis | ep: shard experts

    # --- hybrid (recurrentgemma): repeating block pattern ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "local_attn")
    lru_width: Optional[int] = None
    attention_window: Optional[int] = None  # local attention window (hybrid)
    conv1d_width: int = 4

    # --- xLSTM ---
    xlstm_pattern: Tuple[str, ...] = ()  # e.g. ("mlstm",) or ("slstm","mlstm")

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper: 30 s audio -> 1500 frames
    attn_bias: bool = False  # q/v/o projection biases (whisper)
    learned_pos_embed: bool = False  # decoder learned positions (whisper)
    max_positions: int = 32768  # learned pos-embed table size

    # --- vlm (pixtral): stub patch embeddings prepended to the sequence ---
    num_patches: int = 0

    # --- norms / embeddings ---
    embed_scale: float = 1.0  # sqrt(d_model) for gemma/grok-style models
    moe_group_size: int = 1024  # tokens per routing group (capacity locality)
    moe_capacity_factor: float = 1.25
    norm_eps: float = 1e-6
    norm_style: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    post_attn_norm: bool = False  # extra sandwich norms (grok style)

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- compile/perf knobs (hillclimbed in EXPERIMENTS.md section Perf) ---
    remat_policy: str = "full"  # none | full | dots
    scan_layers: bool = True
    remat_segments: int = 0  # >0: two-level (sqrt) remat — scan G segments
    #   of K layers with the segment checkpointed; bounds the saved residual
    #   stack at G carries instead of L (kills the L x (B,S,d) f32 hoist)
    use_pallas: bool = False  # CPU container: pure-JAX path for lowering
    seq_shard_norm: bool = False  # sequence-parallel norms (perf pass)
    fsdp_params: bool = True  # shard params over the data axis (ZeRO-3)
    grad_compression: str = "none"  # none | int8_cross_pod
    microbatches: int = 1  # gradient-accumulation steps per train_step
    unroll_microbatches: bool = False  # python-loop accumulation: avoids
    #   the while-carry double buffer of the full gradient tree
    opt_moment_dtype: str = "float32"  # bf16 for models that only fit
    #   256 chips with low-precision moments (grok-1: 314B x 10B > 4TB)
    grad_accum_dtype: str = "float32"
    pad_heads_to: int = 0  # pad q-heads to a multiple (0 = off); padded
    #   heads are zero-masked so the math is EXACTLY the unpadded model —
    #   this buys even 16-way TP sharding for head counts like 40 or 24.
    decode_cache_seq_shard: bool = True  # grid-brick KV cache: shard the
    #   cache sequence dim over the model axis and merge partial softmax
    #   stats (the paper's split->local-compute->merge, applied to KV)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded so the embedding shards evenly over 16-way TP and
        lands on MXU-friendly multiples of 128 (lcm(128, 16) -> use 256)."""
        return pad_to_multiple(self.vocab_size, 256)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(1, self.num_kv_heads)

    @property
    def num_heads_padded(self) -> int:
        if self.pad_heads_to and self.num_heads % self.pad_heads_to:
            return pad_to_multiple(self.num_heads, self.pad_heads_to)
        return self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and not any(
            b == "attn" for b in self.xlstm_pattern
        )

    @property
    def supports_long_context(self) -> bool:
        """True when decode state is sub-quadratic in context (O(1) recurrent
        state and/or window-bounded KV): required for the long_500k cell."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None or self.attention_window is not None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment table."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
