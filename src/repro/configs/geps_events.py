"""The paper's own workload: LHC-style event processing.

Each *event* is ~1 MB of raw detector data (paper section 1.1).  We model it
columnar (the ROOT-tree role): per-event scalars plus a tracks matrix.
``EventWorkloadConfig`` sizes one event at ~1 MB to match the paper, and the
Fig-7 crossover benchmark sweeps ``events_per_file`` exactly as the paper
swept raw-event-file size (watershed observed at ~2000 events).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EventWorkloadConfig:
    name: str = "geps-events"
    # one event: scalars + (max_tracks x track_vars) f32 ~ 1 MB (paper 1.1)
    n_scalars: int = 64
    max_tracks: int = 4096
    track_vars: int = 63
    # brick layout
    events_per_brick: int = 256
    replication_factor: int = 2  # paper section 7: redundancy future work
    # calibration passes per event (paper 4.1 "calibration procedure")
    calib_iters: int = 4

    @property
    def event_bytes(self) -> int:
        return 4 * (self.n_scalars + self.max_tracks * self.track_vars)


CONFIG = EventWorkloadConfig()


def reduced() -> EventWorkloadConfig:
    return EventWorkloadConfig(
        n_scalars=8, max_tracks=32, track_vars=7, events_per_brick=16)
