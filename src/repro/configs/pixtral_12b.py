"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT frontend STUBBED (input_specs supplies
precomputed patch embeddings), mistral-nemo decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    num_patches=256,  # stub ViT: 256 precomputed patch embeddings / sample
    rope_style="neox",
    rope_theta=1_000_000.0,
    mlp_style="swiglu",
    norm_style="rmsnorm",
    norm_eps=1e-5,
    microbatches=8,
)
