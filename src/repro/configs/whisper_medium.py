"""whisper-medium [audio] — 24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865 — encoder-decoder, conv frontend STUBBED (input_specs supplies
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,  # decoder layers
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,  # full MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_seq_len=1500,  # 30 s audio -> 1500 frames after the conv stub
    rope_style="none",
    learned_pos_embed=True,
    max_positions=32768,  # decode_32k cell needs learned positions to 32k
    mlp_style="gelu",
    norm_style="layernorm",
    norm_eps=1e-5,
    attn_bias=True,
    microbatches=2,
)
