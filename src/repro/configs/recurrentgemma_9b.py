"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427 (Griffin); unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,  # 12 x (rec, rec, attn) + (rec, rec)
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    rope_style="neox",
    rope_theta=10_000.0,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    attention_window=2048,  # local attention -> O(window) decode state
    conv1d_width=4,
    mlp_style="geglu",
    norm_style="rmsnorm",
    norm_eps=1e-6,
    tie_embeddings=True,
    embed_scale=64.0,  # sqrt(d_model), gemma convention
    microbatches=8,
)
