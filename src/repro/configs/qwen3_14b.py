"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_style="neox",
    rope_theta=1_000_000.0,
    mlp_style="swiglu",
    norm_style="rmsnorm",
    norm_eps=1e-6,
    pad_heads_to=16,  # 40 heads -> 48 zero-masked, even 16-way TP
    microbatches=8,
)
