"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    num_experts_per_tok=2,
    moe_sharding="ep",  # 16 experts == 16-way model axis: 1 expert/chip
    rope_style="neox",
    rope_theta=10_000.0,
    mlp_style="swiglu",
    norm_style="layernorm",
    norm_eps=1e-5,
    attn_bias=False,
    microbatches=8,
    moe_group_size=1024,
)
