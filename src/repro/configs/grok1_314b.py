"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 — attention logit softcap 30, sandwich
norms, sqrt(d) embedding scale.  [hf:xai-org/grok-1; unverified]"""
import math

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_experts_per_tok=2,
    moe_sharding="tp",  # 8 experts don't divide 16-way TP: shard d_ff
    #                     (expert compute stays brick-local, GEPS-style)
    rope_style="neox",
    rope_theta=10_000.0,
    attn_logit_softcap=30.0,
    post_attn_norm=True,  # grok sandwich norms
    mlp_style="swiglu",
    norm_style="rmsnorm",
    norm_eps=1e-5,
    embed_scale=math.sqrt(6144.0),
    microbatches=16,
    remat_segments=8,  # sqrt remat: 8 segments x 8 layers
    moe_group_size=1024,
    opt_moment_dtype="bfloat16",
    grad_accum_dtype="bfloat16",  # f32 accumulator tree would add 2x4.9 GB
    # NOTE: 314B x 10B/param would exceed the pod 4TB HBM; bf16 moments
    # bring params+opt to 6B/param = 1.9 TB (documented in DESIGN.md)
)
