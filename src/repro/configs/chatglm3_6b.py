"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d (partial rotary on half the head dim), GQA.
[arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope_style="half",  # GLM 2D/partial rotary: first half of head dim
    rope_theta=10_000.0,
    mlp_style="swiglu",
    norm_style="rmsnorm",
    norm_eps=1e-5,
    microbatches=4,
)
