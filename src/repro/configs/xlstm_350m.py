"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks at 7:1 (xLSTM[7:1]); O(1) recurrent decode state.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,  # 3 x (7 mLSTM + 1 sLSTM)
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,  # no standard FFN: mLSTM blocks carry the up-projection
    vocab_size=50304,
    xlstm_pattern=("mlstm",) * 7 + ("slstm",),
    conv1d_width=4,
    rope_style="none",
    norm_style="rmsnorm",
    norm_eps=1e-6,
    microbatches=4,  # 19.7 -> 5.1 GB temp (sequential cells are state-heavy)
)
