"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_style="neox",
    rope_theta=1_000_000.0,
    mlp_style="swiglu",
    norm_style="rmsnorm",
    norm_eps=1e-6,
    microbatches=8,
    remat_segments=8,  # sqrt remat over 64 layers: 18.1 -> 8.2 GB temp
)
