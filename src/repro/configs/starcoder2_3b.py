"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, sliding-window 4096, layernorm + biases,
plain-GELU MLP.  [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    rope_style="neox",
    rope_theta=100_000.0,
    sliding_window=4096,  # arXiv:2402.19173 section 2: 4096-token window ->
    #                       window-bounded KV makes long_500k decode feasible
    mlp_style="gelu",
    norm_style="layernorm",
    norm_eps=1e-5,
    attn_bias=True,
    pad_heads_to=16,  # 24 heads -> 32 zero-masked for even 16-way TP
    microbatches=4,
)
