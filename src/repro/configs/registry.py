"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

# arch id -> module name under repro.configs
_ARCH_MODULES = {
    "qwen3-32b": "qwen3_32b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-14b": "qwen3_14b",
    "chatglm3-6b": "chatglm3_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-medium": "whisper_medium",
    "grok-1-314b": "grok1_314b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "xlstm-350m": "xlstm_350m",
    "pixtral-12b": "pixtral_12b",
    "geps-events": "geps_events",  # the paper's own event-processing workload
}


def list_archs() -> List[str]:
    return [a for a in _ARCH_MODULES if a != "geps-events"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def reduced_config(arch: str, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: few layers, narrow,
    small vocab — preserves every structural feature (GQA ratio, qk-norm,
    MoE top-k, block patterns, enc-dec, patches...)."""
    cfg = get_config(arch)
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    # preserve GQA (kv < heads) whenever the full config has it
    if cfg.num_kv_heads < cfg.num_heads and kv >= heads:
        kv = max(1, heads // 2)
    head_dim = 16
    d_model = heads * head_dim * 2  # keep d_model != heads*head_dim (q proj real)
    changes = dict(
        num_layers=min(cfg.num_layers, 4),
        remat_segments=min(cfg.remat_segments, 2),
        microbatches=1,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
        param_dtype="float32",
        moe_group_size=64,
    )
    if cfg.num_experts:
        changes["num_experts"] = min(cfg.num_experts, 4)
        changes["num_experts_per_tok"] = min(cfg.num_experts_per_tok, 2)
    if cfg.num_encoder_layers:
        changes["num_encoder_layers"] = min(cfg.num_encoder_layers, 2)
        changes["encoder_seq_len"] = 32
    if cfg.lru_width:
        changes["lru_width"] = d_model
    if cfg.xlstm_pattern:
        changes["xlstm_pattern"] = ("mlstm", "slstm")  # keep both kinds
    if cfg.attention_window:
        changes["attention_window"] = 16
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    if cfg.num_patches:
        changes["num_patches"] = 4
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
