"""Pure-jnp oracle for the flash-attention kernel: the repeat-KV GQA
attention from models/attention.py, re-exported with the kernel's exact
signature."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attention


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None,
                        logit_cap=None):
    """q (B,Sq,H,D), k/v (B,Sk,K,D) -> (B,Sq,H,D)."""
    sq, sk = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq, dtype=jnp.int32) + (sk - sq if causal else 0)
    k_pos = jnp.arange(sk, dtype=jnp.int32)
    return attention(q, k, v, q_positions=q_pos, k_positions=k_pos,
                     causal=causal, window=window, scale=scale,
                     logit_cap=logit_cap)
