"""Flash attention (forward) Pallas TPU kernel with GQA and windowing.

VMEM tiling: grid (B, H, nQ, nK) with the KV-block axis innermost
(sequential on TPU), so the online-softmax accumulators (m, l, acc) live in
VMEM scratch across KV blocks and each Q tile streams K/V exactly once.
Block shapes default to (128, head_dim) tiles — MXU-aligned (128 lanes) —
and the KV-head index map implements GQA without materializing repeated KV
(the repeat in the pure-JAX path is a sharding device, not a memory-traffic
choice; on TPU the kernel indexes the right KV head directly).

Causality/window: blocks fully outside the allowed band are masked (the
index-map still visits them; block skipping is a perf refinement tracked
in EXPERIMENTS.md section Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            logit_cap: Optional[float], block_q: int, block_k: int,
            sq: int, sk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0, 0, :, :].astype(jnp.float32)       # (BK, D)
    v = v_ref[0, 0, :, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    # positions: queries are the LAST sq positions of the sk context
    q_pos = (qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
             + (sk - sq if causal else 0))
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = k_pos < sk
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_new = jnp.maximum(m_new, 0.1 * NEG_INF)  # masked-block guard
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_scr[...]
                          / jnp.maximum(l_scr[...], 1e-30)[:, None]
                          ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    logit_cap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Entry point (see flash_attention_pallas docstring)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0
    g = h // kh
    scale_v = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (b, h, pl.cdiv(sq, block_q), pl.cdiv(sk, block_k))

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale_v, causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k, sq=sq, sk=sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
