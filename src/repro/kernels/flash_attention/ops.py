"""Jitted wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _fa


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "logit_cap", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    logit_cap=None, block_q=128, block_k=128,
                    interpret=None):
    return _fa(q, k, v, causal=causal, window=window, scale=scale,
               logit_cap=logit_cap, block_q=block_q, block_k=block_k,
               interpret=interpret)
