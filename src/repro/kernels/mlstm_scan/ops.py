"""Jitted wrapper for the chunkwise mLSTM kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm_scan.kernel import mlstm_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def mlstm(q, k, v, log_i, log_f, *, interpret: bool | None = None):
    return mlstm_pallas(q, k, v, log_i, log_f, interpret=interpret)
