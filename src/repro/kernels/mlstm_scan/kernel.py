"""Chunkwise-parallel mLSTM, Pallas TPU kernel.

Same VMEM dataflow as flash attention — grid (B, H, nQ, nK), KV innermost,
online accumulators in scratch — but the softmax is replaced by the xLSTM
gate algebra: weight(t,s) = exp(F_t - F_s + i_s - m_t) * (q_t . k_s)/sqrt(d)
and the output normalizer is max(|sum_s w * qk|, exp(-m_t)).

F (cumulative log forget) and i (log input gate) stream in as (B,H,S)
tiles alongside K/V; the running max m tracks only the gate part (the
paper's stabilizer), not the dot products.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, fq_ref, fk_ref, li_ref, o_ref,
            m_scr, num_scr, den_scr, *, scale: float, block_q: int,
            block_k: int, s_total: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        num_scr[...] = jnp.zeros_like(num_scr)
        den_scr[...] = jnp.zeros_like(den_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale   # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)           # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    fq = fq_ref[0, 0].astype(jnp.float32)         # (BQ,) cumulative log f
    fk = fk_ref[0, 0].astype(jnp.float32)         # (BK,)
    li = li_ref[0, 0].astype(jnp.float32)         # (BK,) log input gate

    logw = fq[:, None] - fk[None, :] + li[None, :]  # (BQ, BK)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, logw.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, logw.shape, 1)
    valid = (k_pos <= q_pos) & (k_pos < s_total)
    logw = jnp.where(valid, logw, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(logw, axis=-1)), 0.1 * NEG)
    wts = jnp.exp(logw - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)

    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
    a = wts * sc
    num_scr[...] = (num_scr[...] * corr[:, None]
                    + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ()))))
    den_scr[...] = den_scr[...] * corr + jnp.sum(a, axis=-1)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        norm = jnp.maximum(jnp.abs(den_scr[...]), jnp.exp(-m_scr[...]))
        o_ref[0, 0] = (num_scr[...] / norm[:, None]).astype(o_ref.dtype)


def mlstm_pallas(q, k, v, log_i, log_f, *, block_q: int = 128,
                 block_k: int = 128, interpret: bool | None = None):
    """q,k,v: (B,S,H,D); log_i/log_f: (B,S,H) f32 -> (B,S,H,D)."""
    b, s, h, d = q.shape
    scale = d ** -0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    grid = (b, h, pl.cdiv(s, block_q), pl.cdiv(s, block_k))

    F = jnp.cumsum(log_f, axis=1)  # (B,S,H)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    Ft = F.transpose(0, 2, 1)
    lit = log_i.transpose(0, 2, 1)

    kernel = functools.partial(_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, s_total=s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, qi, ki: (bb, hh, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, qi, ki: (bb, hh, ki, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bb, hh, qi, ki: (bb, hh, qi)),
            pl.BlockSpec((1, 1, block_k), lambda bb, hh, qi, ki: (bb, hh, ki)),
            pl.BlockSpec((1, 1, block_k), lambda bb, hh, qi, ki: (bb, hh, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qt, kt, vt, Ft, Ft, lit)  # F streamed twice: q-tile view + k-tile view
    return out.transpose(0, 2, 1, 3)
