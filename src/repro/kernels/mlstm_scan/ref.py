"""Pure-jnp oracle for the mLSTM chunkwise kernel: re-export of the
model's parallel formulation with the kernel's signature."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.xlstm import mlstm_parallel


def mlstm_ref(q, k, v, log_i, log_f, *, chunk_size: int = 1024):
    """q,k,v: (B,S,H,D); log_i/log_f: (B,S,H) f32 -> (B,S,H,D)."""
    return mlstm_parallel(None, q, k, v, log_i, log_f,
                          chunk_size=chunk_size)
