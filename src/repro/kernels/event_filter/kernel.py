"""Fused event filter+calibrate+reduce Pallas TPU kernel.

TPU adaptation of the paper's per-node event-processing loop: instead of
the CPU's "calibrate file, write it back, re-read to filter" (three HBM
passes on TPU), one VMEM pass per track tile computes calibration and the
track aggregates, accumulating per-event partials in VMEM across track
tiles — tracks stream HBM->VMEM exactly once.

Grid: (event_blocks, track_tiles); the track-tile axis is the fast
(sequential) axis, so the per-event accumulators live in the output blocks
(count/sum), which Pallas keeps resident in VMEM across the inner axis.

BlockSpecs (VMEM):
  scalars  (BE, n_scalars)  — event axis blocked, revisited per track tile
  tracks   (BE, BT, V)      — both axes blocked (the streamed operand)
  n_tracks (BE, 1)
  outputs: mask (BE,), var (BE,), cnt (BE,), ssum (BE,)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scalars_ref, tracks_ref, ntr_ref, thr_ref,
            mask_ref, var_ref, cnt_ref, sum_ref, *,
            calib_iters: int, var_idx: int, block_t: int):
    tt = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    @pl.when(tt == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    trk = tracks_ref[...].astype(jnp.float32)  # (BE, BT, V)

    def body(i, t):
        pt = t[..., 0:1]
        corr = 1.0 + 0.01 * jnp.tanh(t) * jax.lax.rsqrt(1.0 + pt * pt)
        return t * corr

    trk = jax.lax.fori_loop(0, calib_iters, body, trk)
    pt = trk[..., 0]  # (BE, BT)

    # validity: global track index < n_tracks
    t0 = tt * block_t
    tidx = t0 + jax.lax.broadcasted_iota(jnp.int32, pt.shape, 1)
    valid = tidx < ntr_ref[...]  # (BE, BT) via (BE,1) broadcast

    pt_thresh = thr_ref[1]
    cnt_ref[...] += jnp.sum(
        jnp.where(valid & (pt > pt_thresh), 1.0, 0.0), axis=-1)
    sum_ref[...] += jnp.sum(jnp.where(valid, pt, 0.0), axis=-1)

    @pl.when(tt == n_tiles - 1)
    def _finalize():
        scalar_thresh, _, min_count, sum_cap = (
            thr_ref[0], thr_ref[1], thr_ref[2], thr_ref[3])
        sc = scalars_ref[...].astype(jnp.float32)  # (BE, n_scalars)
        mask = (sc[:, var_idx] > scalar_thresh) & (cnt_ref[...] >= min_count)
        mask = mask & jnp.where(sum_cap > 0, sum_ref[...] < sum_cap, True)
        mask_ref[...] = mask.astype(jnp.float32)
        var_ref[...] = sc[:, 0]


def _batch_kernel(scalars_ref, tracks_ref, ntr_ref, thr_ref,
                  mask_ref, var_ref, cnt_ref, sum_ref, *,
                  calib_iters: int, var_idx: tuple, block_t: int):
    """K-query shared scan: tracks stream HBM->VMEM once; the per-query
    track counts (cnt is (BE, K)) and masks amortize that single read
    across the whole coalesced batch.  sum(pt) is query-independent, so
    one (BE,) accumulator serves every query."""
    tt = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    @pl.when(tt == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    trk = tracks_ref[...].astype(jnp.float32)  # (BE, BT, V)

    def body(i, t):
        pt = t[..., 0:1]
        corr = 1.0 + 0.01 * jnp.tanh(t) * jax.lax.rsqrt(1.0 + pt * pt)
        return t * corr

    trk = jax.lax.fori_loop(0, calib_iters, body, trk)
    pt = trk[..., 0]  # (BE, BT)

    t0 = tt * block_t
    tidx = t0 + jax.lax.broadcasted_iota(jnp.int32, pt.shape, 1)
    valid = tidx < ntr_ref[...]  # (BE, BT)

    pt_thr = thr_ref[1, :]       # (K,)
    hit = valid[..., None] & (pt[..., None] > pt_thr)  # (BE, BT, K)
    cnt_ref[...] += jnp.sum(jnp.where(hit, 1.0, 0.0), axis=1)
    sum_ref[...] += jnp.sum(jnp.where(valid, pt, 0.0), axis=-1)

    @pl.when(tt == n_tiles - 1)
    def _finalize():
        sc = scalars_ref[...].astype(jnp.float32)  # (BE, n_scalars)
        # per-query scalar variable: static gather, K is small
        sc_sel = jnp.stack([sc[:, i] for i in var_idx], axis=-1)  # (BE, K)
        mask = (sc_sel > thr_ref[0, :]) & (cnt_ref[...] >= thr_ref[2, :])
        mask = mask & jnp.where(thr_ref[3, :] > 0,
                                sum_ref[...][:, None] < thr_ref[3, :], True)
        mask_ref[...] = mask.astype(jnp.float32)
        var_ref[...] = sc[:, 0]


def event_filter_batch_pallas(scalars, tracks, n_tracks, thresholds, *,
                              var_idx: tuple, calib_iters: int,
                              block_e: int = 128, block_t: int = 512,
                              interpret: bool = True):
    """Batched variant: thresholds (4, K) f32 = per-query
    [scalar_thresh; pt_thresh; min_count; sum_cap] columns, var_idx a
    static K-tuple of scalar indices.  Returns (mask (N, K), var (N,))."""
    n, s = scalars.shape
    _, t, v = tracks.shape
    k = thresholds.shape[1]
    block_e = min(block_e, n)
    block_t = min(block_t, t)
    grid = (pl.cdiv(n, block_e), pl.cdiv(t, block_t))

    kernel = functools.partial(_batch_kernel, calib_iters=calib_iters,
                               var_idx=tuple(var_idx), block_t=block_t)
    mask, var, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, s), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e, block_t, v), lambda e, tt: (e, tt, 0)),
            pl.BlockSpec((block_e, 1), lambda e, tt: (e, 0)),
            pl.BlockSpec((4, k), lambda e, tt: (0, 0)),  # thresholds (whole)
        ],
        out_specs=[
            pl.BlockSpec((block_e, k), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e, k), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, tracks, n_tracks[:, None], thresholds)
    return mask, var


def event_filter_pallas(scalars, tracks, n_tracks, thresholds, *,
                        var_idx: int, calib_iters: int,
                        block_e: int = 128, block_t: int = 512,
                        interpret: bool = True):
    """scalars (N,S) f32, tracks (N,T,V) f32, n_tracks (N,) i32,
    thresholds (4,) f32 = [scalar_thresh, pt_thresh, min_count, sum_cap].
    Returns (mask (N,), var (N,))."""
    n, s = scalars.shape
    _, t, v = tracks.shape
    block_e = min(block_e, n)
    block_t = min(block_t, t)
    grid = (pl.cdiv(n, block_e), pl.cdiv(t, block_t))

    kernel = functools.partial(_kernel, calib_iters=calib_iters,
                               var_idx=var_idx, block_t=block_t)
    mask, var, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, s), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e, block_t, v), lambda e, tt: (e, tt, 0)),
            pl.BlockSpec((block_e, 1), lambda e, tt: (e, 0)),
            pl.BlockSpec((4,), lambda e, tt: (0,)),  # thresholds (whole)
        ],
        out_specs=[
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, tracks, n_tracks[:, None], thresholds)
    return mask, var
