"""Fused event filter+calibrate+reduce Pallas TPU kernel.

TPU adaptation of the paper's per-node event-processing loop: instead of
the CPU's "calibrate file, write it back, re-read to filter" (three HBM
passes on TPU), one VMEM pass per track tile computes calibration and the
track aggregates, accumulating per-event partials in VMEM across track
tiles — tracks stream HBM->VMEM exactly once.

Grid: (event_blocks, track_tiles); the track-tile axis is the fast
(sequential) axis, so the per-event accumulators live in the output blocks
(count/sum), which Pallas keeps resident in VMEM across the inner axis.

BlockSpecs (VMEM):
  scalars  (BE, n_scalars)  — event axis blocked, revisited per track tile
  tracks   (BE, BT, V)      — both axes blocked (the streamed operand)
  n_tracks (BE, 1)
  outputs: mask (BE,), var (BE,), cnt (BE,), ssum (BE,)

Non-divisible grids are explicit here, not an accident of ``pl.cdiv``
padding: both kernel bodies mask the tail tile on BOTH axes (track
columns past ``t_total`` never reach the accumulators even when an
``n_tracks`` row is garbage in the padded region; event rows past
``n_total`` finalize to zeros instead of whatever the pad holds), and the
wrappers validate shapes up front — a zero-sized operand raises a clear
``ValueError`` instead of a Pallas trace error.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret


def _validate(scalars, tracks, n_tracks, thresholds, *, batch: bool,
              block_e: int, block_t: int):
    """Shape/size validation shared by both wrappers (see module doc):
    reject zero-sized operands and malformed thresholds BEFORE tracing,
    with errors that name the offending operand."""
    if scalars.ndim != 2 or tracks.ndim != 3 or n_tracks.ndim != 1:
        raise ValueError(
            f"event_filter expects scalars (N,S), tracks (N,T,V), "
            f"n_tracks (N,); got {scalars.shape}, {tracks.shape}, "
            f"{n_tracks.shape}")
    n, _ = scalars.shape
    nt, t, v = tracks.shape
    if n == 0 or t == 0 or v == 0:
        raise ValueError(
            f"event_filter got a zero-sized operand (scalars {scalars.shape}, "
            f"tracks {tracks.shape}): empty chunks must be skipped by the "
            f"caller, the kernel has no zero-width grid")
    if nt != n or n_tracks.shape[0] != n:
        raise ValueError(
            f"event axis mismatch: scalars N={n}, tracks N={nt}, "
            f"n_tracks N={n_tracks.shape[0]}")
    if block_e <= 0 or block_t <= 0:
        raise ValueError(
            f"block shapes must be positive, got block_e={block_e}, "
            f"block_t={block_t}")
    if batch:
        if thresholds.ndim != 2 or thresholds.shape[0] != 4 \
                or thresholds.shape[1] == 0:
            raise ValueError(
                f"batched thresholds must be (4, K) with K >= 1, got "
                f"{thresholds.shape}")
    elif thresholds.shape != (4,):
        raise ValueError(f"thresholds must be (4,), got {thresholds.shape}")


def _tile_masks(ntr_ref, shape, *, block_e: int, block_t: int,
                n_total: int, t_total: int):
    """Explicit tail-tile masking for a (BE, BT) tile: ``valid`` is the
    per-track validity (global track index < n_tracks AND < t_total — the
    second clause is what keeps a garbage ``n_tracks`` pad row from
    pulling padded track columns into the accumulators) and ``valid_e``
    the per-event validity (global event index < n_total)."""
    tt = pl.program_id(1)
    eb = pl.program_id(0)
    tidx = tt * block_t + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    eidx = eb * block_e + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    valid = (tidx < ntr_ref[...]) & (tidx < t_total) & (eidx < n_total)
    valid_e = (eidx[:, 0] < n_total)
    return valid, valid_e


def _kernel(scalars_ref, tracks_ref, ntr_ref, thr_ref,
            mask_ref, var_ref, cnt_ref, sum_ref, *,
            calib_iters: int, var_idx: int, block_e: int, block_t: int,
            n_total: int, t_total: int):
    tt = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    @pl.when(tt == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    trk = tracks_ref[...].astype(jnp.float32)  # (BE, BT, V)

    def body(i, t):
        pt = t[..., 0:1]
        corr = 1.0 + 0.01 * jnp.tanh(t) * jax.lax.rsqrt(1.0 + pt * pt)
        return t * corr

    trk = jax.lax.fori_loop(0, calib_iters, body, trk)
    pt = trk[..., 0]  # (BE, BT)

    valid, valid_e = _tile_masks(ntr_ref, pt.shape, block_e=block_e,
                                 block_t=block_t, n_total=n_total,
                                 t_total=t_total)

    pt_thresh = thr_ref[1]
    cnt_ref[...] += jnp.sum(
        jnp.where(valid & (pt > pt_thresh), 1.0, 0.0), axis=-1)
    sum_ref[...] += jnp.sum(jnp.where(valid, pt, 0.0), axis=-1)

    @pl.when(tt == n_tiles - 1)
    def _finalize():
        scalar_thresh, _, min_count, sum_cap = (
            thr_ref[0], thr_ref[1], thr_ref[2], thr_ref[3])
        sc = scalars_ref[...].astype(jnp.float32)  # (BE, n_scalars)
        mask = (sc[:, var_idx] > scalar_thresh) & (cnt_ref[...] >= min_count)
        mask = mask & jnp.where(sum_cap > 0, sum_ref[...] < sum_cap, True)
        mask_ref[...] = (mask & valid_e).astype(jnp.float32)
        var_ref[...] = jnp.where(valid_e, sc[:, 0], 0.0)


def _batch_kernel(scalars_ref, tracks_ref, ntr_ref, thr_ref,
                  mask_ref, var_ref, cnt_ref, sum_ref, *,
                  calib_iters: int, var_idx: tuple, block_e: int,
                  block_t: int, n_total: int, t_total: int):
    """K-query shared scan: tracks stream HBM->VMEM once; the per-query
    track counts (cnt is (BE, K)) and masks amortize that single read
    across the whole coalesced batch.  sum(pt) is query-independent, so
    one (BE,) accumulator serves every query."""
    tt = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    @pl.when(tt == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    trk = tracks_ref[...].astype(jnp.float32)  # (BE, BT, V)

    def body(i, t):
        pt = t[..., 0:1]
        corr = 1.0 + 0.01 * jnp.tanh(t) * jax.lax.rsqrt(1.0 + pt * pt)
        return t * corr

    trk = jax.lax.fori_loop(0, calib_iters, body, trk)
    pt = trk[..., 0]  # (BE, BT)

    valid, valid_e = _tile_masks(ntr_ref, pt.shape, block_e=block_e,
                                 block_t=block_t, n_total=n_total,
                                 t_total=t_total)

    pt_thr = thr_ref[1, :]       # (K,)
    hit = valid[..., None] & (pt[..., None] > pt_thr)  # (BE, BT, K)
    cnt_ref[...] += jnp.sum(jnp.where(hit, 1.0, 0.0), axis=1)
    sum_ref[...] += jnp.sum(jnp.where(valid, pt, 0.0), axis=-1)

    @pl.when(tt == n_tiles - 1)
    def _finalize():
        sc = scalars_ref[...].astype(jnp.float32)  # (BE, n_scalars)
        # per-query scalar variable: static gather, K is small
        sc_sel = jnp.stack([sc[:, i] for i in var_idx], axis=-1)  # (BE, K)
        mask = (sc_sel > thr_ref[0, :]) & (cnt_ref[...] >= thr_ref[2, :])
        mask = mask & jnp.where(thr_ref[3, :] > 0,
                                sum_ref[...][:, None] < thr_ref[3, :], True)
        mask_ref[...] = (mask & valid_e[:, None]).astype(jnp.float32)
        var_ref[...] = jnp.where(valid_e, sc[:, 0], 0.0)


def event_filter_batch_pallas(scalars, tracks, n_tracks, thresholds, *,
                              var_idx: tuple, calib_iters: int,
                              block_e: int = 128, block_t: int = 512,
                              interpret: bool | None = None):
    """Batched variant: thresholds (4, K) f32 = per-query
    [scalar_thresh; pt_thresh; min_count; sum_cap] columns, var_idx a
    static K-tuple of scalar indices.  Returns (mask (N, K), var (N,)).
    ``interpret=None`` auto-detects (compiled on TPU/GPU, interpreter on
    CPU — see ``repro.kernels.default_interpret``)."""
    _validate(scalars, tracks, n_tracks, thresholds, batch=True,
              block_e=block_e, block_t=block_t)
    n, s = scalars.shape
    _, t, v = tracks.shape
    k = thresholds.shape[1]
    block_e = min(block_e, n)
    block_t = min(block_t, t)
    grid = (pl.cdiv(n, block_e), pl.cdiv(t, block_t))

    kernel = functools.partial(_batch_kernel, calib_iters=calib_iters,
                               var_idx=tuple(var_idx), block_e=block_e,
                               block_t=block_t, n_total=n, t_total=t)
    mask, var, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, s), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e, block_t, v), lambda e, tt: (e, tt, 0)),
            pl.BlockSpec((block_e, 1), lambda e, tt: (e, 0)),
            pl.BlockSpec((4, k), lambda e, tt: (0, 0)),  # thresholds (whole)
        ],
        out_specs=[
            pl.BlockSpec((block_e, k), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e, k), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(scalars, tracks, n_tracks[:, None], thresholds)
    return mask, var


def event_filter_pallas(scalars, tracks, n_tracks, thresholds, *,
                        var_idx: int, calib_iters: int,
                        block_e: int = 128, block_t: int = 512,
                        interpret: bool | None = None):
    """scalars (N,S) f32, tracks (N,T,V) f32, n_tracks (N,) i32,
    thresholds (4,) f32 = [scalar_thresh, pt_thresh, min_count, sum_cap].
    Returns (mask (N,), var (N,)).  ``interpret=None`` auto-detects
    (compiled on TPU/GPU, interpreter on CPU)."""
    _validate(scalars, tracks, n_tracks, thresholds, batch=False,
              block_e=block_e, block_t=block_t)
    n, s = scalars.shape
    _, t, v = tracks.shape
    block_e = min(block_e, n)
    block_t = min(block_t, t)
    grid = (pl.cdiv(n, block_e), pl.cdiv(t, block_t))

    kernel = functools.partial(_kernel, calib_iters=calib_iters,
                               var_idx=var_idx, block_e=block_e,
                               block_t=block_t, n_total=n, t_total=t)
    mask, var, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, s), lambda e, tt: (e, 0)),
            pl.BlockSpec((block_e, block_t, v), lambda e, tt: (e, tt, 0)),
            pl.BlockSpec((block_e, 1), lambda e, tt: (e, 0)),
            pl.BlockSpec((4,), lambda e, tt: (0,)),  # thresholds (whole)
        ],
        out_specs=[
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
            pl.BlockSpec((block_e,), lambda e, tt: (e,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(scalars, tracks, n_tracks[:, None], thresholds)
    return mask, var
