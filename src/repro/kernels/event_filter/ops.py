"""Jitted wrapper for the event-filter kernel + query-AST pattern matcher.

``filter_and_summarize`` accepts the GEPS canonical hot-query family

    "<scalar> > A && count(pt > B) >= C [&& sum(pt) < D]"

extracts (A, B, C, D) from the parsed AST and dispatches to the fused
Pallas kernel; anything else falls back to the pure-jnp compiled query
(same results, just without the fusion win).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import query as q
from repro.kernels.event_filter.kernel import (event_filter_batch_pallas,
                                               event_filter_pallas)
from repro.kernels.event_filter.ref import (event_filter_batch_ref,
                                            event_filter_ref)


def match_canonical(expr: str, schema) -> Optional[dict]:
    """Returns kernel params if the expression matches the hot family."""
    try:
        ast = q.parse(expr)
    except q.QueryError:
        return None

    def is_cmp(node, op):
        return isinstance(node, q.Bin) and node.op == op

    terms = []

    def flatten_and(node):
        if isinstance(node, q.Bin) and node.op == "&&":
            flatten_and(node.lhs)
            flatten_and(node.rhs)
        else:
            terms.append(node)

    flatten_and(ast)
    out = {"sum_cap": -1.0}
    seen = set()
    for t in terms:
        # scalar threshold: Var > Num
        if (is_cmp(t, ">") and isinstance(t.lhs, q.Var)
                and isinstance(t.rhs, q.Num) and "scalar" not in seen):
            try:
                out["var_idx"] = schema.scalar_index(t.lhs.name)
            except ValueError:
                return None
            out["scalar_thresh"] = t.rhs.value
            seen.add("scalar")
        # count(pt > B) >= C
        elif (is_cmp(t, ">=") and isinstance(t.lhs, q.Agg)
              and t.lhs.fn == "count" and is_cmp(t.lhs.arg, ">")
              and isinstance(t.lhs.arg.lhs, q.Var)
              and t.lhs.arg.lhs.name == "pt"
              and isinstance(t.lhs.arg.rhs, q.Num)
              and isinstance(t.rhs, q.Num) and "count" not in seen):
            out["pt_thresh"] = t.lhs.arg.rhs.value
            out["min_count"] = t.rhs.value
            seen.add("count")
        # sum(pt) < D
        elif (is_cmp(t, "<") and isinstance(t.lhs, q.Agg)
              and t.lhs.fn == "sum" and isinstance(t.lhs.arg, q.Var)
              and t.lhs.arg.name == "pt" and isinstance(t.rhs, q.Num)):
            out["sum_cap"] = t.rhs.value
        else:
            return None
    if "scalar" not in seen or "count" not in seen:
        return None
    return out


@functools.partial(jax.jit, static_argnames=("var_idx", "calib_iters",
                                             "interpret", "use_pallas"))
def event_filter(scalars, tracks, n_tracks, thresholds, *, var_idx: int,
                 calib_iters: int, interpret: bool = True,
                 use_pallas: bool = True):
    if use_pallas:
        return event_filter_pallas(
            scalars, tracks, n_tracks, thresholds, var_idx=var_idx,
            calib_iters=calib_iters, interpret=interpret)
    return event_filter_ref(
        scalars, tracks, n_tracks, var_idx=var_idx,
        scalar_thresh=thresholds[0], pt_thresh=thresholds[1],
        min_count=thresholds[2], sum_cap=thresholds[3],
        calib_iters=calib_iters)


def filter_and_summarize(expr: str, schema, batch, *, calib_iters: int = 0,
                         interpret: bool = True):
    """(mask, var) for an arbitrary expression; Pallas path when canonical.

    NOTE: when the kernel handles calibration the caller must pass the RAW
    batch (core.jse passes calib_iters through here instead of
    pre-calibrating)."""
    params = match_canonical(expr, schema)
    if params is None:
        pred = q.compile_query(expr, schema)
        b = batch
        if calib_iters:
            b = dict(b, tracks=q.calibrate(b, calib_iters))
        return pred(b), b["scalars"][:, 0]
    thresholds = jnp.array([params["scalar_thresh"], params["pt_thresh"],
                            params["min_count"], params["sum_cap"]],
                           jnp.float32)
    return event_filter(
        batch["scalars"], batch["tracks"], batch["n_tracks"], thresholds,
        var_idx=params["var_idx"], calib_iters=calib_iters,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("var_idx", "calib_iters",
                                             "interpret", "use_pallas"))
def event_filter_batch(scalars, tracks, n_tracks, thresholds, *,
                       var_idx: Tuple[int, ...], calib_iters: int,
                       interpret: bool = True, use_pallas: bool = True):
    if use_pallas:
        return event_filter_batch_pallas(
            scalars, tracks, n_tracks, thresholds, var_idx=var_idx,
            calib_iters=calib_iters, interpret=interpret)
    return event_filter_batch_ref(
        scalars, tracks, n_tracks, thresholds, var_idx=var_idx,
        calib_iters=calib_iters)


def filter_and_summarize_batch(exprs, schema, batch, *, calib_iters: int = 0,
                               interpret: bool = True):
    """K-query shared scan: (masks (K, N), var (N,)).

    The fused batched kernel runs when EVERY expression matches the
    canonical hot family; a single non-canonical straggler drops the whole
    window to the stacked-predicate jnp path (still one sweep, one shared
    calibration — just without the kernel's track-streaming fusion)."""
    params = [match_canonical(e, schema) for e in exprs]
    if any(p is None for p in params):
        bpred = q.compile_query_batch(exprs, schema)
        b = batch
        if calib_iters:
            b = dict(b, tracks=q.calibrate(b, calib_iters))
        return bpred(b), b["scalars"][:, 0]
    thresholds = jnp.array(
        [[p["scalar_thresh"] for p in params],
         [p["pt_thresh"] for p in params],
         [p["min_count"] for p in params],
         [p["sum_cap"] for p in params]], jnp.float32)   # (4, K)
    var_idx = tuple(p["var_idx"] for p in params)
    mask, var = event_filter_batch(
        batch["scalars"], batch["tracks"], batch["n_tracks"], thresholds,
        var_idx=var_idx, calib_iters=calib_iters, interpret=interpret)
    return mask.T, var
