"""Jitted wrapper for the event-filter kernel + query-AST pattern matcher.

``filter_and_summarize`` accepts the GEPS canonical hot-query family

    "<scalar> > A && count(pt > B) >= C [&& sum(pt) < D]"

extracts (A, B, C, D) from the parsed AST and dispatches to the fused
Pallas kernel; anything else falls back to the pure-jnp compiled query
(same results, just without the fusion win).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import query as q
from repro.kernels.event_filter.kernel import (event_filter_batch_pallas,
                                               event_filter_pallas)
from repro.kernels.event_filter.ref import (event_filter_batch_ref,
                                            event_filter_ref)


def match_canonical(expr: str, schema) -> Optional[dict]:
    """Returns kernel params if the expression matches the FULL hot
    family — a strictness check over :func:`match_epilogue` (one matcher
    encodes the kernel's term shapes): both the scalar-threshold and the
    count terms must be present."""
    params = match_epilogue(expr, schema)
    if params is None or not {"scalar", "count"} <= params["terms"]:
        return None
    return params


def batch_kernel_params(params) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    """Assemble the batched kernel's inputs from per-target param dicts
    (``match_canonical`` / ``match_epilogue`` output): the ``(4, K)``
    float32 thresholds array — rows [scalar_thresh; pt_thresh;
    min_count; sum_cap] — and the static ``var_idx`` tuple.  The single
    place the kernel's threshold-row layout is encoded on the host
    side."""
    thresholds = jnp.array(
        [[p["scalar_thresh"] for p in params],
         [p["pt_thresh"] for p in params],
         [p["min_count"] for p in params],
         [p["sum_cap"] for p in params]], jnp.float32)   # (4, K)
    return thresholds, tuple(p["var_idx"] for p in params)


def match_epilogue(target, schema) -> Optional[dict]:
    """Relaxed matcher for kernel-EPILOGUE fusion of fragment-plan targets.

    ``match_canonical`` requires the full hot family (scalar threshold AND
    count term); a fragment plan's targets also include materialized
    boolean fragments that are *subsets* of it — a bare
    ``count(pt > 15) >= 2`` conjunct, a lone scalar cut.  This matcher
    accepts any ``&&``-conjunction of the kernel's three term shapes with
    each term OPTIONAL (at least one present):

        <scalar> > A    |    count(pt > B) >= C    |    sum(pt) < D

    and encodes missing terms as neutral thresholds the kernel epilogue
    already treats as pass-through: no scalar term -> ``scalar_thresh =
    -inf`` (any finite scalar passes), no count term -> ``min_count = 0``
    (the count accumulator is always >= 0), no sum term -> ``sum_cap =
    -1`` (the kernel's existing no-cap sentinel; a sum term with D <= 0
    is rejected rather than aliased onto it).  ``target`` is an AST node
    (what :meth:`FragmentPlan.targets` holds) or an expression string.
    Returns kernel params — with ``"terms"``, the set of term kinds that
    were present, so :func:`match_canonical` can impose its stricter
    full-family requirement — or None when the target is outside the
    family."""
    if isinstance(target, str):
        try:
            target = q.parse(target)
        except q.QueryError:
            return None

    def is_cmp(node, op):
        return isinstance(node, q.Bin) and node.op == op

    terms = []

    def flatten_and(node):
        if isinstance(node, q.Bin) and node.op == "&&":
            flatten_and(node.lhs)
            flatten_and(node.rhs)
        else:
            terms.append(node)

    flatten_and(target)
    out = {"var_idx": 0, "scalar_thresh": float("-inf"),
           "pt_thresh": 0.0, "min_count": 0.0, "sum_cap": -1.0}
    seen = set()
    for t in terms:
        if (is_cmp(t, ">") and isinstance(t.lhs, q.Var)
                and isinstance(t.rhs, q.Num) and "scalar" not in seen):
            try:
                out["var_idx"] = schema.scalar_index(t.lhs.name)
            except ValueError:
                return None
            out["scalar_thresh"] = t.rhs.value
            seen.add("scalar")
        elif (is_cmp(t, ">=") and isinstance(t.lhs, q.Agg)
              and t.lhs.fn == "count" and is_cmp(t.lhs.arg, ">")
              and isinstance(t.lhs.arg.lhs, q.Var)
              and t.lhs.arg.lhs.name == "pt"
              and isinstance(t.lhs.arg.rhs, q.Num)
              and isinstance(t.rhs, q.Num) and "count" not in seen):
            out["pt_thresh"] = t.lhs.arg.rhs.value
            out["min_count"] = t.rhs.value
            seen.add("count")
        elif (is_cmp(t, "<") and isinstance(t.lhs, q.Agg)
              and t.lhs.fn == "sum" and isinstance(t.lhs.arg, q.Var)
              and t.lhs.arg.name == "pt" and isinstance(t.rhs, q.Num)
              and t.rhs.value > 0 and "sum" not in seen):
            out["sum_cap"] = t.rhs.value
            seen.add("sum")
        else:
            return None
    if not seen:
        return None
    out["terms"] = frozenset(seen)
    return out


@functools.partial(jax.jit, static_argnames=("var_idx", "calib_iters",
                                             "interpret", "use_pallas",
                                             "block_e", "block_t"))
def event_filter(scalars, tracks, n_tracks, thresholds, *, var_idx: int,
                 calib_iters: int, interpret: Optional[bool] = None,
                 use_pallas: bool = True, block_e: int = 128,
                 block_t: int = 512):
    """Jitted single-query kernel dispatch: the Pallas path
    (``use_pallas=True``) or the jnp reference.  ``interpret=None``
    auto-detects (compiled on TPU/GPU, interpreter on CPU); ``block_e`` /
    ``block_t`` are the kernel's static block shapes (see
    :func:`autotune_block_shapes` in ``tune.py`` for the sweep)."""
    if use_pallas:
        return event_filter_pallas(
            scalars, tracks, n_tracks, thresholds, var_idx=var_idx,
            calib_iters=calib_iters, interpret=interpret,
            block_e=block_e, block_t=block_t)
    return event_filter_ref(
        scalars, tracks, n_tracks, var_idx=var_idx,
        scalar_thresh=thresholds[0], pt_thresh=thresholds[1],
        min_count=thresholds[2], sum_cap=thresholds[3],
        calib_iters=calib_iters)


def filter_and_summarize(expr: str, schema, batch, *, calib_iters: int = 0,
                         interpret: Optional[bool] = None):
    """(mask, var) for an arbitrary expression; Pallas path when canonical.

    NOTE: when the kernel handles calibration the caller must pass the RAW
    batch (core.jse passes calib_iters through here instead of
    pre-calibrating)."""
    params = match_canonical(expr, schema)
    if params is None:
        pred = q.compile_query(expr, schema)
        b = batch
        if calib_iters:
            b = dict(b, tracks=q.calibrate(b, calib_iters))
        return pred(b), b["scalars"][:, 0]
    thresholds = jnp.array([params["scalar_thresh"], params["pt_thresh"],
                            params["min_count"], params["sum_cap"]],
                           jnp.float32)
    return event_filter(
        batch["scalars"], batch["tracks"], batch["n_tracks"], thresholds,
        var_idx=params["var_idx"], calib_iters=calib_iters,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("var_idx", "calib_iters",
                                             "interpret", "use_pallas",
                                             "block_e", "block_t"))
def event_filter_batch(scalars, tracks, n_tracks, thresholds, *,
                       var_idx: Tuple[int, ...], calib_iters: int,
                       interpret: Optional[bool] = None,
                       use_pallas: bool = True, block_e: int = 128,
                       block_t: int = 512):
    """Jitted K-query kernel dispatch (see :func:`event_filter` for the
    flag semantics; thresholds are the ``(4, K)`` layout from
    :func:`batch_kernel_params`)."""
    if use_pallas:
        return event_filter_batch_pallas(
            scalars, tracks, n_tracks, thresholds, var_idx=var_idx,
            calib_iters=calib_iters, interpret=interpret,
            block_e=block_e, block_t=block_t)
    return event_filter_batch_ref(
        scalars, tracks, n_tracks, thresholds, var_idx=var_idx,
        calib_iters=calib_iters)


def filter_and_summarize_batch(exprs, schema, batch, *, calib_iters: int = 0,
                               interpret: Optional[bool] = None):
    """K-query shared scan: (masks (K, N), var (N,)).

    The fused batched kernel runs when EVERY expression matches the
    canonical hot family; a single non-canonical straggler drops the whole
    window to the stacked-predicate jnp path (still one sweep, one shared
    calibration — just without the kernel's track-streaming fusion)."""
    params = [match_canonical(e, schema) for e in exprs]
    if any(p is None for p in params):
        bpred = q.compile_query_batch(exprs, schema)
        b = batch
        if calib_iters:
            b = dict(b, tracks=q.calibrate(b, calib_iters))
        return bpred(b), b["scalars"][:, 0]
    thresholds, var_idx = batch_kernel_params(params)
    mask, var = event_filter_batch(
        batch["scalars"], batch["tracks"], batch["n_tracks"], thresholds,
        var_idx=var_idx, calib_iters=calib_iters, interpret=interpret)
    return mask.T, var
