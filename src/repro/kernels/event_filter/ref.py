"""Pure-jnp oracle for the fused event-filter kernel.

Canonical GEPS hot query family (the paper's filter+calibration job):

    mask = (scalars[:, var_idx] > scalar_thresh)
           & (count(calibrated_pt > pt_thresh) >= min_count)
           & (sum(calibrated_pt) < sum_cap)          [sum_cap <= 0: disabled]
    var  = scalars[:, 0]   (summary variable for the histogram/merge)

Calibration is the paper's section-4.1 iterative per-track refinement,
applied on the fly (the kernel fuses it with the reduction so tracks are
read from HBM exactly once).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def calibrate_tracks(tracks: jax.Array, iters: int) -> jax.Array:
    """tracks: (..., T, V) f32. Matches core.query.calibrate."""
    def body(i, trk):
        pt = trk[..., 0:1]
        corr = 1.0 + 0.01 * jnp.tanh(trk) * jax.lax.rsqrt(1.0 + pt * pt)
        return trk * corr

    return jax.lax.fori_loop(0, iters, body, tracks)


def event_filter_ref(scalars, tracks, n_tracks, *, var_idx: int,
                     scalar_thresh: float, pt_thresh: float,
                     min_count: float, sum_cap: float, calib_iters: int):
    """Returns (mask (N,) f32 in {0,1}, var (N,) f32)."""
    trk = calibrate_tracks(tracks.astype(jnp.float32), calib_iters)
    pt = trk[..., 0]  # (N, T)
    t = jnp.arange(pt.shape[-1])
    valid = t[None, :] < n_tracks[:, None]
    cnt = jnp.sum(jnp.where(valid & (pt > pt_thresh), 1.0, 0.0), axis=-1)
    ssum = jnp.sum(jnp.where(valid, pt, 0.0), axis=-1)
    mask = (scalars[:, var_idx] > scalar_thresh) & (cnt >= min_count)
    if sum_cap > 0:
        mask = mask & (ssum < sum_cap)
    return mask.astype(jnp.float32), scalars[:, 0]


def event_filter_batch_ref(scalars, tracks, n_tracks, thresholds, *,
                           var_idx, calib_iters: int):
    """Batched oracle: thresholds (4, K) columns per query, var_idx a
    K-tuple.  Returns (mask (N, K) f32 in {0,1}, var (N,) f32) — one
    calibration + one track sweep shared by all K queries."""
    trk = calibrate_tracks(tracks.astype(jnp.float32), calib_iters)
    pt = trk[..., 0]  # (N, T)
    t = jnp.arange(pt.shape[-1])
    valid = t[None, :] < n_tracks[:, None]
    hit = valid[..., None] & (pt[..., None] > thresholds[1, :])  # (N, T, K)
    cnt = jnp.sum(jnp.where(hit, 1.0, 0.0), axis=1)              # (N, K)
    ssum = jnp.sum(jnp.where(valid, pt, 0.0), axis=-1)           # (N,)
    sc_sel = jnp.stack([scalars[:, i] for i in var_idx], axis=-1)
    mask = (sc_sel > thresholds[0, :]) & (cnt >= thresholds[2, :])
    mask = mask & jnp.where(thresholds[3, :] > 0,
                            ssum[:, None] < thresholds[3, :], True)
    return mask.astype(jnp.float32), scalars[:, 0]
