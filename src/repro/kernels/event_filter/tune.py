"""Block-shape autotuner for the fused ``event_filter`` kernel.

The kernel's ``(block_e, block_t)`` block shapes were a fixed
``(128, 512)`` — fine for the TPU tiling the BlockSpecs were written
against, wrong in general: the best shape depends on the chunk shape the
SPMD scan actually feeds (``chunk_events`` x tracks x vars), the query
width K, and whether the kernel runs compiled or interpreted.  This
module measures instead of guessing:

- :func:`autotune_block_shapes` sweeps :data:`CANDIDATES` on a sample
  chunk (deduplicating candidates that clamp to the same effective
  shape), times each with the jitted dispatch it will actually run
  under, and returns a :class:`TunedShape` carrying the winner, the
  fixed-default baseline, every measurement, and a roofline point
  (bytes moved / useful FLOPs / achieved GB/s and GFLOP/s at the
  winner's runtime).
- Winners are cached **in-process** by :func:`shape_key` (chunk shape x
  schema width x K x calib x interpret), so a scan pays the sweep once
  per shape class; ``BENCH_backend.json`` persists the roofline points
  via ``benchmarks/bench_backend.py --autotune``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

from repro.kernels import resolve_interpret

#: The sweep grid: small-event blocks for small streaming chunks, the
#: historical (128, 512) default, and wider track tiles for track-heavy
#: schemas.  Candidates clamp to the operand shape, so an oversized
#: entry is timed at most once (see the dedup in the sweep).
CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (32, 128), (64, 128), (64, 256), (128, 128), (128, 256),
    (128, 512), (256, 256), (256, 512))

#: The fixed pre-autotune default the tuned shape is benchmarked against.
DEFAULT_SHAPE: Tuple[int, int] = (128, 512)

#: In-process winner cache: ``shape_key -> TunedShape``.
_CACHE: Dict[tuple, "TunedShape"] = {}


@dataclasses.dataclass(frozen=True)
class TunedShape:
    """One autotune verdict: the winning block shape for a shape class,
    with the evidence (per-candidate timings) and the winner's roofline
    point (estimated bytes/FLOPs over measured runtime)."""
    block_e: int
    block_t: int
    best_ms: float
    default_ms: float
    #: ((block_e, block_t, ms), ...) for every effective candidate timed
    measurements: Tuple[Tuple[int, int, float], ...]
    #: bytes / flops estimates + achieved GB/s, GFLOP/s, FLOP/byte
    roofline: Dict[str, float]

    @property
    def speedup_vs_default(self) -> float:
        """default_ms / best_ms — >= 1.0 by construction (the default is
        itself a candidate, so the winner can never be slower)."""
        return self.default_ms / self.best_ms if self.best_ms > 0 else 1.0

    def as_dict(self) -> dict:
        """JSON-ready form for BENCH snapshot recording."""
        return {
            "block_e": self.block_e, "block_t": self.block_t,
            "best_ms": round(self.best_ms, 4),
            "default_ms": round(self.default_ms, 4),
            "speedup_vs_default": round(self.speedup_vs_default, 3),
            "measurements": [[be, bt, round(ms, 4)]
                             for be, bt, ms in self.measurements],
            "roofline": {k: (round(v, 4) if isinstance(v, float) else v)
                         for k, v in self.roofline.items()},
        }


def shape_key(n: int, t: int, v: int, s: int, k: int, calib_iters: int,
              interpret: Optional[bool]) -> tuple:
    """The in-process cache key: everything the winner depends on —
    chunk shape (n, t, v), scalar width s, query width k, calibration
    depth, and the *resolved* interpret mode."""
    return (n, t, v, s, k, calib_iters, resolve_interpret(interpret))


def roofline_point(n: int, t: int, v: int, s: int, k: int,
                   calib_iters: int, ms: float) -> Dict[str, float]:
    """Estimated traffic/compute for one kernel invocation, scaled by a
    measured runtime into achieved GB/s / GFLOP/s.  Traffic counts each
    operand once (the kernel's whole point is that tracks stream
    HBM->VMEM exactly once); FLOPs count the calibration polynomial
    (~10 flops/element/iter: tanh+rsqrt+mults) plus the per-query
    compare/accumulate epilogue."""
    bytes_moved = 4.0 * (n * t * v          # tracks, one streaming read
                         + n * s            # scalars
                         + n                # n_tracks
                         + n * k + n)       # mask + var outputs
    flops = (10.0 * calib_iters * n * t * v     # calibration sweep
             + n * t * (k + 2.0))               # hit test + cnt/sum accum
    sec = max(ms, 1e-9) / 1e3
    return {
        "bytes": bytes_moved, "flops": flops,
        "intensity_flop_per_byte": flops / bytes_moved,
        "gbytes_per_s": bytes_moved / sec / 1e9,
        "gflops_per_s": flops / sec / 1e9,
        "ms": ms,
    }


def _time_once(fn, repeats: int) -> float:
    """Best-of-``repeats`` wall milliseconds, after one untimed warmup
    call (compilation / trace caching)."""
    fn()  # warmup: compile + cache
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def autotune_block_shapes(scalars, tracks, n_tracks, thresholds, *,
                          var_idx: Tuple[int, ...], calib_iters: int,
                          interpret: Optional[bool] = None,
                          candidates: Sequence[Tuple[int, int]] = CANDIDATES,
                          repeats: int = 3,
                          cache: Optional[Dict[tuple, TunedShape]] = None
                          ) -> TunedShape:
    """Sweep ``candidates`` on the given sample chunk and return the
    winning :class:`TunedShape` (cached in-process by shape class).

    Candidates whose blocks clamp to the same effective ``(min(be, n),
    min(bt, t))`` are timed once — on small streaming chunks the sweep
    frequently collapses to a couple of distinct shapes, which is what
    keeps autotune affordable mid-scan.  The fixed ``(128, 512)``
    default is always included, so ``speedup_vs_default >= 1.0``."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.event_filter import ops as ef_ops

    n, s = scalars.shape
    _, t, v = tracks.shape
    k = thresholds.shape[1]
    key = shape_key(n, t, v, s, k, calib_iters, interpret)
    store = _CACHE if cache is None else cache
    hit = store.get(key)
    if hit is not None:
        return hit

    scalars = jnp.asarray(scalars)
    tracks = jnp.asarray(tracks)
    n_tracks = jnp.asarray(n_tracks)
    thresholds = jnp.asarray(thresholds)

    effective: Dict[Tuple[int, int], Tuple[int, int]] = {}
    for be, bt in tuple(candidates) + (DEFAULT_SHAPE,):
        effective.setdefault((min(be, n), min(bt, t)), (be, bt))

    def run(be, bt):
        mask, var = ef_ops.event_filter_batch(
            scalars, tracks, n_tracks, thresholds, var_idx=var_idx,
            calib_iters=calib_iters, interpret=interpret,
            block_e=be, block_t=bt)
        jax.block_until_ready((mask, var))

    timed = []
    for (ebe, ebt), (be, bt) in sorted(effective.items()):
        ms = _time_once(lambda: run(be, bt), repeats)
        timed.append((be, bt, ms))
    best_be, best_bt, best_ms = min(timed, key=lambda r: r[2])
    dbe, dbt = DEFAULT_SHAPE
    default_ms = next(ms for be, bt, ms in timed
                      if (min(be, n), min(bt, t))
                      == (min(dbe, n), min(dbt, t)))
    tuned = TunedShape(
        block_e=best_be, block_t=best_bt, best_ms=best_ms,
        default_ms=default_ms, measurements=tuple(timed),
        roofline=roofline_point(n, t, v, s, k, calib_iters, best_ms))
    store[key] = tuned
    return tuned


def cached_shapes() -> Dict[tuple, TunedShape]:
    """A snapshot of the in-process winner cache (bench reporting)."""
    return dict(_CACHE)


def clear_cache() -> None:
    """Drop every cached winner (tests / fresh bench sweeps)."""
    _CACHE.clear()
