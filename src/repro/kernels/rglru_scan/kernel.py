"""RG-LRU linear-recurrence scan, Pallas TPU kernel.

TPU adaptation: ``associative_scan`` materializes O(log S) intermediate
(B,S,W) tensors in HBM; a TPU core can instead stream S sequentially
through VMEM once, carrying h in a (block_b, block_w) VMEM scratch —
bandwidth-optimal (read a,b once, write y once) at the cost of sequential
time-steps, which the VPU pipelines fine since every step is elementwise.

Grid (nB, nW, nS): S-chunk axis innermost/sequential; the carry scratch
persists across S chunks for each (B, W) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, y_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[...]

    a = a_ref[...]  # (BB, BS, BW)
    b = b_ref[...]

    def step(t, h):
        h = a[:, t, :] * h + b[:, t, :]
        y_ref[:, t, :] = h
        return h

    h_scr[...] = jax.lax.fori_loop(0, a.shape[1], step, h_scr[...])


def rglru_scan_pallas(a, b, h0=None, *, block_b: int = 8,
                      block_s: int = 256, block_w: int = 512,
                      interpret: bool | None = None):
    """a, b: (B,S,W) f32; h0: (B,W) f32 or None.
    Returns (h (B,S,W), h_last (B,W))."""
    bsz, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    block_b = min(block_b, bsz)
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    grid = (pl.cdiv(bsz, block_b), pl.cdiv(w, block_w), pl.cdiv(s, block_s))

    kernel = functools.partial(_kernel, block_s=block_s)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_s, block_w),
                         lambda bb, wi, si: (bb, si, wi)),
            pl.BlockSpec((block_b, block_s, block_w),
                         lambda bb, wi, si: (bb, si, wi)),
            pl.BlockSpec((block_b, block_w), lambda bb, wi, si: (bb, wi)),
        ],
        out_specs=pl.BlockSpec((block_b, block_s, block_w),
                               lambda bb, wi, si: (bb, si, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_w), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32))
    return y, y[:, -1, :]
