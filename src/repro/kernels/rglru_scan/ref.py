"""Pure-jnp oracle for the RG-LRU scan kernel: first-order linear
recurrence h_t = a_t * h_{t-1} + b_t via associative scan (O(log S) depth
but O(log S) HBM passes — the thing the kernel improves on TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0=None):
    """a, b: (B, S, W) f32. Returns (h (B,S,W), h_last (B,W))."""
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]
