"""Jitted wrapper: full RG-LRU block scan with the gate math in XLA (MXU
matmuls) and the sequential recurrence in the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.models.rglru import rglru_gates


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(p: dict, x: jax.Array, h0=None, *, interpret: bool | None = None):
    """Drop-in replacement for models.rglru.rglru_scan (kernel-backed)."""
    a, bx = rglru_gates(p, x)
    y, h_last = rglru_scan_pallas(a, bx, h0, interpret=interpret)
    return y.astype(x.dtype), h_last
