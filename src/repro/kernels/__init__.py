# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel-package utilities: the ONE `interpret` auto-detect.

Every kernel package here (``event_filter``, ``flash_attention``,
``mlstm_scan``, ``rglru_scan``) wraps ``pl.pallas_call`` whose
``interpret`` flag decides between the compiled Mosaic/Triton lowering
(TPU/GPU) and the pure-Python interpreter (the only thing that runs the
kernel bodies on CPU).  Historically every wrapper defaulted to
``interpret=True`` — safe everywhere, but it silently left compiled
execution on the table on real accelerators.  The unified story:

- ``interpret=None`` (every wrapper's new default) means **auto**:
  compiled on TPU/GPU, interpret only as the CPU fallback.
- :func:`default_interpret` is the single auto-detect; the
  ``REPRO_INTERPRET`` environment variable (``auto`` / ``1`` /
  ``interpret`` / ``0`` / ``compiled``) overrides it, which is what the
  CI ``kernel-matrix`` job uses to force both modes on one host.
- :func:`resolve_interpret` maps a wrapper's ``bool | None`` flag to the
  concrete bool handed to ``pl.pallas_call``.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

#: Environment override consumed by :func:`default_interpret`.
INTERPRET_ENV = "REPRO_INTERPRET"

#: jax backends with a real Pallas lowering (everything else interprets).
COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


@functools.lru_cache(maxsize=None)
def _backend_interprets() -> bool:
    """True when the active jax backend has no compiled Pallas lowering
    (CPU — the interpreter is the fallback).  Cached: the backend is
    pinned at first jax init and never changes within a process."""
    import jax
    return jax.default_backend() not in COMPILED_BACKENDS


def default_interpret() -> bool:
    """The auto-detected ``interpret`` flag: False (compiled) on TPU/GPU,
    True (interpreter) on CPU.  ``REPRO_INTERPRET`` forces a mode —
    ``1``/``interpret``/``true`` or ``0``/``compiled``/``false`` — while
    ``auto``/unset keeps the backend probe (the CI kernel-matrix knob)."""
    forced = os.environ.get(INTERPRET_ENV, "auto").strip().lower()
    if forced in ("1", "interpret", "true", "yes"):
        return True
    if forced in ("0", "compiled", "false", "no"):
        return False
    if forced not in ("auto", ""):
        raise ValueError(
            f"unrecognized {INTERPRET_ENV}={forced!r}: use 'interpret', "
            "'compiled', or 'auto'")
    return _backend_interprets()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Map a kernel wrapper's ``interpret: bool | None`` to the concrete
    bool for ``pl.pallas_call``: ``None`` means :func:`default_interpret`
    (auto), an explicit bool is honoured verbatim."""
    return default_interpret() if interpret is None else bool(interpret)
