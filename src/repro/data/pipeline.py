"""Brick-resident training data pipeline.

GEPS rule: "data should not be moved when applying for a job submission" —
each host feeds the SPMD batch exclusively from bricks it owns.  The
packet scheduler (core/packets.py) decides which brick range each host
reads next, so slow hosts automatically contribute from smaller ranges and
a dead host's pending ranges fail over to replica owners (PROOF rule).

Token bricks are synthetic deterministic streams (seeded per brick) so any
replica produces byte-identical data — the property that makes failover
exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.catalog import MetadataCatalog
from repro.core.packets import AdaptivePacketScheduler
from repro.core.replication import failover_owner, place_replicas


@dataclasses.dataclass
class TokenBrickSpec:
    brick_id: int
    node: int
    replicas: tuple
    n_sequences: int


class TokenBrickStore:
    """Deterministic synthetic token shards ("bricks") per node."""

    def __init__(self, *, vocab_size: int, seq_len: int, n_bricks: int,
                 seqs_per_brick: int, n_nodes: int, replication: int = 2,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        self.specs: Dict[int, TokenBrickSpec] = {}
        for bid in range(n_bricks):
            node = bid % n_nodes
            self.specs[bid] = TokenBrickSpec(
                bid, node, place_replicas(bid, node, n_nodes, replication),
                seqs_per_brick)
        self.n_nodes = n_nodes

    def read(self, brick_id: int, start: int, count: int) -> np.ndarray:
        """(count, seq_len) int32 — identical from any replica (seeded)."""
        spec = self.specs[brick_id]
        assert 0 <= start and start + count <= spec.n_sequences
        rng = np.random.default_rng(
            (self.seed, brick_id, start, count))
        # deterministic per-row: regenerate row-by-row seeds for exactness
        rows = []
        for r in range(start, start + count):
            rrng = np.random.default_rng((self.seed, brick_id, r))
            rows.append(rrng.integers(0, self.vocab_size,
                                      size=self.seq_len, dtype=np.int32))
        return np.stack(rows)

    def owners(self, brick_id: int) -> List[int]:
        spec = self.specs[brick_id]
        return [spec.node, *spec.replicas]


class BrickDataPipeline:
    """Yields fixed-size global batches assembled brick-locally.

    Each global batch of B sequences is split into per-host quotas; hosts
    fill their quota from packets over their OWN bricks.  On failure the
    scheduler re-leases the dead host's packets to replica owners, so the
    global batch content is unchanged (deterministic bricks) — training is
    bitwise reproducible across failures."""

    def __init__(self, store: TokenBrickStore, catalog: MetadataCatalog,
                 *, global_batch: int, mesh=None):
        self.store = store
        self.catalog = catalog
        self.global_batch = global_batch
        self.mesh = mesh
        self.sched = AdaptivePacketScheduler(
            catalog, base_packet=max(1, global_batch // max(
                1, len(catalog.alive_nodes()))),
            min_packet=1, max_packet=global_batch)
        self._work: List[tuple] = []  # (brick_id, cursor)
        for bid in sorted(store.specs):
            self._work.append([bid, 0])
        self._wi = 0

    def _refill(self, needed: int):
        added = 0
        while added < needed and self._work:
            bid, cursor = self._work[self._wi % len(self._work)]
            spec = self.store.specs[bid]
            room = spec.n_sequences - cursor
            take = min(room, needed - added)
            if take > 0:
                self.sched.add_work(bid, take)
                self._work[self._wi % len(self._work)][1] += take
                added += take
            if self._work[self._wi % len(self._work)][1] >= spec.n_sequences:
                # brick exhausted this epoch: reset cursor (infinite stream)
                self._work[self._wi % len(self._work)][1] = 0
            self._wi += 1
        return added

    def next_batch(self) -> np.ndarray:
        """(global_batch, seq_len) int32 assembled via packet leases."""
        self._refill(self.global_batch)
        rows = []
        alive = self.catalog.alive_nodes()
        if not alive:
            raise RuntimeError("no alive nodes to feed the batch")
        ni = 0
        while len(rows) < self.global_batch:
            node = alive[ni % len(alive)]
            ni += 1
            pkt = self.sched.next_packet(node)
            if pkt is None:
                if self.sched.exhausted:
                    self._refill(self.global_batch - len(rows))
                continue
            owners = self.store.owners(pkt.brick_id)
            dead = self.catalog.dead_nodes()
            owner = failover_owner(owners, dead)
            if owner < 0:
                raise RuntimeError(f"brick {pkt.brick_id} lost")
            data = self.store.read(pkt.brick_id, pkt.start, pkt.size)
            self.sched.complete(pkt.packet_id, pkt.size, 1e-3 * pkt.size)
            rows.append(data)
        batch = np.concatenate(rows, axis=0)[:self.global_batch]
        return batch

    def next_device_batch(self) -> dict:
        tokens = jnp.asarray(self.next_batch())
        if self.mesh is not None:
            axes = tuple(a for a in ("pod", "data")
                         if a in self.mesh.axis_names)
            sh = NamedSharding(self.mesh, P(axes, None))
            tokens = jax.device_put(tokens, sh)
        return {"tokens": tokens, "labels": tokens}
