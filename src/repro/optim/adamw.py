"""AdamW in pure JAX, sharded the same way as the parameters.

Moments are f32 (params stay in cfg.param_dtype, bf16 on target).  The
optimizer state pytree mirrors the parameter pytree so the ParamTable's
sharding specs apply leaf-for-leaf — guaranteeing the update is fully local
(no optimizer collectives beyond the gradient merge itself, GEPS-style).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def init_opt_state(params, opt: AdamW):
    dt = jnp.dtype(opt.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params_abstract, opt: AdamW, sharder=None):
    """ShapeDtypeStruct mirror for dry-run lowering (keeps input shardings)."""
    dt = jnp.dtype(opt.moment_dtype)

    def mirror(p):
        sh = getattr(p, "sharding", None)
        return jax.ShapeDtypeStruct(p.shape, dt, sharding=sh)

    return {
        "m": jax.tree.map(mirror, params_abstract),
        "v": jax.tree.map(mirror, params_abstract),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_specs(param_specs):
    """PartitionSpec tree for the optimizer state given the param spec tree."""
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(params, grads, state, lr, opt: AdamW):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, opt.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = opt.b1, opt.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        step = mhat / (jnp.sqrt(vhat) + opt.eps)
        step = step + opt.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
