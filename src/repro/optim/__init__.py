from repro.optim.adamw import AdamW, init_opt_state, opt_specs  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
