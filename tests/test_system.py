"""End-to-end behaviour tests for the paper's system: submit -> broker ->
per-brick dispatch -> merge -> retrieve, plus the SPMD twin, in one flow
(the GEPS portal scenario of paper section 5)."""
import jax

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core.brick import create_store, gather_store, shard_to_mesh
from repro.core.catalog import DONE, MetadataCatalog
from repro.core.jse import JobSubmissionEngine, spmd_query_step
from repro.launch.mesh import make_mesh_of


def test_geps_portal_flow_end_to_end():
    schema = ev.EventSchema.from_config(reduced())
    store = create_store(schema, n_events=256, n_nodes=4,
                         events_per_brick=32, replication=2, seed=9)
    catalog = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(catalog, store)

    # user submits through the portal (Fig 4)
    expr = "e_total > 40 && count(pt > 15) >= 1"
    jid = jse.submit(expr, calib_iters=2)
    assert catalog.jobs[jid].status == "PENDING"

    # the broker polls the catalogue and runs the job (section 4.2)
    assert jse.broker_poll() == jid
    rec = catalog.jobs[jid]
    assert rec.status == DONE
    assert rec.events_processed == 256
    assert rec.result["n_selected"] > 0

    # job status retrieval (Fig 6) and node info (Fig 5 / GRIS)
    info = catalog.grid_info(0)
    assert info["alive"] and info["throughput_ema"] > 0

    # the SPMD realization gives the same physics answer
    mesh = make_mesh_of((1, 1), ("data", "model"))
    sharded = shard_to_mesh(gather_store(store), mesh)
    out = jax.jit(spmd_query_step(expr, schema, calib_iters=2))(sharded)
    assert int(out["n_selected"]) == rec.result["n_selected"]

    # catalogue survives a JSE restart (control-plane checkpointing)
    catalog2 = MetadataCatalog.from_json(catalog.to_json())
    assert catalog2.jobs[jid].status == DONE
