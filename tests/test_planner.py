"""Shared-aggregate query planner: fragment-factoring equivalence (incl.
property-based over random ASTs and node-failure scripts), cost-budget
admission, fragment-level cache entries, adaptive-window convergence."""
import jax
import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.catalog import DONE, MetadataCatalog
from repro.core.jse import JobSubmissionEngine
from repro.service import (AdmissionError, QueryScheduler, QueryService,
                           WindowController, estimate_cost, make_submission,
                           plan_window, shared_boolean_fragments)

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)


def make_store(n_events=192, n_nodes=4, replication=2, seed=7):
    from repro.core.brick import create_store
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=seed)


def near_duplicates(k):
    shared = ["count(pt > 15) >= 2", "sum(pt) < 350", "count(pt > 25) >= 1"]
    return [f"e_total > {20 + i} && {shared[i % len(shared)]}"
            for i in range(k)]


def assert_results_identical(got, want):
    assert merge_lib.results_identical(got, want)


# ------------------- fragment factoring --------------------------------- #
def test_plan_factors_common_subexpressions():
    plan = query_lib.build_fragment_plan(near_duplicates(64))
    # >= 2x fewer evaluations than per-query compilation (acceptance bar)
    assert plan.unique_fragments * 2 <= plan.unshared_evals
    # identical canonical subtrees are the same interned object
    roots = plan.roots
    assert roots[0].rhs is roots[3].rhs  # shared "count(pt > 15) >= 2"


def test_plan_eval_matches_per_query_compile():
    batch = ev.synthetic_events(jax.random.key(0), SCHEMA, 96)
    exprs = near_duplicates(12)
    plan = query_lib.build_fragment_plan(exprs)
    outs = plan.evaluate(batch, SCHEMA)
    for e, out in zip(exprs, outs):
        ref = query_lib.compile_query(e, SCHEMA)(batch)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_compile_query_batch_is_fragment_factored():
    batch = ev.synthetic_events(jax.random.key(1), SCHEMA, 64)
    exprs = near_duplicates(6)
    stacked = query_lib.compile_query_batch(exprs, SCHEMA)(batch)
    assert stacked.shape == (6, 64)
    for i, e in enumerate(exprs):
        ref = query_lib.compile_query(e, SCHEMA)(batch)
        np.testing.assert_array_equal(np.asarray(stacked[i]),
                                      np.asarray(ref))


def test_shared_boolean_fragments_found():
    exprs = ["e_total > 40 && count(pt > 15) >= 2",
             "e_t_miss > 25 && count(pt > 15) >= 2",
             "pt_lead > 60"]
    plan = query_lib.build_fragment_plan(exprs)
    keys = [query_lib.node_key(n) for n in shared_boolean_fragments(plan)]
    assert query_lib.canonical_expr("count(pt > 15) >= 2") in keys
    # whole-query roots are excluded (cached under their own key already)
    assert query_lib.canonical_expr(exprs[0]) not in keys


@pytest.mark.parametrize("failure_script", [None, {0.5: 1}])
def test_planned_batch_bit_identical_to_singles(failure_script):
    """Factored + materialized execution vs. independent jobs, including
    under a node-failure script (the acceptance bit-identity bar)."""
    store = make_store(n_events=256)
    exprs = near_duplicates(6)

    singles = []
    for e in exprs:
        cat = MetadataCatalog(store.n_nodes)
        jse = JobSubmissionEngine(cat, store)
        merged, _ = jse.run_job_simulated(
            jse.submit(e), failure_script=failure_script)
        singles.append(merged)

    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jids = [jse.submit(e) for e in exprs]
    plan = plan_window(exprs)
    assert plan.materialize  # the shared conjuncts are materialized
    batch, stats = jse.run_job_batch_simulated(
        jids, failure_script=failure_script, plan=plan)

    assert len(batch) == len(exprs)  # materialized extras not in results
    for got, want in zip(batch, singles):
        assert_results_identical(got, want)
    assert stats.fragment_evals < stats.fragment_evals_unshared
    assert set(stats.fragment_results) == set(plan.materialize_keys())


def test_materialized_fragment_matches_standalone_query():
    """A materialized shared fragment's merged result equals running that
    fragment as its own query."""
    store = make_store(n_events=256)
    exprs = ["e_total > 40 && count(pt > 15) >= 2",
             "e_t_miss > 25 && count(pt > 15) >= 2"]
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jids = [jse.submit(e) for e in exprs]
    _, stats = jse.run_job_batch_simulated(jids, plan=plan_window(exprs))
    frag_key = query_lib.canonical_expr("count(pt > 15) >= 2")
    assert frag_key in stats.fragment_results

    cat2 = MetadataCatalog(store.n_nodes)
    jse2 = JobSubmissionEngine(cat2, store)
    want, _ = jse2.run_job_simulated(jse2.submit("count(pt > 15) >= 2"))
    assert_results_identical(stats.fragment_results[frag_key], want)


# ------------------- property-based equivalence ------------------------- #
def _hypothesis_strategies():
    st = pytest.importorskip("hypothesis").strategies
    num = st.builds(query_lib.Num,
                    st.floats(min_value=0.0, max_value=100.0,
                              allow_nan=False).map(lambda x: round(x, 2)))
    scalar_var = st.builds(query_lib.Var, st.sampled_from(
        ("e_total", "e_t_miss", "pt_lead", "n_tracks")))
    track_var = st.builds(query_lib.Var, st.sampled_from(
        ("pt", "eta", "phi", "e_total")))
    ops = st.sampled_from(("+", "-", "*", "/", "<", "<=", ">", ">=",
                           "==", "!=", "&&", "||"))
    unary_ops = st.sampled_from(("-", "!"))

    def grow(children):
        return (st.builds(query_lib.Bin, ops, children, children)
                | st.builds(query_lib.Unary, unary_ops, children))

    track = st.recursive(num | track_var, grow, max_leaves=6)
    agg = st.builds(query_lib.Agg,
                    st.sampled_from(query_lib.AGGS), track)
    scalar = st.recursive(num | scalar_var | agg, grow, max_leaves=10)
    return st, scalar


def test_property_plan_eval_bit_identical_random_asts():
    hypothesis = pytest.importorskip("hypothesis")
    st, scalar = _hypothesis_strategies()
    batch = ev.synthetic_events(jax.random.key(3), SCHEMA, 48)

    @hypothesis.settings(max_examples=30, deadline=None)
    @hypothesis.given(st.lists(scalar, min_size=2, max_size=5))
    def check(asts):
        exprs = [query_lib.unparse(a) for a in asts]
        plan = query_lib.build_fragment_plan(exprs)
        outs = plan.evaluate(batch, SCHEMA)
        for e, out in zip(exprs, outs):
            ref = query_lib.compile_query(e, SCHEMA)(batch)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    check()


# ------------------- cost model + budgeted admission -------------------- #
def test_estimate_cost_scales_with_work():
    cheap = estimate_cost("e_total > 40", n_events=1000)
    agg = estimate_cost("count(pt > 15) >= 2", n_events=1000)
    calib = estimate_cost("count(pt > 15) >= 2", n_events=1000,
                          calib_iters=4)
    more_events = estimate_cost("e_total > 40", n_events=4000)
    assert cheap < agg < calib
    assert more_events == 4 * cheap


def test_cost_budget_admission_rejects_over_budget_tenant():
    sched = QueryScheduler(cost_budget_per_tenant=5000.0)
    # one aggregate over 1000 events: 1000 * (1 + 4) = 5000 -> at budget
    sched.enqueue(make_submission(0, "a", "count(pt > 15) >= 2", 0, SCHEMA,
                                  n_events=1000))
    assert sched.pending_cost_for("a") == 5000.0
    with pytest.raises(AdmissionError, match="cost budget"):
        sched.enqueue(make_submission(1, "a", "e_total > 1", 0, SCHEMA,
                                      n_events=1000))
    # another tenant has its own budget
    sched.enqueue(make_submission(2, "b", "e_total > 1", 0, SCHEMA,
                                  n_events=1000))
    # dispatching releases the cost -> tenant a admits again
    assert len(sched.next_batch()) == 2
    assert sched.pending_cost == 0.0
    sched.enqueue(make_submission(3, "a", "e_total > 2", 0, SCHEMA,
                                  n_events=1000))


def test_global_cost_budget():
    sched = QueryScheduler(cost_budget_total=2500.0)
    sched.enqueue(make_submission(0, "a", "e_total > 1", 0, SCHEMA,
                                  n_events=1000))
    sched.enqueue(make_submission(1, "b", "e_total > 2", 0, SCHEMA,
                                  n_events=1000))
    with pytest.raises(AdmissionError, match="cost budget"):
        sched.enqueue(make_submission(2, "c", "e_total > 3", 0, SCHEMA,
                                      n_events=1000))


def test_service_cost_budget_rejects_with_reason():
    store = make_store()
    sched = QueryScheduler(
        cost_budget_per_tenant=float(store.n_events))  # one scalar query
    svc = QueryService(store, scheduler=sched, use_cache=False)
    t1 = svc.submit("e_total > 40", tenant="a")
    t2 = svc.submit("e_total > 50", tenant="a")  # over budget
    t3 = svc.submit("e_total > 60", tenant="b")  # other tenant fine
    assert svc.result(t1).status == "QUEUED"
    assert svc.result(t2).status == "REJECTED"
    assert "cost budget" in svc.result(t2).note
    assert svc.result(t3).status == "QUEUED"
    svc.drain()
    assert svc.result(t1).status == "SERVED"


# ------------------- fragment-level cache entries ----------------------- #
def test_fragment_cache_serves_future_subexpression_query():
    store = make_store(n_events=256)
    svc = QueryService(store)
    t0 = svc.submit("e_total > 40 && count(pt > 15) >= 2", tenant="a")
    t1 = svc.submit("e_t_miss > 25 && count(pt > 15) >= 2", tenant="b")
    svc.drain()
    assert svc.result(t0).status == "SERVED"
    assert svc.result(t1).status == "SERVED"
    assert svc.cache.stats.fragment_puts >= 1
    scanned = svc.stats.events_scanned

    # the shared conjunct arrives later as its own query -> zero brick I/O
    t2 = svc.submit("count(pt > 15) >= 2", tenant="c")
    tk = svc.result(t2)
    assert tk.status == "SERVED" and tk.from_cache
    assert svc.stats.events_scanned == scanned

    # and the cached fragment equals an independent execution
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    want, _ = jse.run_job_simulated(jse.submit("count(pt > 15) >= 2"))
    assert_results_identical(tk.result, want)


def test_failed_batch_caches_no_fragments():
    store = make_store(n_events=256)
    svc = QueryService(store)
    svc.submit("e_total > 40 && count(pt > 15) >= 2", tenant="a")
    svc.submit("e_t_miss > 25 && count(pt > 15) >= 2", tenant="b")
    svc.step(failure_script={0.01: 0, 0.02: 1, 0.03: 2, 0.04: 3})
    assert svc.cache.stats.fragment_puts == 0
    assert len(svc.cache) == 0


# ------------------- adaptive dispatch windows -------------------------- #
def test_window_controller_converges_to_rate_times_latency():
    wc = WindowController(initial=4, max_window=512, alpha=0.4)
    assert wc.window() == 4  # no telemetry yet -> initial
    t = 0.0
    for _ in range(60):
        wc.observe_arrival(t)
        t += 0.01  # 100 arrivals/s
    for _ in range(10):
        wc.observe_scan(0.5)  # scans take 0.5s
    # sweet spot: lambda * L = 100 * 0.5 = 50 arrivals per scan
    assert 45 <= wc.window() <= 55


def test_window_controller_tracks_bursts_and_recovers():
    wc = WindowController(initial=8, max_window=1024, alpha=0.4)
    t = 0.0
    for _ in range(50):
        wc.observe_arrival(t)
        t += 0.05  # calm: 20/s
    for _ in range(6):
        wc.observe_scan(1.0)
    calm = wc.window()
    assert 15 <= calm <= 25
    for _ in range(80):
        wc.observe_arrival(t)
        t += 0.002  # burst: 500/s
    burst = wc.window()
    assert burst > 4 * calm  # widens to absorb the burst
    for _ in range(120):
        wc.observe_arrival(t)
        t += 0.05  # calm again
    recovered = wc.window()
    assert 15 <= recovered <= 30  # converges back near lambda*L


def test_window_controller_clamps():
    wc = WindowController(initial=8, min_window=2, max_window=16, alpha=0.5)
    t = 0.0
    for _ in range(20):
        wc.observe_arrival(t)
        t += 1e-4  # 10k/s
    wc.observe_scan(10.0)
    assert wc.window() == 16
    wc2 = WindowController(min_window=2, max_window=16, alpha=0.5)
    t = 0.0
    for _ in range(20):
        wc2.observe_arrival(t)
        t += 100.0  # glacial arrivals
    wc2.observe_scan(1e-3)
    assert wc2.window() == 2


def test_service_adaptive_windows_end_to_end():
    """Bursty arrivals through the full service: the controller retunes
    scheduler.max_batch between windows and everything still serves."""
    store = make_store(n_events=192)
    vnow = [0.0]
    wc = WindowController(initial=4, max_window=64, alpha=0.5)
    svc = QueryService(store, window_controller=wc, clock=lambda: vnow[0],
                       use_cache=False)
    served = []
    for i in range(24):
        svc.submit(f"e_total > {30 + i}", tenant=f"t{i % 3}")
        vnow[0] += 0.02 if i < 12 else 0.2  # burst then calm
        if (i + 1) % 8 == 0:
            served.extend(svc.step())
    served.extend(svc.drain())
    assert len(served) == 24
    assert len(svc.window_history) == svc.stats.batches
    # the controller actually changed the window away from its seed
    assert len(set(svc.window_history)) > 1
