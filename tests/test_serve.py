"""Serving loop: prefill-into-cache + greedy decode produce stable,
deterministic generations for a decoder-only arch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import reduced_config
from repro.launch.mesh import make_mesh_of
from repro.launch.serve import generate
from repro.models import model_zoo
from repro.parallel.sharding import Sharder


def test_generate_deterministic_and_in_vocab():
    cfg = reduced_config("qwen3-14b")
    mesh = make_mesh_of((1, 1), ("data", "model"))
    shd = Sharder(cfg, mesh)
    model = model_zoo.build_model(cfg)
    params = model.table.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(2), (2, 6), 0,
                                cfg.vocab_size, jnp.int32)
    out1 = generate(cfg, model, params, shd, prompt, max_new_tokens=5,
                    cache_len=64)
    out2 = generate(cfg, model, params, shd, prompt, max_new_tokens=5,
                    cache_len=64)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab_size  # padded vocab never sampled
