"""Coherence fabric acceptance tests: deterministic bus, epoch gossip
bound (incl. partition heal), shared-L2 zero-I/O hits (whole-query and
fragment), cross-frontend stream fan-out bit-identity + never-final-
partial, registry-seeded planning equivalence + pre-warming, cost-model
calibration, stream-aware packet ramp, and hook-lifecycle hygiene."""
import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store, gather_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine, PacketTelemetry
from repro.fabric import (Fleet, FragmentRegistry, MessageBus,
                          SharedCacheTier, TieredResultCache, rounds_bound)
from repro.service import QueryService, fit_cost_weights, plan_window

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)


def make_store(n_events=192, n_nodes=4, replication=2, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=seed)


def make_fleet(store, n=4, **kw):
    kw.setdefault("registry", FragmentRegistry())
    return Fleet(store, n, **kw)


def snapshots_identical(a, b):
    return (a.seq == b.seq and a.final == b.final
            and a.t_virtual == b.t_virtual and a.coverage == b.coverage
            and merge_lib.results_identical(a.result, b.result))


# --------------------------- message bus ------------------------------- #
def test_bus_round_delivery_order_and_delay():
    bus = MessageBus(delay=1)
    bus.register("a"), bus.register("b")
    bus.send("a", "b", "t", 1)
    bus.send("a", "b", "t", 2)
    bus.tick()  # delay=1: not yet deliverable
    assert bus.recv("b") == []
    bus.tick()
    got = [e.payload for e in bus.recv("b")]
    assert got == [1, 2]  # global send order preserved
    assert bus.idle


def test_bus_partition_blocks_and_heals():
    bus = MessageBus()
    for n in ("a", "b"):
        bus.register(n)
    bus.partition(["a"], ["b"])
    assert not bus.send("a", "b", "t", "lost")
    assert bus.stats.partitioned == 1
    bus.heal()
    assert bus.send("a", "b", "t", "ok")
    bus.tick()
    assert [e.payload for e in bus.recv("b")] == ["ok"]


def test_bus_deterministic_drops():
    def run():
        bus = MessageBus(drop_rate=0.5, seed=42)
        bus.register("a"), bus.register("b")
        outcomes = [bus.send("a", "b", "t", i) for i in range(20)]
        return outcomes
    first, second = run(), run()
    assert first == second            # seeded loss replays identically
    assert not all(first) and any(first)


# --------------------------- epoch gossip (acceptance a) ---------------- #
def test_epoch_bump_invalidates_all_peers_within_bound():
    store = make_store()
    fleet = make_fleet(store, 4, gossip_fanout=1)
    assert fleet.rounds_bound == rounds_bound(4, 1) == 3
    # one scan on fe0 seeds L2; every other front-end then holds an L1
    # entry promoted from the shared tier
    for i in range(4):
        fleet.submit("e_total > 40", tenant=f"t{i}", frontend=i)
        fleet.step(i)
    assert all(len(fe.service.cache) == 1 for fe in fleet.frontends)

    fleet.bump_dataset_version(2)  # observed by ONE member only
    assert len(fleet.frontends[2].service.cache) == 0  # local: immediate
    fleet.pump(fleet.rounds_bound)
    # within the documented bound every peer converged and purged
    assert [fe.catalog.dataset_epoch for fe in fleet.frontends] == [1] * 4
    assert all(len(fe.service.cache) == 0 for fe in fleet.frontends)
    assert len(fleet.l2) == 0
    # a stale entry can never be served now: resubmit rescans
    t = fleet.submit("e_total > 40", tenant="x", frontend=1)
    fleet.drain()
    assert not fleet.result(t).from_cache


def test_partition_heal_reconciles_divergent_bumps():
    store = make_store()
    fleet = make_fleet(store, 4)
    for i in range(4):
        fleet.submit("e_total > 40", tenant=f"t{i}", frontend=i)
        fleet.step(i)
    fleet.bus.partition(["fe0", "fe1"], ["fe2", "fe3"])
    # divergent bumps on both sides of the split
    fleet.bump_dataset_version(0)
    fleet.bump_dataset_version(2)
    fleet.pump(fleet.rounds_bound)
    # each side converged to ITS epoch view (sum of known bumps = 1)
    assert [fe.catalog.dataset_epoch for fe in fleet.frontends] == [1] * 4
    # caches were purged everywhere; entries cached during the split are
    # keyed to partition-era epochs
    a = fleet.submit("e_total > 40", tenant="a", frontend=0)
    b = fleet.submit("e_total > 40", tenant="b", frontend=2)
    fleet.drain()
    assert not fleet.result(a).from_cache and not fleet.result(b).from_cache

    fleet.bus.heal()
    fleet.pump(fleet.rounds_bound)
    # version vectors merged: effective epoch = both bumps = 2 everywhere,
    # so EVERYTHING cached during the partition is stale on every member
    assert [fe.catalog.dataset_epoch for fe in fleet.frontends] == [2] * 4
    assert all(len(fe.service.cache) == 0 for fe in fleet.frontends)
    assert len(fleet.l2) == 0


# --------------------------- shared L2 (acceptance b) ------------------- #
def test_whole_query_answered_on_a_is_l2_hit_on_b():
    store = make_store()
    fleet = make_fleet(store, 2)
    a = fleet.submit("e_total > 40", tenant="a", frontend=0)
    fleet.drain()
    assert fleet.result(a).status == "SERVED"
    svc_b = fleet.frontends[1].service
    assert svc_b.stats.events_scanned == 0
    b = fleet.submit(" e_total>40.0 ", tenant="b", frontend=1)  # near-dup
    tk = fleet.result(b)
    assert tk.status == "SERVED" and tk.from_cache
    # zero brick I/O on B, asserted via the JobStats aggregation
    assert svc_b.stats.events_scanned == 0
    assert svc_b.cache.stats.l2_hits == 1
    assert merge_lib.results_identical(tk.result, fleet.result(a).result)


def test_fragment_byproduct_on_a_is_l2_hit_on_b():
    store = make_store()
    fleet = make_fleet(store, 2)
    # two queries sharing a conjunct -> the planner materializes it as a
    # scan by-product on fe0
    fleet.submit("e_total > 30 && count(pt > 15) >= 2", tenant="a",
                 frontend=0)
    fleet.submit("e_t_miss > 20 && count(pt > 15) >= 2", tenant="b",
                 frontend=0)
    fleet.drain()
    assert fleet.l2.stats.fragment_puts >= 1
    svc_b = fleet.frontends[1].service
    f = fleet.submit("count(pt > 15) >= 2", tenant="c", frontend=1)
    tk = fleet.result(f)
    assert tk.status == "SERVED" and tk.from_cache
    assert svc_b.stats.events_scanned == 0  # zero brick I/O via JobStats
    # the fragment answer equals an actual scan of that expression
    batch = gather_store(store)
    t = np.arange(batch["tracks"].shape[1])
    valid = t[None, :] < batch["n_tracks"][:, None]
    cnt = ((batch["tracks"][..., 0] > 15) & valid).sum(axis=1)
    assert tk.result.n_selected == int((cnt >= 2).sum())


def test_concurrent_independent_bumps_never_alias_in_l2():
    # fe0 bumps and scans; fe1 independently bumps for a DIFFERENT data
    # change before gossip converges.  Both sides sit at effective epoch
    # 1, but the epochs denote different dataset states — fe1 must NOT
    # get fe0's pre-(fe1-bump) result from the shared tier.
    store = make_store()
    fleet = make_fleet(store, 2)
    fleet.bump_dataset_version(0)
    a = fleet.submit("e_total > 40", tenant="a", frontend=0)
    fleet.step(0, pump_rounds=0)  # no gossip: fe1 has not heard fe0's bump
    assert fleet.result(a).status == "SERVED"
    fleet.frontends[1].catalog.bump_dataset_version()  # fe1's own change
    assert fleet.frontends[1].catalog.dataset_epoch == 1  # same scalar!
    b = fleet.submit("e_total > 40", tenant="b", frontend=1)
    tk = fleet.result(b)
    assert not tk.from_cache  # vector keyspace keeps the states apart
    assert fleet.l2.stats.stale_refused >= 1
    # once gossip reconciles (vector {fe0:1, fe1:1}, epoch 2), the tier
    # serves normally again
    fleet.pump(fleet.rounds_bound)
    fleet.drain()
    c = fleet.submit("e_total > 40", tenant="c", frontend=0)
    fleet.drain()
    assert fleet.result(c).status == "SERVED"
    d = fleet.submit("e_total > 40", tenant="d", frontend=1)
    assert fleet.result(d).from_cache


def test_l2_refuses_stale_epochs():
    l2 = SharedCacheTier()
    r = merge_lib.QueryResult(n_selected=1)
    l2.put("(a > 1.0)", 0, 0, r)
    assert l2.get("(a > 1.0)", 0, 0) is not None
    l2.observe_epoch(1)  # any member mentions a newer epoch
    assert len(l2) == 0
    l2.put("(a > 1.0)", 0, 0, r)  # late writer from a stale front-end
    assert len(l2) == 0 and l2.stats.stale_refused >= 1
    assert l2.get("(a > 1.0)", 0, 0) is None


# ----------------------- stream fan-out (acceptance c) ------------------ #
def test_cross_frontend_stream_bit_identical_to_local():
    store = make_store(n_events=256)
    fleet = Fleet(store, 2, service_kwargs={"use_cache": False,
                                            "stream_capacity": 512})
    g = fleet.submit("e_total > 40", tenant="a", frontend=0, stream=True)
    local, remote = [], []
    fleet.stream(g).subscribe(local.append)
    proxy = fleet.stream(g, frontend=1)
    proxy.subscribe(remote.append)
    fleet.pump()       # deliver the subscription to the owner
    fleet.step(0)      # scan runs on fe0; snapshots forward over the bus
    fleet.drain()
    assert proxy.done and len(remote) == len(local) > 1
    for a, b in zip(local, remote):
        assert snapshots_identical(a, b)
    # a partial is never surfaced as final
    assert [s.final for s in remote].count(True) == 1
    assert remote[-1].final
    assert merge_lib.results_identical(remote[-1].result,
                                       fleet.result(g).result)


def test_cross_frontend_stream_late_attach_sees_buffered_prefix():
    store = make_store(n_events=256)
    fleet = Fleet(store, 2, service_kwargs={"use_cache": False,
                                            "stream_capacity": 512})
    g = fleet.submit("e_total > 40", tenant="a", frontend=0, stream=True)
    fleet.step(0)  # scan completes BEFORE anyone attaches remotely
    local_buffered = fleet.stream(g).buffered()
    proxy = fleet.stream(g, frontend=1)
    fleet.drain()
    # remote late reader drains exactly what a local late reader would
    got = list(proxy)
    assert len(got) == len(local_buffered)
    for a, b in zip(local_buffered, got):
        assert snapshots_identical(a, b)
    assert proxy.done


def test_cross_frontend_stream_abort_never_final():
    store = make_store(n_events=256)
    fleet = Fleet(store, 2, service_kwargs={"use_cache": False})
    g = fleet.submit("e_total > 40", tenant="a", frontend=0, stream=True)
    proxy = fleet.stream(g, frontend=1)
    fleet.pump()
    fleet.step(0, failure_script={0.01: 0, 0.02: 1, 0.03: 2, 0.04: 3})
    fleet.drain()
    assert proxy.state == "ABORTED" and not proxy.done
    assert "aborted" in proxy.note
    assert all(not s.final for s in proxy.buffered())


# ----------------------- registry (acceptance d) ------------------------ #
def test_registry_seeded_plans_bit_identical_to_unseeded():
    store = make_store(n_events=256)
    reg = FragmentRegistry(hot_min_windows=1)
    warm = ["e_total > 30 && count(pt > 15) >= 2",
            "sum(pt) < 300 && count(pt > 15) >= 2"]
    reg.observe_plan(plan_window(warm))
    assert reg.hot()  # the shared conjunct is hot now
    exprs = ["e_total > 35 && count(pt > 15) >= 2",
             "e_t_miss > 20", "pt_lead > 60 || n_tracks >= 8"]

    def run(plan):
        cat = MetadataCatalog(store.n_nodes)
        jse = JobSubmissionEngine(cat, store)
        jids = [jse.submit(e) for e in exprs]
        return jse.run_job_batch_simulated(jids, plan=plan)

    base, _ = run(plan_window(exprs))
    seeded_plan = plan_window(exprs, registry=reg)
    # the hot fragment is materialized despite a single reference
    assert any("count" in k for k in seeded_plan.materialize_keys())
    seeded, st = run(seeded_plan)
    for got, want in zip(seeded, base):
        assert merge_lib.results_identical(got, want)
    # and the pre-warmed fragment's merged mask is a scan by-product
    assert any("count" in k for k in st.fragment_results)


def test_registry_prewarms_fragment_cache_across_windows():
    store = make_store()
    reg = FragmentRegistry(hot_min_windows=2)
    svc = QueryService(store, registry=reg)
    # the conjunct appears ONCE per window -> the >=2-refs per-window rule
    # alone would never materialize it
    for w in range(3):
        svc.submit(f"e_total > {30 + w} && count(pt > 15) >= 2", tenant="a")
        svc.step()
    assert svc.cache.stats.fragment_puts >= 1
    scanned = svc.stats.events_scanned
    t = svc.submit("count(pt > 15) >= 2", tenant="b")
    assert svc.result(t).from_cache
    assert svc.stats.events_scanned == scanned  # zero-I/O pre-warmed hit
    svc.close()


def test_registry_persistence_roundtrip(tmp_path):
    reg = FragmentRegistry(hot_min_windows=1, max_hot=4)
    reg.observe_plan(plan_window(["e_total > 30 && count(pt > 15) >= 2",
                                  "e_t_miss > 20 && count(pt > 15) >= 2"]))
    path = tmp_path / "registry.json"
    reg.save(path)
    loaded = FragmentRegistry.load(path)
    assert loaded.hot() == reg.hot()
    assert loaded.windows_observed == reg.windows_observed
    assert {r.key for r in loaded.records.values()} == set(reg.records)


# ----------------------- cost-model calibration ------------------------- #
def test_fit_cost_weights_recovers_synthetic_model():
    rng = np.random.default_rng(0)
    k, a_true, c_true = 2e-6, 3.0, 0.8
    tel = []
    for _ in range(300):
        size = int(rng.integers(16, 256))
        calib = int(rng.integers(0, 5))
        aggs = int(rng.integers(0, 4))
        wall = (k * size * (1 + c_true * calib) * (1 + a_true * aggs)
                * (1 + rng.normal(0, 0.02)))
        tel.append(PacketTelemetry(size, calib, aggs, wall))
    w = fit_cost_weights(tel)
    assert w.fitted
    assert abs(w.agg_weight - a_true) < 0.5
    assert abs(w.calib_weight - c_true) < 0.2


def test_fit_cost_weights_degenerate_falls_back_to_prior():
    # no variation in calib or aggs: nothing to identify the weights from
    tel = [PacketTelemetry(64, 2, 1, 1e-4) for _ in range(10)]
    w = fit_cost_weights(tel)
    from repro.service.planner import AGG_WEIGHT, CALIB_WEIGHT
    assert w.agg_weight == AGG_WEIGHT and w.calib_weight == CALIB_WEIGHT
    assert fit_cost_weights([]).fitted is False


def test_service_refits_weights_every_k_windows():
    store = make_store()
    svc = QueryService(store, refit_cost_every=2)
    assert svc.cost_weights is None  # cold-start prior in effect
    for i in range(4):
        svc.submit(f"e_total > {30 + i} && count(pt > 10) >= 1",
                   calib_iters=1)
        svc.step()
    assert svc.cost_weights is not None
    assert svc.cost_weights.scale > 0
    svc.close()


# ----------------------- stream-aware packet ramp ----------------------- #
def test_packet_ramp_small_early_packets_same_answer():
    store = make_store(n_events=512)
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store, packet_ramp=8)
    merged, st = jse.run_job_simulated(jse.submit("e_total > 40"))
    sizes = [t.size for t in st.packet_telemetry]
    assert sizes[0] <= 8          # first packet capped by the ramp
    assert max(sizes) > 8         # later packets grow past the cap
    cat2 = MetadataCatalog(store.n_nodes)
    jse2 = JobSubmissionEngine(cat2, store)
    merged2, _ = jse2.run_job_simulated(jse2.submit("e_total > 40"))
    # different packet partition, same physics
    assert merged.n_selected == merged2.n_selected
    assert merged.n_processed == merged2.n_processed
    np.testing.assert_array_equal(merged.hist, merged2.hist)


def test_service_stream_ramp_first_partial_earlier():
    def first_partial(**kw):
        store = make_store(n_events=1024, seed=13)
        svc = QueryService(store, use_cache=False, **kw)
        seen = []
        t = svc.submit("e_total > 40", stream=True)
        svc.stream(t).subscribe(lambda s: seen.append(s))
        svc.step()
        final = svc.stream(t).latest()
        assert final is not None and final.final
        assert merge_lib.results_identical(final.result,
                                           svc.result(t).result)
        return seen[0].t_virtual, final.t_virtual

    t_ramp, final_ramp = first_partial(stream_ramp=8)
    t_plain, final_plain = first_partial()
    assert t_ramp < t_plain       # ramp lands the first exact prefix earlier
    # and the makespan cost of streaming-friendly sizing stays small
    assert final_ramp <= final_plain * 1.5


# ----------------------- lifecycle hygiene (satellite) ------------------ #
def test_service_close_prevents_hook_accumulation():
    store = make_store()
    catalog = MetadataCatalog(store.n_nodes)
    for _ in range(10):
        svc = QueryService(store, catalog,
                           cache=TieredResultCache(catalog=catalog,
                                                   l2=SharedCacheTier()))
        t = svc.submit("e_total > 40")
        svc.drain()
        assert svc.result(t).status == "SERVED"
        svc.close()
    # a long-lived catalogue holds no dead hooks after services shut down
    assert catalog._epoch_hooks == []
    svc.close()  # idempotent


def test_fleet_close_detaches_everything_and_aborts_streams():
    store = make_store()
    fleet = make_fleet(store, 3)
    g = fleet.submit("e_total > 40", frontend=0, stream=True)
    rs = fleet.stream(g)
    fleet.close()
    for fe in fleet.frontends:
        assert fe.catalog._epoch_hooks == []
    assert rs.state == "ABORTED" and "closed" in rs.note


# ----------------------- review regressions ---------------------------- #
def test_packet_ramp_cap_never_overflows():
    from repro.core.packets import AdaptivePacketScheduler
    cat = MetadataCatalog(2)
    sched = AdaptivePacketScheduler(cat, ramp_start=16, ramp_factor=2.0)
    sched.done = [None] * 5000  # far past any float-exponent range
    sched.add_work(0, 10_000)
    assert sched.packet_size_for(0) >= sched.min  # no OverflowError


def test_conflicting_liveness_observations_converge():
    store = make_store()
    fleet = make_fleet(store, 3)
    # fe0 and fe1 observe CONFLICTING equal-version facts concurrently
    fleet.frontends[0].gossip.observe_liveness(1, False)
    fleet.frontends[1].gossip.observe_liveness(1, True)
    fleet.pump(2 * fleet.rounds_bound)
    views = [1 in fe.catalog.dead_nodes() for fe in fleet.frontends]
    assert len(set(views)) == 1  # deterministic fleet-wide agreement


def test_proxy_release_and_reattach_gets_full_replay():
    store = make_store(n_events=256)
    fleet = Fleet(store, 2, service_kwargs={"use_cache": False,
                                            "stream_capacity": 512})
    g = fleet.submit("e_total > 40", tenant="a", frontend=0, stream=True)
    proxy = fleet.stream(g, frontend=1)
    fleet.pump()
    fleet.step(0)
    fleet.drain()
    assert proxy.done
    reader = fleet.frontends[1].fanout
    reader.release(g)
    again = fleet.stream(g, frontend=1)
    assert again is not proxy
    fleet.drain()
    # the re-attached proxy still receives the buffered prefix + final
    assert again.done and again.published > 0


def test_drain_terminates_on_delayed_bus():
    store = make_store()
    fleet = Fleet(store, 3, bus=MessageBus(delay=2))
    fleet.submit("e_total > 40", tenant="a", frontend=0, stream=True)
    fleet.drain()
    # gossip emits every pump, so a delayed bus is never "idle" — drain
    # must still terminate promptly instead of burning its guard rounds
    assert fleet.bus.round < 100
    fleet.close()


# ----------------------- gossip-driven failover (satellite) ------------- #
def test_gossip_failover_propagates_to_peer_scheduling():
    store = make_store(n_events=256)
    fleet = make_fleet(store, 3)
    # fe0 observes the death; peers have not heard yet
    plan = fleet.node_leave(1, observed_by=0)
    assert not plan.lost_bricks  # replication covered every brick
    assert 1 in fleet.frontends[0].catalog.dead_nodes()
    assert 1 not in fleet.frontends[2].catalog.dead_nodes()
    fleet.pump(fleet.rounds_bound)
    # liveness gossip reached every peer's catalogue
    for fe in fleet.frontends:
        assert 1 in fe.catalog.dead_nodes()
    # a peer's scan now avoids the dead node entirely and still succeeds
    t = fleet.submit("e_total > 40", tenant="a", frontend=2)
    fleet.drain()
    tk = fleet.result(t)
    assert tk.status == "SERVED"
    svc2 = fleet.frontends[2].service
    assert svc2.stats.events_scanned > 0
    # rejoin propagates the same way
    fleet.node_join(1, observed_by=2)
    fleet.pump(fleet.rounds_bound)
    for fe in fleet.frontends:
        assert 1 not in fe.catalog.dead_nodes()
