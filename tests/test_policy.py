"""Failure-scenario matrix for the failure-policy engine
(``service/policy.py``): state-machine hysteresis, routing avoidance,
speculative re-execution, proactive re-replication, gossip ack/repair —
every scenario asserted bit-identical to its failure-free run.

Seeds come from ``POLICY_SEEDS`` (comma-separated, default 101,202,303)
so the CI policy-matrix job can pin one seed per shard.
"""
import os

import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store
from repro.core.backend import SimulatedBackend
from repro.core.catalog import DONE, MetadataCatalog
from repro.core.jse import JobSubmissionEngine
from repro.fabric import Fleet, FragmentRegistry, MessageBus
from repro.fabric.gossip import rounds_bound_lossy
from repro.obs import Observability
from repro.obs.health import HEALTH_OK, HEALTH_SUSPECT, HealthReport
from repro.obs.trace import validate_records
from repro.service import QueryScheduler, QueryService, WindowController
from repro.service.policy import (POLICY_BANNED, POLICY_DEGRADED, POLICY_OK,
                                  POLICY_PROBING, FailurePolicy, PolicyConfig)

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)
POLICY_SEEDS = tuple(int(s) for s in os.environ.get(
    "POLICY_SEEDS", "101,202,303").split(","))


def make_store(n_events=192, n_nodes=4, replication=2, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=seed)


EXPRS = ["e_total > 40 && count(pt > 15) >= 2",
         "e_t_miss > 30",
         "pt_lead > 60 || n_tracks >= 8"]


def run_engine(store, *, node_speed=None, failure_script=None,
               dead=(), collect=None, **kw):
    """One shared-scan batch of EXPRS on a pristine catalogue with fixed
    (non-adaptive) packet sizing, so every run partitions the sweep
    identically regardless of routing/failures/speculation."""
    cat = MetadataCatalog(store.n_nodes)
    for n in dead:
        cat.mark_dead(n)
    jse = JobSubmissionEngine(cat, store, node_speed=node_speed,
                              adaptive_packets=False)
    jids = [jse.submit(e) for e in EXPRS]
    on_partial = None
    if collect is not None:
        on_partial = collect.append
    merged, stats = jse.run_job_batch_simulated(
        jids, failure_script=failure_script, on_partial=on_partial, **kw)
    return merged, stats, cat, jids


def assert_batches_identical(got, want):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert merge_lib.results_identical(a, b)


def report_with(failures):
    """Fabricated health evidence: failure EWMAs only (the deterministic
    evidence channel the policy's scenario configs trust)."""
    states = {n: (HEALTH_SUSPECT if f >= 0.3 else HEALTH_OK)
              for n, f in failures.items()}
    return HealthReport(states=states, rates={}, failures=dict(failures))


class FakeStats:
    def __init__(self, telemetry=()):
        self.packet_telemetry = tuple(telemetry)


class FakeTelemetry:
    def __init__(self, node):
        self.node = node


# ------------------------- state machine (unit) ------------------------ #
def test_state_machine_full_lifecycle_with_hysteresis():
    store = make_store()
    cat = MetadataCatalog(store.n_nodes)
    pol = FailurePolicy(cat, store, config=PolicyConfig(
        degrade_after=2, recover_after=2, ban_after=2, probe_after=2,
        probe_packets=3, rereplicate_after=99))
    sick, clean = report_with({1: 0.8}), report_with({1: 0.0})

    # ok -> degraded needs degrade_after consecutive unhealthy windows
    pol.decide(sick)
    assert pol.states()[1] == POLICY_OK
    pol.decide(sick)
    assert pol.states()[1] == POLICY_DEGRADED
    # one clean window resets the suspect streak, no transition
    pol.decide(clean)
    assert pol.states()[1] == POLICY_DEGRADED
    # degraded -> banned needs ban_after consecutive suspect windows
    pol.decide(sick)
    pol.decide(sick)
    assert pol.states()[1] == POLICY_BANNED
    # banned dwells probe_after windows, then probes with quota
    d = pol.decide(clean)
    assert pol.states()[1] == POLICY_BANNED and 1 in d.avoid
    assert d.probe_quota == {}
    d = pol.decide(clean)
    assert pol.states()[1] == POLICY_PROBING
    assert d.probe_quota == {1: 3} and 1 in d.avoid
    # probing clears only on observed clean probe packets, not reports
    pol.observe_window(FakeStats([FakeTelemetry(1)] * 2))
    assert pol.states()[1] == POLICY_PROBING
    pol.observe_window(FakeStats([FakeTelemetry(1)]))
    assert pol.states()[1] == POLICY_OK
    # recovery reset the re-replication episode
    assert not pol.nodes[1].rereplicated and pol.nodes[1].degraded_run == 0


def test_dead_node_forced_banned_and_rejoins_via_probing():
    store = make_store()
    cat = MetadataCatalog(store.n_nodes)
    pol = FailurePolicy(cat, store, config=PolicyConfig(probe_after=1))
    cat.mark_dead(2)
    d = pol.decide(None)
    assert pol.states()[2] == POLICY_BANNED and 2 in d.avoid
    # a rejoin never goes straight back to ok
    cat.mark_alive(2)
    d = pol.decide(None)
    assert pol.states()[2] == POLICY_PROBING and d.probe_quota[2] > 0


def test_sustained_degradation_rereplicates_once_per_episode():
    store = make_store(replication=2)  # surviving copies to source from
    cat = MetadataCatalog(store.n_nodes)
    pol = FailurePolicy(cat, store, config=PolicyConfig(
        degrade_after=1, ban_after=99, rereplicate_after=2))
    sick = report_with({1: 0.9})
    before = {b: set(s.replicas) for b, s in store.specs.items()}
    pol.decide(sick)        # ok -> degraded (episode clock starts after)
    pol.decide(sick)        # degraded_run = 1
    assert pol.rereplications == 0
    d = pol.decide(sick)    # degraded_run = 2 = rereplicate_after
    assert pol.rereplications == 1 and d.rereplicated
    # every copy lands off the sick node and extends replicas
    for bid, src, dst in d.rereplicated:
        assert dst != 1 and dst in store.specs[bid].replicas
        assert dst not in before[bid]
    # the episode re-replicates once, not every window
    pol.decide(sick)
    assert pol.rereplications == 1


def test_rereplication_data_movement_charged_in_window_jobstats():
    """A window dispatched under a decision that re-replicated bricks
    pays for the copies on the virtual clock: ``backend_kwargs`` carries
    the copy list, the backend charges each copy's transfer time to both
    endpoints, and ``JobStats.rereplication_transfer_s`` records it —
    re-replication is no longer free in the time model."""
    store = make_store(replication=2)
    cat = MetadataCatalog(store.n_nodes)
    pol = FailurePolicy(cat, store, config=PolicyConfig(
        degrade_after=1, ban_after=99, rereplicate_after=2))
    sick = report_with({1: 0.9})
    pol.decide(sick), pol.decide(sick)
    d = pol.decide(sick)
    assert d.rereplicated
    assert d.backend_kwargs()["rereplicated"] == d.rereplicated

    def window(kwargs):
        c = MetadataCatalog(store.n_nodes)
        be = SimulatedBackend(c, store, adaptive_packets=False)
        jids = [be.submit(e) for e in EXPRS]
        return be.run_batch(jids, **kwargs)

    base_res, base_stats = window({})
    res, stats = window(d.backend_kwargs())
    assert base_stats.rereplication_transfer_s == 0.0
    assert stats.rereplication_transfer_s > 0.0
    tm = SimulatedBackend(MetadataCatalog(store.n_nodes), store).engine.tm
    want = sum(store.specs[bid].n_events * tm.brick_bytes_per_event
               / tm.bandwidth_Bps for bid, _, _ in d.rereplicated)
    assert stats.rereplication_transfer_s == pytest.approx(want)
    # the copies delay their endpoints, so the window can only slow down
    assert stats.makespan_s >= base_stats.makespan_s
    # and never perturb results
    for a, b in zip(base_res, res):
        assert merge_lib.results_identical(a, b)


# ------------------- engine routing avoidance (unit) ------------------- #
def test_avoided_node_gets_zero_packets_results_identical():
    store = make_store(n_events=256)
    base, _, _, _ = run_engine(store)
    got, stats, cat, jids = run_engine(store, route_avoid={2})
    assert_batches_identical(got, base)
    assert all(t.node != 2 for t in stats.packet_telemetry)
    assert stats.packet_telemetry  # the other nodes did the work
    assert all(cat.jobs[j].status == DONE for j in jids)


def test_probe_quota_admits_exactly_that_many_packets():
    store = make_store(n_events=256)
    base, _, _, _ = run_engine(store)
    got, stats, _, _ = run_engine(store, route_avoid={2},
                                  probe_quota={2: 1})
    assert_batches_identical(got, base)
    assert sum(1 for t in stats.packet_telemetry if t.node == 2) == 1


def test_availability_beats_policy_when_avoidance_would_starve():
    store = make_store(n_events=256)
    base, _, _, _ = run_engine(store)
    got, stats, cat, jids = run_engine(store, route_avoid={0, 1, 2, 3})
    assert_batches_identical(got, base)
    assert all(cat.jobs[j].status == DONE for j in jids)


# ------------------- speculative re-execution (unit) ------------------- #
@pytest.mark.parametrize("seed", POLICY_SEEDS)
def test_speculation_bit_identical_and_cuts_straggler_tail(seed):
    store = make_store(n_events=256, seed=seed)
    slow = {1: 0.02}  # node 1 computes at 2% speed: every packet straggles
    plain_parts = []
    base, _, _, _ = run_engine(store, node_speed=slow, collect=plain_parts)
    spec_parts = []
    got, stats, cat, jids = run_engine(
        store, node_speed=slow, collect=spec_parts, speculate=True)
    assert_batches_identical(got, base)
    assert all(cat.jobs[j].status == DONE for j in jids)
    # speculation actually fired and won at least once
    assert stats.speculated >= 1 and stats.spec_wins >= 1
    # every packet merged exactly once, in seq order (no double-merge)
    seqs = [p.seq for p in spec_parts]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert set(seqs) == {p.seq for p in plain_parts}
    # the straggler tail shrank: last partial lands strictly earlier
    assert max(p.t_virtual for p in spec_parts) < \
        max(p.t_virtual for p in plain_parts)


def test_speculation_composes_with_mid_scan_node_death():
    store = make_store(n_events=256)
    script = {0.5: 3}
    base, bstats, _, _ = run_engine(store, failure_script=dict(script))
    got, stats, cat, jids = run_engine(
        store, failure_script=dict(script), speculate=True)
    assert_batches_identical(got, base)
    assert stats.failures == bstats.failures == 1
    assert all(cat.jobs[j].status == DONE for j in jids)


# ------------------- correlated failures (scenario) -------------------- #
@pytest.mark.parametrize("seed", POLICY_SEEDS)
def test_correlated_multi_node_death_bit_identical(seed):
    """Two nodes of four die together (a rack).  Replica placement is
    stride-2 (owner pairs {n, n+2}), so killing the ADJACENT pair 1 and 2
    leaves every brick a live owner — the scan completes bit-identical
    to the healthy run.  Killing a stride pair instead loses bricks."""
    store = make_store(n_events=256, replication=2, seed=seed)
    base, _, _, _ = run_engine(store)
    got, stats, cat, jids = run_engine(store, dead=(1, 2))
    assert_batches_identical(got, base)
    assert all(t.node in (0, 3) for t in stats.packet_telemetry)
    assert all(cat.jobs[j].status == DONE for j in jids)
    # routing policy layered on top of the deaths changes nothing
    got2, _, _, _ = run_engine(store, dead=(1, 2), route_avoid={0},
                               probe_quota={0: 2}, speculate=True)
    assert_batches_identical(got2, base)
    # the rack that DOES share replica pairs (stride partners 1 and 3)
    # loses those bricks: the engine fails the jobs rather than serving
    # a silent partial result
    _, _, cat3, jids3 = run_engine(store, dead=(1, 3))
    assert all(cat3.jobs[j].status != DONE for j in jids3)


# ---------------- banned-node lifecycle (acceptance) ------------------- #
def _lifecycle_config():
    return PolicyConfig(degrade_after=1, recover_after=1, ban_after=1,
                        probe_after=2, probe_packets=4,
                        rereplicate_after=2, rate_evidence=False)


def _drive_windows(svc, n_windows, per_window=3):
    """Submit DISTINCT queries each window (cache hits run no scan, so a
    repeated workload would never produce probe packets) and step."""
    tickets = []
    for w in range(n_windows):
        for q in range(per_window):
            tid = svc.submit(f"e_total > {20 + 2 * (w * per_window + q)}",
                             tenant=f"t{q}")
            tickets.append(tid)
        svc.step()
    return tickets


@pytest.mark.parametrize("seed", POLICY_SEEDS)
def test_banned_node_lifecycle_end_to_end(seed):
    """The tentpole acceptance scenario: seeded failure evidence drives
    node 1 through degraded -> banned -> probing -> ok; the banned window
    routes ZERO packets to it (asserted from trace records); sustained
    degradation re-replicates its bricks; results stay bit-identical to
    the same workload on a policy-less service."""
    n_windows = 8
    # fixed 64-event packets: the sweep partitions identically whether or
    # not a node is banned, so float merges are bit-identical too
    store = make_store(n_events=1024, seed=seed)
    pstore = make_store(n_events=1024, seed=seed)
    plain = QueryService(pstore, backend=SimulatedBackend(
        MetadataCatalog(pstore.n_nodes), pstore, adaptive_packets=False))
    want = _drive_windows(plain, n_windows)

    obs = Observability(origin="fe0")
    cat = MetadataCatalog(store.n_nodes)
    pol = FailurePolicy(cat, store, obs=obs, config=_lifecycle_config())
    svc = QueryService(store, backend=SimulatedBackend(
        cat, store, adaptive_packets=False), obs=obs, policy=pol)

    states_per_window = []
    packets_per_window = []
    tickets = []
    for w in range(n_windows):
        if w < 2:
            # the node is actively failing for two windows: fresh deaths
            # keep the failure EWMA above threshold against the decay
            # from the clean packets it still serves pre-ban
            for _ in range(6):
                obs.health.observe_failure(1)
        for q in range(3):
            tid = svc.submit(f"e_total > {20 + 2 * (w * 3 + q)}",
                             tenant=f"t{q}")
            tickets.append(tid)
        before = len(obs.tracer.records())
        svc.step()
        new = obs.tracer.records()[before:]
        packets_per_window.append(
            [r["attrs"].get("node") for r in new
             if r.get("name") == "packet"])
        states_per_window.append(pol.states()[1])

    # the full arc, one transition per window of evidence
    assert states_per_window[0] == POLICY_DEGRADED
    assert states_per_window[-1] == POLICY_OK
    banned_windows = [w for w, s in enumerate(states_per_window)
                      if s == POLICY_BANNED]
    assert banned_windows  # the ban actually happened
    # zero packets routed to the banned node, proven from the trace
    for w in banned_windows:
        assert 1 not in packets_per_window[w]
        assert packets_per_window[w]  # the others carried the window
    # probing re-admitted node 1 (bounded by its quota) before recovery
    post_ban = range(banned_windows[-1] + 1, n_windows)
    probe_counts = [packets_per_window[w].count(1) for w in post_ban]
    assert any(c > 0 for c in probe_counts)
    assert all(c <= pol.config.probe_packets for c in probe_counts[:1])
    # sustained degradation proactively re-replicated its bricks
    assert pol.rereplications >= 1
    assert obs.metrics.value("policy.rereplications") >= 1
    # bit-identical to the policy-less service, every ticket served
    for got_t, want_t in zip(tickets, want):
        a, b = svc.result(got_t), plain.result(want_t)
        assert a.status == b.status == "SERVED"
        assert merge_lib.results_identical(a.result, b.result)
    # transitions landed on the virtual timeline, trace is well-formed
    recs = obs.tracer.records()
    trans = [r for r in recs if r.get("name") == "policy_transition"]
    assert [(t["attrs"]["old"], t["attrs"]["new"]) for t in trans] == [
        ("ok", "degraded"), ("degraded", "banned"),
        ("banned", "probing"), ("probing", "ok")]
    assert any(t["t0_virtual"] > 0 for t in trans)
    assert validate_records(recs) == []


def test_policy_narrows_admission_under_tenant_burst():
    """A thundering-herd burst from one tenant while a node is banned:
    the scheduler narrows the window by the routable fraction, yet every
    query is eventually served with correct results."""
    store = make_store(n_events=256)
    obs = Observability(origin="fe0")
    cat = MetadataCatalog(store.n_nodes)
    pol = FailurePolicy(cat, store, obs=obs, config=_lifecycle_config())
    pol.nodes[1].state = POLICY_BANNED  # mid-episode: node 1 out
    sched = QueryScheduler(max_batch=8, obs=obs)
    svc = QueryService(store, backend=SimulatedBackend(
        cat, store, adaptive_packets=False), obs=obs, policy=pol,
        scheduler=sched)
    pstore = make_store(n_events=256)
    plain = QueryService(pstore, backend=SimulatedBackend(
        MetadataCatalog(pstore.n_nodes), pstore, adaptive_packets=False))

    burst = [f"e_total > {20 + i}" for i in range(16)]
    tids = [svc.submit(e, tenant="herd") for e in burst]
    want = [plain.submit(e, tenant="herd") for e in burst]
    svc.step()
    assert sched.last_health_hint["routable_fraction"] == 0.75
    assert sched.last_health_hint["max_batch"] == 6  # 8 * 0.75
    svc.drain()
    plain.drain()
    for a, b in zip(tids, want):
        assert merge_lib.results_identical(svc.result(a).result,
                                           plain.result(b).result)


# ------------------- epoch bump mid-workload (scenario) ---------------- #
def test_epoch_bump_between_windows_never_serves_stale():
    store = make_store(n_events=256)
    obs = Observability(origin="fe0")
    cat = MetadataCatalog(store.n_nodes)
    pol = FailurePolicy(cat, store, obs=obs, config=_lifecycle_config())
    svc = QueryService(store, backend=SimulatedBackend(
        cat, store, adaptive_packets=False), obs=obs, policy=pol)
    a = svc.submit("e_total > 40", tenant="t0")
    svc.step()
    warm = svc.submit("e_total > 40", tenant="t1")
    svc.step()
    assert svc.result(warm).from_cache
    cat.bump_dataset_version()  # dataset changed mid-workload
    cold = svc.submit("e_total > 40", tenant="t2")
    svc.step()
    assert not svc.result(cold).from_cache
    assert merge_lib.results_identical(svc.result(cold).result,
                                       svc.result(a).result)


# ---------------- gossip ack/repair under loss (scenario) -------------- #
@pytest.mark.parametrize("seed", POLICY_SEEDS)
def test_gossip_repair_converges_under_seeded_bus_loss(seed):
    drop = 0.35
    store = make_store()
    bus = MessageBus(drop_rate=drop, seed=seed)
    fleet = Fleet(store, 4, bus=bus, obs=True, gossip_repair=True,
                  policy=True, registry=FragmentRegistry())
    bound = rounds_bound_lossy(4, fleet.gossip_fanout, drop_rate=drop,
                               confidence=0.999)
    assert bound > fleet.rounds_bound  # loss buys extra rounds, bounded
    fleet.bump_dataset_version(0)
    for _ in range(bound):
        fleet.pump(1)
        if all(fe.catalog.dataset_epoch == 1 for fe in fleet.frontends):
            break
    assert [fe.catalog.dataset_epoch for fe in fleet.frontends] == [1] * 4
    acks = sum(fe.gossip.stats.acks_received for fe in fleet.frontends)
    assert acks > 0  # the ack channel was exercised under loss
    fleet.close()


def test_gossip_repair_survives_one_dead_link():
    """A single link losing 90% of its messages: ack-timeout repair keeps
    re-pushing until the digest lands (or a reply arrives via the
    push-pull path), so the victim still converges."""
    store = make_store()
    bus = MessageBus(seed=3)
    bus.set_link_loss("fe0", "fe1", 0.9)
    fleet = Fleet(store, 3, bus=bus, obs=True, gossip_repair=True,
                  registry=FragmentRegistry())
    fleet.bump_dataset_version(0)
    bound = rounds_bound_lossy(3, fleet.gossip_fanout, drop_rate=0.9,
                               confidence=0.999)
    for _ in range(bound):
        fleet.pump(1)
        if all(fe.catalog.dataset_epoch == 1 for fe in fleet.frontends):
            break
    assert [fe.catalog.dataset_epoch for fe in fleet.frontends] == [1] * 3
    fleet.close()


# ------------- partition + heal during streaming (scenario) ------------ #
def test_partition_during_stream_never_final_then_heals_identical():
    store = make_store(n_events=256)
    fleet = Fleet(store, 2, obs=True, policy=True, gossip_repair=True,
                  registry=FragmentRegistry(),
                  service_kwargs={"use_cache": False})
    g = fleet.submit("e_total > 40", tenant="a", frontend=0, stream=True)
    local = []
    fleet.stream(g).subscribe(local.append)
    orphan = fleet.stream(g, frontend=1)
    fleet.pump()                      # subscription reaches the owner
    fleet.bus.partition(["fe0"], ["fe1"])
    fleet.step(0)                     # scan runs while fe1 is cut off
    fleet.drain()
    # the cut-off proxy NEVER surfaces a partial as final
    assert not orphan.done
    assert all(not s.final for s in orphan.buffered())
    assert local and local[-1].final

    fleet.bus.heal()
    fleet.pump(fleet.rounds_bound)
    # a post-heal reader re-subscribes (release drops the cut-off proxy)
    # and replays the buffered prefix, final included, bit-identical to
    # what the local subscriber saw
    fleet.frontends[1].fanout.release(g)
    healed = fleet.stream(g, frontend=1)
    fleet.drain()
    got = healed.buffered()
    assert got and got[-1].final
    assert merge_lib.results_identical(got[-1].result, local[-1].result)
    assert got[-1].t_virtual == local[-1].t_virtual
    for fe in fleet.frontends:
        assert validate_records(fe.obs.tracer.records()) == []
    fleet.close()


# ------------------ WindowController hysteresis (fix) ------------------ #
def _drive_square_wave(wc, cycles=40):
    """Arrivals at a fixed rate, scan latency square-waving between two
    values whose λ·L targets straddle adjacent widths."""
    t, widths = 0.0, []
    for i in range(cycles):
        for _ in range(4):
            t += 0.1
            wc.observe_arrival(t)
        wc.observe_scan(1.0 if i % 2 == 0 else 1.35)
        widths.append(wc.window())
    return widths


def test_window_controller_square_wave_does_not_oscillate():
    flappy = _drive_square_wave(WindowController(initial=16, hysteresis=0.0))
    steady = _drive_square_wave(WindowController(initial=16))
    flaps = lambda ws: sum(1 for a, b in zip(ws, ws[1:]) if a != b)
    # the raw controller re-sizes every window once warmed up; the
    # dead-band holds one width after the initial settle
    assert flaps(flappy[10:]) >= 10
    assert flaps(steady[10:]) == 0
    # hysteresis=0 reproduces the pre-fix controller exactly
    assert flappy == _drive_square_wave(
        WindowController(initial=16, hysteresis=0.0))


def test_window_controller_tracks_real_demand_shifts():
    wc = WindowController(initial=16, hysteresis=0.25)
    t = 0.0
    for _ in range(30):
        t += 0.1
        wc.observe_arrival(t)
        wc.observe_scan(1.0)
    settled = wc.window()
    for _ in range(30):  # demand actually quadruples: the band must open
        t += 0.025
        wc.observe_arrival(t)
        wc.observe_scan(1.0)
    assert wc.window() > settled * 2


def test_window_controller_rejects_negative_hysteresis():
    with pytest.raises(ValueError):
        WindowController(hysteresis=-0.1)


# ------------------- property test (hypothesis, CI) -------------------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _PROP_STORE = make_store(n_events=256, seed=11)
    _PROP_BASE, _, _, _ = run_engine(_PROP_STORE)

    @settings(max_examples=25, deadline=None)
    @given(kills=st.lists(
        st.tuples(st.floats(0.05, 3.0), st.integers(0, 3)),
        max_size=2, unique_by=lambda kv: kv[1]),
        speculate=st.booleans(),
        lead=st.floats(0.5, 3.0))
    def test_random_failure_scripts_with_speculation_exact(
            kills, speculate, lead):
        """Any failure script x speculation timing: results bit-identical
        to the failure-free run, every packet merged exactly once, and
        the final coverage is exact."""
        script = {t: n for t, n in kills}
        if len(script) < len(kills):
            return  # two kills collapsed onto one virtual time
        parts = []
        got, stats, cat, jids = run_engine(
            _PROP_STORE, failure_script=script, collect=parts,
            speculate=speculate, spec_lead_factor=lead)
        assert_batches_identical(got, _PROP_BASE)
        assert all(cat.jobs[j].status == DONE for j in jids)
        seqs = [p.seq for p in parts]
        assert len(set(seqs)) == len(seqs)  # no double-merge
        assert seqs == sorted(seqs)         # merge order respected
        # replaying the partial stream through a MergeAccumulator lands
        # exactly on the final result with complete coverage
        acc = merge_lib.MergeAccumulator(
            events_total=_PROP_STORE.n_events,
            bricks_total=len(_PROP_STORE.bricks))
        for p in parts:
            acc.add(p.partials[0], brick_id=p.brick_id,
                    events=p.size, t_virtual=p.t_virtual)
        assert merge_lib.results_identical(acc.snapshot(), got[0])
        cov = acc.coverage()
        assert cov.events_scanned == _PROP_STORE.n_events
        assert cov.complete
