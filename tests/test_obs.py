"""Observability plane: span determinism, schema validation, mergeable
metrics (incl. associativity property), health telemetry + scheduler
gate, disabled-path equivalence, fleet metrics reconciliation."""
import numpy as np
import pytest

try:  # property tests run where hypothesis is installed (CI tier-1)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store
from repro.fabric import Fleet
from repro.obs import (HEALTH_OK, HEALTH_STATES, HEALTH_SUSPECT,
                       HealthMonitor,
                       MetricsRegistry, MetricsSnapshot, Observability,
                       STATUS_ERROR, STATUS_OK, Tracer, chrome_from_records,
                       comparable_records, load_jsonl, merge2,
                       merge_snapshots, save_jsonl, validate_records)
from repro.service import QueryScheduler, QueryService, make_submission
from repro.service.frontend import REJECTED, SERVED
from repro.service.streaming import ABORTED

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)


def make_store(n_events=192, n_nodes=4, replication=2, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=seed)


EXPRS = [
    "e_total > 40.0",
    "e_total > 40.0 && count(pt > 12.0) >= 1",
    "sum(pt) < 400.0 || n_tracks >= 2",
]


def run_service(store, *, obs=None, backend="sim", stream=False):
    svc = QueryService(store, backend=backend, obs=obs)
    tids = [svc.submit(e, tenant=f"t{i % 2}", stream=stream)
            for i, e in enumerate(EXPRS)]
    svc.drain()
    svc.close()
    return svc, tids


# ----------------------------- tracer ---------------------------------- #
def test_tracer_span_lifecycle():
    tr = Tracer(process="fe0")
    s = tr.begin("submit", t_virtual=1.0, ticket=3, tenant="a")
    assert s.status == "open" and tr.open_spans() == [s]
    tr.end(s, t_virtual=2.0, status=STATUS_ERROR, note="boom")
    assert s.status == STATUS_ERROR and s.attrs["note"] == "boom"
    # idempotent close: the first (error) verdict wins later cleanups
    tr.end(s, t_virtual=9.0, status=STATUS_OK)
    assert s.status == STATUS_ERROR and s.t1_virtual == 2.0

    e = tr.event("final", t_virtual=2.0, ticket=3, outcome="SERVED")
    assert e.kind == "event" and e.status == STATUS_OK
    assert e.t1_virtual == e.t0_virtual
    assert tr.open_spans() == []


def test_tracer_parent_stack():
    tr = Tracer()
    w = tr.begin("window", t_virtual=0.0)
    tr.push(w)
    p = tr.begin("packet", t_virtual=0.1)
    assert p.parent_id == w.span_id
    explicit = tr.begin("plan", t_virtual=0.1, parent=p)
    assert explicit.parent_id == p.span_id
    assert tr.pop() is w
    orphan = tr.begin("submit", t_virtual=0.2)
    assert orphan.parent_id is None


def test_validate_records_catches_problems():
    tr = Tracer(process="fe0")
    s = tr.begin("window", t_virtual=0.0)
    recs = tr.records()
    assert any("open" in p for p in validate_records(recs))
    tr.end(s, t_virtual=1.0)
    assert validate_records(tr.records()) == []

    bad = tr.records()
    bad[0]["parent_id"] = 999
    assert any("dangling" in p for p in validate_records(bad))
    bad = tr.records()
    bad[0]["status"] = "weird"
    assert any("bad status" in p for p in validate_records(bad))
    bad = tr.records()
    del bad[0]["ticket"]
    assert any("missing field" in p for p in validate_records(bad))


def test_jsonl_roundtrip_and_chrome_export(tmp_path):
    tr = Tracer(process="fe0")
    s = tr.begin("dispatch", t_virtual=0.5, batch=0)
    tr.push(s)
    p = tr.begin("packet", t_virtual=0.5, node=2, brick=1, size=64)
    tr.end(p, t_virtual=1.5)
    tr.pop()
    tr.end(s, t_virtual=2.0)
    tr.event("final", t_virtual=2.0, ticket=0, outcome="SERVED")

    path = tmp_path / "t.jsonl"
    save_jsonl(tr.records(), path)
    assert load_jsonl(path) == tr.records()

    chrome = chrome_from_records(tr.records())
    evs = chrome["traceEvents"]
    assert [e["ph"] for e in evs] == ["X", "X", "i"]
    pkt = evs[1]
    assert pkt["tid"] == 2 and pkt["ts"] == pytest.approx(0.5e6)
    assert pkt["dur"] == pytest.approx(1.0e6)


# ----------------------------- metrics --------------------------------- #
def test_histogram_buckets_and_registry_errors():
    reg = MetricsRegistry(origin="fe0")
    h = reg.histogram("lat", edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)
    assert h.counts == [2, 0, 1, 1] and h.count == 4
    # fetching without edges returns the registered instance
    assert reg.histogram("lat") is h
    with pytest.raises(ValueError):
        reg.histogram("lat", edges=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.counter("lat")
    with pytest.raises(ValueError):
        reg.histogram("bad", edges=(2.0, 1.0))


def test_merge2_semantics():
    ra, rb = MetricsRegistry("a"), MetricsRegistry("b")
    ra.counter("c").inc(3)
    rb.counter("c").inc(4)
    ra.gauge("g").set(2.0)
    rb.gauge("g").set(5.0)
    ra.histogram("h", edges=(1.0, 2.0)).observe(0.5)
    rb.histogram("h", edges=(1.0, 2.0)).observe(1.5)
    ra.counter("only_a").inc()

    m = merge2(ra.snapshot(), rb.snapshot())
    assert m.value("c") == 7 and m.value("g") == 5.0
    assert m.value("only_a") == 1
    assert m.hist("h")["counts"] == [1, 1, 0]
    assert m.origins == ("a", "b")

    rc = MetricsRegistry("c")
    rc.histogram("h", edges=(1.0, 3.0)).observe(0.5)
    with pytest.raises(ValueError):
        merge2(m, rc.snapshot())
    rd = MetricsRegistry("d")
    rd.gauge("c").set(1.0)
    with pytest.raises(ValueError):
        merge2(m, rd.snapshot())


_EDGES = [1.0, 2.0, 4.0]


def _check_merge_algebra(a, b, c):
    left = merge2(merge2(a, b), c)
    right = merge2(a, merge2(b, c))
    assert left.metrics == right.metrics
    assert merge2(a, b).metrics == merge2(b, a).metrics
    # tree reduction agrees with a sequential fold
    folded = merge2(merge2(a, b), c)
    assert merge_snapshots([a, b, c]).metrics == folded.metrics


def _random_snapshot(rng):
    # fixed name -> type mapping so any two generated snapshots merge;
    # integer-valued floats keep addition exactly associative
    metrics = {}
    if rng.random() < 0.8:
        metrics["c1"] = {"type": "counter",
                         "value": float(rng.integers(0, 1000))}
    if rng.random() < 0.5:
        metrics["g1"] = {"type": "gauge",
                         "value": float(rng.integers(0, 1000))}
    if rng.random() < 0.8:
        metrics["h1"] = {"type": "histogram", "edges": list(_EDGES),
                         "counts": [int(v) for v in
                                    rng.integers(0, 50, size=4)],
                         "sum": float(rng.integers(0, 1000)),
                         "count": int(rng.integers(0, 200))}
    return MetricsSnapshot(metrics=metrics, origins=("o",))


def test_merge2_associative_commutative_seeded():
    rng = np.random.default_rng(0)
    for _ in range(50):
        _check_merge_algebra(*(_random_snapshot(rng) for _ in range(3)))


if HAVE_HYPOTHESIS:
    def _snapshot_strategy():
        num = st.integers(0, 1000).map(float)
        counter = st.fixed_dictionaries(
            {"type": st.just("counter"), "value": num})
        gauge = st.fixed_dictionaries(
            {"type": st.just("gauge"), "value": num})
        hist = st.fixed_dictionaries({
            "type": st.just("histogram"), "edges": st.just(list(_EDGES)),
            "counts": st.lists(st.integers(0, 50), min_size=4, max_size=4),
            "sum": num, "count": st.integers(0, 200)})
        by_name = {"c1": counter, "c2": counter, "g1": gauge, "h1": hist}
        names = st.sets(st.sampled_from(sorted(by_name)), max_size=4)
        return names.flatmap(
            lambda ns: st.fixed_dictionaries(
                {n: by_name[n] for n in sorted(ns)})).map(
            lambda m: MetricsSnapshot(metrics=m, origins=("o",)))

    @settings(max_examples=60, deadline=None)
    @given(a=_snapshot_strategy(), b=_snapshot_strategy(),
           c=_snapshot_strategy())
    def test_merge2_associative_commutative(a, b, c):
        _check_merge_algebra(a, b, c)


# ----------------------------- health ---------------------------------- #
def test_health_classification():
    mon = HealthMonitor(origin="fe0", min_packets=3)
    for node in (0, 1, 2):
        for _ in range(5):
            mon.observe_packet(node, size=100, wall_s=0.01)
    for _ in range(5):  # node 3 scans 10x slower than the median
        mon.observe_packet(3, size=100, wall_s=0.1)
    mon.observe_packet(4, size=100, wall_s=5.0)  # under evidence floor
    rep = mon.report()
    assert rep.states[0] == HEALTH_OK
    assert rep.states[3] == HEALTH_SUSPECT
    assert rep.states[4] == HEALTH_OK  # insufficient data != sickness
    assert 3 in rep.suspects and rep.healthy_fraction < 1.0

    mon2 = HealthMonitor(origin="fe0")
    for _ in range(4):
        mon2.observe_failure(7)
    assert mon2.report().states[7] == HEALTH_SUSPECT


def test_health_gossip_merge():
    a, b = HealthMonitor(origin="fe0"), HealthMonitor(origin="fe1")
    for _ in range(5):
        a.observe_packet(0, size=100, wall_s=0.01)
        b.observe_packet(1, size=100, wall_s=0.01)
    b.merge_digest(a.digest())
    assert set(b.report().states) == {0, 1}
    # idempotent: merging the same digest twice changes nothing
    before = b.digest()
    b.merge_digest(a.digest())
    assert b.digest() == before
    # own-origin entries are never overwritten by hearsay
    fake = {"origin": "x", "entries": [
        {"node": 1, "origin": "fe1", "packets": 999,
         "rate_ewma": 9.9, "failure_ewma": 0.9, "stamp": 10**6}]}
    b.merge_digest(fake)
    assert b.report().failures[1] < 0.5
    # higher stamp per (node, origin) wins; lower is ignored
    a.observe_packet(0, size=100, wall_s=0.5)
    newer = a.digest()
    b.merge_digest(newer)
    got = b.report().rates[0]
    b.merge_digest({"origin": "fe0", "entries": [
        {"node": 0, "origin": "fe0", "packets": 1,
         "rate_ewma": 7.0, "failure_ewma": 0.0, "stamp": 1}]})
    assert b.report().rates[0] == got

    assert HealthMonitor().report().healthy_fraction == 1.0


# ------------------------- service integration ------------------------- #
def test_spans_deterministic_and_schema_valid():
    runs = []
    for _ in range(2):
        obs = Observability(origin="fe0")
        run_service(make_store(seed=11), obs=obs, stream=True)
        recs = obs.tracer.records()
        assert validate_records(recs) == []
        assert obs.tracer.open_spans() == []
        runs.append(comparable_records(recs))
    assert runs[0] == runs[1]


def test_disabled_path_results_identical():
    base, _ = run_service(make_store(seed=13))
    assert base.obs is None and base.backend.obs is None
    obs = Observability(origin="fe0")
    traced, _ = run_service(make_store(seed=13), obs=obs)
    for t_base, t_obs in zip(base.tickets.values(),
                             traced.tickets.values()):
        assert t_base.status == t_obs.status == SERVED
        assert merge_lib.results_identical(t_base.result, t_obs.result)
    # tracing cost the virtual timeline nothing: same makespans
    assert traced._virtual_now > 0.0
    assert obs.metrics.value("tickets.served") == len(EXPRS)


def test_cache_hit_records_short_span_and_tier_metric():
    obs = Observability(origin="fe0")
    svc = QueryService(make_store(), obs=obs)
    svc.submit(EXPRS[0])
    svc.drain()
    tid = svc.submit(EXPRS[0])  # L1 hit: answered with zero brick I/O
    assert svc.result(tid).from_cache
    assert obs.metrics.value("cache.hits_l1") == 1
    sub = [s for s in obs.tracer.spans
           if s.name == "submit" and s.ticket == tid]
    assert len(sub) == 1 and sub[0].status == STATUS_OK
    assert sub[0].attrs["cache_tier"] == "l1"
    finals = [s for s in obs.tracer.spans
              if s.name == "final" and s.ticket == tid]
    assert len(finals) == 1 and finals[0].attrs["cached"] is True
    svc.close()
    assert obs.tracer.open_spans() == []


def test_rejected_and_aborted_streams_close_spans_with_error():
    obs = Observability(origin="fe0")
    svc = QueryService(make_store(), obs=obs)
    bad = svc.submit("&& e_total", stream=True)  # parse error -> rejected
    assert svc.result(bad).status == REJECTED
    assert svc.stream(bad).state == ABORTED
    assert obs.metrics.value("submit.rejected") == 1

    pending = svc.submit(EXPRS[0], stream=True)
    svc.close()  # truncated: never dispatched; close aborts the stream
    assert svc.stream(pending).state == ABORTED
    assert obs.tracer.open_spans() == []
    by_ticket = {s.ticket: s for s in obs.tracer.spans
                 if s.name == "stream"}
    assert by_ticket[bad].status == STATUS_ERROR
    assert by_ticket[pending].status == STATUS_ERROR
    assert by_ticket[pending].attrs["note"] == "service closed"
    assert validate_records(obs.tracer.records()) == []


TICKET_SPANS = ("submit", "window", "plan", "dispatch", "final")


def _ticket_view(obs):
    recs = [r for r in obs.tracer.records() if r["name"] in TICKET_SPANS]
    recs = comparable_records(recs, virtual=False)
    # packet-span interleaving shifts span ids between backends; the
    # ticket-visible structure is ids-free
    for r in recs:
        r.pop("span_id"), r.pop("parent_id")
    return recs


def test_sim_and_spmd_ticket_spans_identical():
    views = []
    for backend in ("sim", "spmd"):
        obs = Observability(origin="fe0")
        svc, _ = run_service(make_store(seed=17), obs=obs,
                             backend=backend)
        assert validate_records(obs.tracer.records()) == []
        views.append(_ticket_view(obs))
    assert views[0] == views[1]


def test_scheduler_health_gate_narrows_windows():
    obs = Observability(origin="fe0")
    for node in (0, 1):
        for _ in range(5):
            obs.health.observe_packet(node, size=100, wall_s=0.01)
    for _ in range(5):
        obs.health.observe_failure(1)  # node 1 -> suspect

    def fill(sched):
        for i in range(8):
            sched.enqueue(make_submission(i, f"t{i}", EXPRS[0], 0, SCHEMA,
                                          n_events=256))

    gated = QueryScheduler(max_batch=8, obs=obs, health_gate=True)
    fill(gated)
    window = gated.next_batch()
    assert len(window) == 4  # healthy_fraction 0.5 halves the window
    assert gated.last_health_hint["healthy_fraction"] == 0.5
    assert gated.last_health_hint["suspect"] == [1]
    assert obs.metrics.value("sched.health_hints") == 1

    ungated = QueryScheduler(max_batch=8, obs=obs)
    fill(ungated)
    assert len(ungated.next_batch()) == 8
    assert ungated.last_health_hint is None


def test_fleet_metrics_reconcile_with_fleet_stats(tmp_path):
    store = make_store(n_events=256)
    fleet = Fleet(store, 2, obs=True)
    fleet.submit(EXPRS[0], frontend=0)
    fleet.drain()
    fleet.submit(EXPRS[0], frontend=0)  # L1 hit at fe0
    fleet.submit(EXPRS[0], frontend=1)  # L2 hit via the shared tier
    fleet.submit(EXPRS[1], frontend=1)
    fleet.drain()

    snap = fleet.metrics_snapshot()
    stats = fleet.fleet_stats()
    assert stats["cache_hits"] == 2 and stats["l2_hits"] == 1
    # the invariant CI's acceptance run pins: merged obs counters
    # reconcile exactly with the service-stats aggregation
    assert (snap.value("cache.hits_l1") + snap.value("cache.hits_l2")
            == stats["cache_hits"])
    assert snap.value("cache.hits_l2") == stats["l2_hits"]
    assert snap.value("tickets.served") == stats["served"]
    assert set(snap.origins) == {"fe0", "fe1", "fleet"}
    assert snap.value("gossip.digests_sent") > 0

    recs = fleet.trace_records()
    assert validate_records(recs) == []
    n = fleet.save_chrome_trace(tmp_path / "fleet.json")
    assert n == len(recs) > 0
    rep = fleet.health_report()
    # states are wall-rate-derived (can jitter on a tiny run); pin the
    # shape: every grid node observed, every state legal
    assert rep is not None
    assert set(rep.states.values()) <= set(HEALTH_STATES)
    assert set(rep.states) == set(range(store.n_nodes))
    fleet.close()
