"""Multi-device SPMD correctness, run in a subprocess with 8 fake CPU
devices (the parent test process must keep its 1-device view for the other
tests — jax pins device count at first init)."""
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_with_devices(body: str, n: int = 8) -> str:
    src = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", src],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=600, cwd=str(REPO))
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_brick_decode_attention_matches_oracle():
    run_with_devices("""
        from repro.configs.registry import reduced_config
        from repro.launch.mesh import make_mesh_of
        from repro.models import model_zoo
        from repro.parallel.sharding import Sharder
        import dataclasses

        # force the brick path: no window, big-enough cache, 4-way model axis
        cfg = reduced_config("qwen3-32b")
        mesh = make_mesh_of((2, 4), ("data", "model"))
        shd = Sharder(cfg, mesh)
        model = model_zoo.build_model(cfg)
        params = model.table.init(jax.random.key(0))

        from repro.core import brick_attention as brick
        W = 8192  # > 4096 threshold, divisible by 4
        assert brick.brick_active(cfg, shd, W)

        cache = model.init_cache(shd, 4, W)
        from repro.train import steps as steps_lib
        dec, _ = steps_lib.make_decode_step(cfg, model, mesh)
        tok = jnp.ones((4, 1), jnp.int32)
        logits = []
        c = cache
        jd = jax.jit(dec)
        for i in range(3):
            lg, c = jd(params, c, {"tokens": tok + i})
            logits.append(np.asarray(lg, np.float32))

        # oracle: same model decoded on a 1x1 mesh (non-brick path)
        cfg1 = dataclasses.replace(cfg, decode_cache_seq_shard=False)
        mesh1 = make_mesh_of((1, 1), ("data", "model"))
        shd1 = Sharder(cfg1, mesh1)
        model1 = model_zoo.build_model(cfg1)
        c1 = model1.init_cache(shd1, 4, W)
        dec1, _ = steps_lib.make_decode_step(cfg1, model1, mesh1)
        jd1 = jax.jit(dec1)
        for i in range(3):
            lg1, c1 = jd1(params, c1, {"tokens": tok + i})
            np.testing.assert_allclose(logits[i], np.asarray(lg1, np.float32),
                                       rtol=2e-4, atol=2e-4)
        print("BRICK ATTENTION OK")
    """)


def test_train_step_invariant_to_mesh():
    """The same train step on (1,1) and (2,4) meshes gives the same loss —
    sharding must not change the math."""
    run_with_devices("""
        from repro.configs.registry import reduced_config
        from repro.launch.mesh import make_mesh_of
        from repro.models import model_zoo
        from repro.optim.adamw import AdamW, init_opt_state
        from repro.parallel.sharding import Sharder
        from repro.train import steps as steps_lib

        cfg = reduced_config("qwen3-14b", microbatches=2)
        model = model_zoo.build_model(cfg)
        params = model.table.init(jax.random.key(0))
        batch = {
            "tokens": jax.random.randint(jax.random.key(1), (8, 32), 0,
                                         cfg.vocab_size, jnp.int32),
            "labels": jax.random.randint(jax.random.key(2), (8, 32), 0,
                                         cfg.vocab_size, jnp.int32),
        }
        losses = []
        for shape in ((1, 1), (2, 4)):
            mesh = make_mesh_of(shape, ("data", "model"))
            step_fn, shd = steps_lib.make_train_step(cfg, model, mesh)
            p = jax.device_put(params, model.table.shardings(shd))
            o = init_opt_state(p, AdamW())
            _, _, m = jax.jit(step_fn)(p, o, batch)
            losses.append(float(m["loss"]))
        assert abs(losses[0] - losses[1]) < 1e-3, losses
        print("MESH INVARIANCE OK", losses)
    """)


def test_spmd_query_matches_host_jse():
    """The SPMD grid-brick query job over a sharded event store equals the
    host-level JSE result (the paper's dataflow, two realizations)."""
    run_with_devices("""
        from repro.configs.geps_events import reduced
        from repro.core import events as ev
        from repro.core.brick import create_store, gather_store, shard_to_mesh
        from repro.core.catalog import MetadataCatalog
        from repro.core.jse import JobSubmissionEngine, spmd_query_step
        from repro.launch.mesh import make_mesh_of

        cfgE = reduced()
        schema = ev.EventSchema.from_config(cfgE)
        store = create_store(schema, n_events=128, n_nodes=8,
                             events_per_brick=16, replication=2, seed=3)
        batch = gather_store(store)
        mesh = make_mesh_of((8, 1), ("data", "model"))
        sharded = shard_to_mesh(batch, mesh)
        expr = "e_total > 40 && count(pt > 15) >= 1"
        step = jax.jit(spmd_query_step(expr, schema, calib_iters=2))
        out = step(sharded)

        cat = MetadataCatalog(8)
        jse = JobSubmissionEngine(cat, store)
        jid = jse.submit(expr, calib_iters=2)
        merged, _ = jse.run_job_simulated(jid)
        assert int(out["n_selected"]) == merged.n_selected
        assert abs(float(out["sum_var"]) - merged.sum_var) < 1e-2
        print("SPMD QUERY OK", int(out["n_selected"]))
    """)
